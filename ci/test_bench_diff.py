#!/usr/bin/env python3
"""Unit tests for ci/bench_diff.py (run with `python3 ci/test_bench_diff.py`
or `python3 -m pytest ci/test_bench_diff.py -q`). Covers the schema
duck-typing, missing-key alerts, new-bench skips, the threshold edge, and
the warn-only escape hatch."""

import json
import os
import subprocess
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_diff  # noqa: E402

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_diff.py")


def doc(cases, section="cases", **top):
    d = {"bench": "t", section: cases}
    d.update(top)
    return d


class IndexDocTest(unittest.TestCase):
    def test_generic_schema_picks_rate_metrics_only(self):
        idx = bench_diff.index_doc(
            doc([{"n": 2000, "rank": 64, "speedup": 12.0, "solve_query_s": 0.4, "build_s": 1.0}])
        )
        key = (("section", "cases"), ("n", 2000), ("rank", 64))
        # absolute seconds are runner-dependent and must NOT gate
        self.assertEqual(idx, {key: {"speedup": 12.0}})

    def test_legacy_mmm_schema_indexes_both_sections(self):
        idx = bench_diff.index_doc(
            {
                "bench": "mmm_microbench",
                "threads": 2,
                "gemm": [{"n": 256, "gflops": 4.0}],
                "solves": [{"n": 2000, "t": 8, "cached_speedup": 1.3, "materialize_speedup": 1.8}],
            }
        )
        self.assertEqual(len(idx), 2)
        self.assertIn((("section", "gemm"), ("n", 256)), idx)
        solves = idx[(("section", "solves"), ("n", 2000), ("t", 8))]
        self.assertEqual(solves, {"cached_speedup": 1.3, "materialize_speedup": 1.8})


class DiffTest(unittest.TestCase):
    def diff(self, cur_cases, base_cases, threshold=0.20):
        return bench_diff.diff_indexed(
            bench_diff.index_doc(doc(cur_cases)),
            bench_diff.index_doc(doc(base_cases)),
            threshold,
        )

    def test_regression_past_threshold_alerts(self):
        alerts = self.diff([{"n": 1, "speedup": 7.9}], [{"n": 1, "speedup": 10.0}])
        self.assertEqual(len(alerts), 1)
        self.assertIn("21% slower", alerts[0])

    def test_exactly_at_threshold_passes(self):
        # ratio == 1 - threshold is NOT "more than threshold slower"
        self.assertEqual(self.diff([{"n": 1, "speedup": 8.0}], [{"n": 1, "speedup": 10.0}]), [])

    def test_just_inside_threshold_passes(self):
        self.assertEqual(self.diff([{"n": 1, "speedup": 8.01}], [{"n": 1, "speedup": 10.0}]), [])

    def test_improvement_passes(self):
        self.assertEqual(self.diff([{"n": 1, "speedup": 99.0}], [{"n": 1, "speedup": 10.0}]), [])

    def test_missing_case_alerts(self):
        alerts = self.diff([{"n": 1, "speedup": 10.0}], [{"n": 1, "speedup": 10.0}, {"n": 2, "speedup": 5.0}])
        self.assertEqual(len(alerts), 1)
        self.assertIn("case missing", alerts[0])

    def test_missing_metric_alerts(self):
        alerts = self.diff([{"n": 1, "qps": 100.0}], [{"n": 1, "qps": 100.0, "speedup": 3.0}])
        self.assertEqual(len(alerts), 1)
        self.assertIn("metric missing", alerts[0])

    def test_extra_current_cases_and_metrics_are_ignored(self):
        # fresh runs may grow the grid before the baseline is refreshed
        alerts = self.diff(
            [{"n": 1, "speedup": 10.0, "extra_speedup": 1.0}, {"n": 2, "speedup": 1.0}],
            [{"n": 1, "speedup": 10.0}],
        )
        self.assertEqual(alerts, [])

    def test_nonpositive_baseline_is_skipped(self):
        self.assertEqual(self.diff([{"n": 1, "speedup": 0.1}], [{"n": 1, "speedup": 0.0}]), [])


class CliTest(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.results = os.path.join(self.tmp.name, "results")
        self.baselines = os.path.join(self.tmp.name, "benches")
        os.makedirs(self.results)
        os.makedirs(self.baselines)

    def tearDown(self):
        self.tmp.cleanup()

    def write(self, dirname, fname, cases):
        with open(os.path.join(dirname, fname), "w") as f:
            json.dump(doc(cases), f)

    def run_cli(self, *argv):
        return subprocess.run(
            [sys.executable, SCRIPT, *argv], capture_output=True, text=True
        )

    def test_dir_mode_gates_regressions(self):
        self.write(self.results, "BENCH_love.json", [{"n": 8000, "speedup": 2.0}])
        self.write(self.baselines, "BENCH_love_baseline.json", [{"n": 8000, "speedup": 10.0}])
        p = self.run_cli("--results", self.results, "--baselines", self.baselines)
        self.assertEqual(p.returncode, 1, p.stdout + p.stderr)
        self.assertIn("[love]", p.stdout)

    def test_dir_mode_skips_new_bench_without_baseline(self):
        self.write(self.results, "BENCH_new.json", [{"n": 100, "speedup": 1.0}])
        p = self.run_cli("--results", self.results, "--baselines", self.baselines)
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("no committed baseline", p.stdout)

    def test_warn_only_reports_but_passes(self):
        self.write(self.results, "BENCH_love.json", [{"n": 8000, "speedup": 2.0}])
        self.write(self.baselines, "BENCH_love_baseline.json", [{"n": 8000, "speedup": 10.0}])
        p = self.run_cli("--results", self.results, "--baselines", self.baselines, "--warn-only")
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("warn-only", p.stdout)

    def test_pair_mode_still_works(self):
        cur = os.path.join(self.tmp.name, "cur.json")
        base = os.path.join(self.tmp.name, "base.json")
        with open(cur, "w") as f:
            json.dump(doc([{"n": 1, "gflops": 4.1}]), f)
        with open(base, "w") as f:
            json.dump(doc([{"n": 1, "gflops": 4.0}]), f)
        p = self.run_cli(cur, base)
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("1 cases checked", p.stdout)

    def test_empty_results_dir_is_a_usage_error(self):
        p = self.run_cli("--results", self.results, "--baselines", self.baselines)
        self.assertEqual(p.returncode, 2)


if __name__ == "__main__":
    unittest.main()
