#!/usr/bin/env python3
"""Perf-regression gate: diff fresh BENCH_*.json artifacts against their
committed baselines and fail when a rate/speedup metric falls more than
the threshold below baseline.

Two modes:

  pair mode (legacy):   bench_diff.py CURRENT.json BASELINE.json
  directory mode:       bench_diff.py --results rust/results --baselines rust/benches

Directory mode diffs every ``BENCH_<name>.json`` under ``--results``
against ``BENCH_<name>_baseline.json`` under ``--baselines``; a bench with
no committed baseline yet is reported and skipped (new benches land before
their first baseline).

The schema is duck-typed: every list-valued top-level key holds cases,
each case's identity is its identifying keys (``n``, ``b``, ``t``,
``rank``, …) and its metrics are the higher-is-better keys (``gflops``,
``speedup``, ``*_speedup``, ``qps``). Absolute seconds are deliberately
NOT diffed — they are runner-dependent; only rates and ratios gate.

The step is blocking. ``--warn-only`` prints the same report but exits 0 —
CI offers it as an escape hatch (label-gated) for PRs that intentionally
trade a benched metric away.
"""

import argparse
import glob
import json
import os
import sys

# keys that identify a case within its section (order fixes the label)
IDENTITY_KEYS = ("name", "n", "b", "t", "r", "rank", "m", "d", "iters")
# higher-is-better metrics; anything else (raw seconds, counts) is ignored
METRIC_KEYS = ("gflops", "speedup", "qps")
METRIC_SUFFIXES = ("_speedup", "_gflops", "_qps")


def is_metric(key):
    return key in METRIC_KEYS or key.endswith(METRIC_SUFFIXES)


def case_identity(section, case):
    ident = [("section", section)]
    for k in IDENTITY_KEYS:
        if k in case and not is_metric(k):
            ident.append((k, case[k]))
    return tuple(ident)


def case_metrics(case):
    return {
        k: v
        for k, v in case.items()
        if is_metric(k) and isinstance(v, (int, float)) and not isinstance(v, bool)
    }


def index_doc(doc):
    """Map case identity -> {metric: value} for every list section."""
    out = {}
    for section, val in doc.items():
        if not isinstance(val, list):
            continue
        for case in val:
            if not isinstance(case, dict):
                continue
            metrics = case_metrics(case)
            if metrics:
                out[case_identity(section, case)] = metrics
    return out


def fmt_key(key):
    return "/".join(f"{k}={v}" for k, v in key)


def diff_indexed(cur, base, threshold):
    """Alerts for baseline metrics that regressed or went missing."""
    alerts = []
    for key, base_metrics in base.items():
        cur_metrics = cur.get(key)
        if cur_metrics is None:
            alerts.append(f"{fmt_key(key)}: case missing from current run")
            continue
        for name, bval in base_metrics.items():
            if bval is None or bval <= 0:
                continue
            cval = cur_metrics.get(name)
            if cval is None:
                alerts.append(f"{fmt_key(key)} {name}: metric missing from current run")
                continue
            ratio = cval / bval
            if ratio < 1.0 - threshold:
                alerts.append(
                    f"{fmt_key(key)} {name}: {cval:.3f} vs baseline {bval:.3f} "
                    f"({(1.0 - ratio) * 100:.0f}% slower)"
                )
    return alerts


def diff_files(current_path, baseline_path, threshold):
    with open(current_path) as f:
        cur = index_doc(json.load(f))
    with open(baseline_path) as f:
        base = index_doc(json.load(f))
    return diff_indexed(cur, base, threshold), len(base)


def run_pair(args):
    alerts, checked = diff_files(args.current, args.baseline, args.threshold)
    return alerts, checked, []


def run_dirs(args):
    alerts, checked, skipped = [], 0, []
    pattern = os.path.join(args.results, "BENCH_*.json")
    found = sorted(glob.glob(pattern))
    if not found:
        print(f"ERROR: no BENCH_*.json artifacts under {args.results}", file=sys.stderr)
        sys.exit(2)
    for path in found:
        bench = os.path.basename(path)[len("BENCH_") : -len(".json")]
        baseline = os.path.join(args.baselines, f"BENCH_{bench}_baseline.json")
        if not os.path.exists(baseline):
            skipped.append(bench)
            continue
        file_alerts, n = diff_files(path, baseline, args.threshold)
        alerts.extend(f"[{bench}] {a}" for a in file_alerts)
        checked += n
    return alerts, checked, skipped


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", help="fresh BENCH json (pair mode)")
    ap.add_argument("baseline", nargs="?", help="committed baseline json (pair mode)")
    ap.add_argument("--results", help="directory of fresh BENCH_*.json artifacts")
    ap.add_argument("--baselines", help="directory of BENCH_*_baseline.json files")
    ap.add_argument("--threshold", type=float, default=0.20)
    ap.add_argument(
        "--warn-only",
        action="store_true",
        help="report regressions but exit 0 (CI escape hatch)",
    )
    args = ap.parse_args()

    if args.results and args.baselines:
        alerts, checked, skipped = run_dirs(args)
    elif args.current and args.baseline:
        alerts, checked, skipped = run_pair(args)
    else:
        ap.error("need either CURRENT BASELINE or --results DIR --baselines DIR")
        return  # unreachable; keeps linters happy

    for bench in skipped:
        print(f"note: bench '{bench}' has no committed baseline yet — skipped")
    if alerts:
        kind = "PERF ALERT (warn-only)" if args.warn_only else "PERF REGRESSION"
        print(
            f"{kind}: metrics fell more than {args.threshold * 100:.0f}% "
            "below the committed baseline:"
        )
        for a in alerts:
            print(f"  - {a}")
        sys.exit(0 if args.warn_only else 1)
    print(
        f"perf within -{args.threshold * 100:.0f}% of baseline "
        f"({checked} cases checked, {len(skipped)} bench(es) without baselines)"
    )


if __name__ == "__main__":
    main()
