#!/usr/bin/env python3
"""Non-blocking perf-regression alert: diff a fresh BENCH_mmm.json against
the committed baseline and flag any metric that moved more than the
threshold in the bad direction (GFLOP/s or speedups falling). Exits 1 on
an alert so the CI step (marked continue-on-error) shows a warning without
blocking the PR — CI runners are noisy, so this is a tripwire, not a gate.
"""

import argparse
import json
import sys


def index_cases(doc):
    out = {}
    for c in doc.get("gemm", []):
        out[("gemm", c["n"])] = {"gflops": c["gflops"]}
    for c in doc.get("solves", []):
        out[("solve", c["n"], c["t"])] = {
            "cached_speedup": c.get("cached_speedup"),
            "materialize_speedup": c.get("materialize_speedup"),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.20)
    args = ap.parse_args()

    with open(args.current) as f:
        cur = index_cases(json.load(f))
    with open(args.baseline) as f:
        base = index_cases(json.load(f))

    alerts = []
    for key, base_metrics in base.items():
        cur_metrics = cur.get(key)
        if cur_metrics is None:
            alerts.append(f"{key}: missing from current run")
            continue
        for name, bval in base_metrics.items():
            cval = cur_metrics.get(name)
            if bval is None or cval is None or bval <= 0:
                continue
            ratio = cval / bval
            if ratio < 1.0 - args.threshold:
                alerts.append(
                    f"{key} {name}: {cval:.3f} vs baseline {bval:.3f} "
                    f"({(1.0 - ratio) * 100:.0f}% slower)"
                )

    if alerts:
        print("PERF ALERT (non-blocking): metrics regressed past "
              f"±{args.threshold * 100:.0f}% of the committed baseline:")
        for a in alerts:
            print(f"  - {a}")
        sys.exit(1)
    print(f"perf within ±{args.threshold * 100:.0f}% of baseline "
          f"({len(base)} cases checked)")


if __name__ == "__main__":
    main()
