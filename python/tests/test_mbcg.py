"""L2 correctness: jax mBCG solves + tridiagonal recovery vs dense oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.mbcg import mbcg, tridiag_from_coeffs


def spd_matrix(n, seed=0, cond_boost=0.5):
    g = np.random.RandomState(seed).normal(size=(n, n)).astype(np.float32)
    a = g.T @ g + cond_boost * n * np.eye(n, dtype=np.float32)
    return jnp.asarray(a)


def test_solves_match_dense_solve():
    n, s = 60, 4
    a = spd_matrix(n, 1)
    b = jnp.asarray(np.random.RandomState(2).normal(size=(n, s)).astype(np.float32))
    solves, _alphas, _betas = mbcg(lambda m: a @ m, b, n)
    want = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(solves), np.asarray(want), atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=50),
    s=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=100),
)
def test_residual_shrinks_with_iterations(n, s, seed):
    a = spd_matrix(n, seed)
    b = jnp.asarray(
        np.random.RandomState(seed + 1).normal(size=(n, s)).astype(np.float32)
    )
    early, _, _ = mbcg(lambda m: a @ m, b, max(1, n // 4))
    late, _, _ = mbcg(lambda m: a @ m, b, n)
    r_early = float(jnp.linalg.norm(a @ early - b))
    r_late = float(jnp.linalg.norm(a @ late - b))
    assert r_late <= r_early + 1e-3


def test_tridiag_eigenvalues_approximate_spectrum():
    # Ritz values of the recovered T lie within the spectrum of A and the
    # full-iteration logdet matches slogdet
    n = 24
    a = spd_matrix(n, 3)
    z = np.random.RandomState(4).choice([-1.0, 1.0], size=(n, 1)).astype(np.float32)
    _s, alphas, betas = mbcg(lambda m: a @ m, jnp.asarray(z), n)
    t = np.asarray(tridiag_from_coeffs(alphas, betas))[0]
    ritz = np.linalg.eigvalsh(t)
    w = np.linalg.eigvalsh(np.asarray(a))
    assert ritz.min() >= w.min() * 0.9
    assert ritz.max() <= w.max() * 1.1
    # SLQ with the full Krylov space: n·e₁ᵀlog(T)e₁ over many probes ≈ logdet.
    # With one Rademacher probe the estimate is exact in expectation only;
    # here we check the quadrature machinery instead: weights sum to 1.
    evals, vecs = np.linalg.eigh(t)
    weights = vecs[0] ** 2
    assert abs(weights.sum() - 1.0) < 1e-5


def test_slq_logdet_unbiasedness_over_probes():
    n = 32
    a = spd_matrix(n, 5)
    sign, want = np.linalg.slogdet(np.asarray(a))
    assert sign > 0
    rs = np.random.RandomState(6)
    t_probes = 64
    z = rs.choice([-1.0, 1.0], size=(n, t_probes)).astype(np.float32)
    _s, alphas, betas = mbcg(lambda m: a @ m, jnp.asarray(z), n)
    tt = np.asarray(tridiag_from_coeffs(alphas, betas))
    est = 0.0
    for i in range(t_probes):
        evals, vecs = np.linalg.eigh(tt[i])
        est += n * float((vecs[0] ** 2 * np.log(np.maximum(evals, 1e-30))).sum())
    est /= t_probes
    assert abs(est - want) / abs(want) < 0.15, (est, want)


def test_zero_rhs_column_stays_zero():
    n = 16
    a = spd_matrix(n, 7)
    b = np.zeros((n, 2), np.float32)
    b[:, 1] = np.random.RandomState(8).normal(size=n)
    solves, alphas, _ = mbcg(lambda m: a @ m, jnp.asarray(b), 10)
    assert np.abs(np.asarray(solves)[:, 0]).max() == 0.0
    assert np.abs(np.asarray(alphas)[:, 0]).max() == 0.0
