"""L1 correctness: Pallas fused kernel mat-mul vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts —
hypothesis sweeps shapes, dtypes, hyperparameters and kernel families.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.kernel_matmul import kernel_matmul, vmem_estimate_bytes
from compile.kernels.ref import kernel_matmul_ref, kernel_matrix, sq_dists

KINDS = ["rbf", "matern52", "rbf_dls", "matern52_dls"]


def make_inputs(n, d, t, seed=0, dtype=jnp.float32):
    kx, kv = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (n, d), minval=-1.0, maxval=1.0, dtype=dtype)
    v = jax.random.normal(kv, (n, t), dtype=dtype)
    return x, v


@pytest.mark.parametrize("kind", KINDS)
def test_pallas_matches_ref_basic(kind):
    x, v = make_inputs(100, 3, 4)
    got = kernel_matmul(x, v, -0.5, 0.2, -2.0, kind=kind, block_n=32, block_m=32)
    want = kernel_matmul_ref(x, v, -0.5, 0.2, -2.0, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=200),
    d=st.integers(min_value=1, max_value=8),
    t=st.integers(min_value=1, max_value=6),
    kind=st.sampled_from(KINDS),
    log_ls=st.floats(min_value=-1.5, max_value=1.0),
    log_os=st.floats(min_value=-1.0, max_value=1.0),
    bn=st.sampled_from([8, 16, 64, 128]),
    bm=st.sampled_from([8, 32, 128]),
)
def test_pallas_matches_ref_hypothesis(n, d, t, kind, log_ls, log_os, bn, bm):
    x, v = make_inputs(n, d, t, seed=n * 7 + d)
    got = kernel_matmul(
        x, v, log_ls, log_os, -2.0, kind=kind, block_n=bn, block_m=bm
    )
    want = kernel_matmul_ref(x, v, log_ls, log_os, -2.0, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4)


def test_noise_term_only_on_plain_kinds():
    x, v = make_inputs(50, 2, 3, seed=3)
    hi_noise = kernel_matmul(x, v, 0.0, 0.0, 2.0, kind="rbf")
    lo_noise = kernel_matmul(x, v, 0.0, 0.0, -20.0, kind="rbf")
    diff = np.asarray(hi_noise - lo_noise)
    expect = (np.exp(2.0) - np.exp(-20.0)) * np.asarray(v)
    np.testing.assert_allclose(diff, expect, rtol=1e-4, atol=1e-5)
    # derivative kinds must ignore noise entirely
    a = kernel_matmul(x, v, 0.0, 0.0, 2.0, kind="rbf_dls")
    b = kernel_matmul(x, v, 0.0, 0.0, -20.0, kind="rbf_dls")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_symmetry_of_kernel_operator():
    # uᵀ(K̂v) == vᵀ(K̂u) — operator symmetry through the fused path
    x, v = make_inputs(80, 3, 1, seed=4)
    _, u = make_inputs(80, 3, 1, seed=5)
    kv = kernel_matmul(x, v, -0.3, 0.1, -1.0, kind="rbf")
    ku = kernel_matmul(x, u, -0.3, 0.1, -1.0, kind="rbf")
    lhs = float(jnp.vdot(u, kv))
    rhs = float(jnp.vdot(v, ku))
    assert abs(lhs - rhs) < 1e-3 * max(1.0, abs(lhs))


def test_dls_matches_autodiff():
    # ∂(K·v)/∂log ℓ from the fused *_dls kind == jax.grad of the ref
    x, v = make_inputs(40, 2, 2, seed=6)

    def contraction(log_ls):
        k = kernel_matrix(x, x, log_ls, 0.3, kind="rbf")
        return jnp.sum(k @ v)

    got = float(jnp.sum(kernel_matmul(x, v, -0.4, 0.3, None, kind="rbf_dls")))
    want = float(jax.grad(contraction)(-0.4))
    assert abs(got - want) < 1e-2 * max(1.0, abs(want))


def test_sq_dists_nonnegative_and_zero_diag():
    x, _ = make_inputs(30, 4, 1, seed=7)
    r2 = np.asarray(sq_dists(x, x))
    assert (r2 >= 0).all()
    np.testing.assert_allclose(np.diag(r2), 0.0, atol=1e-5)


def test_kernel_matrix_psd():
    # K + small jitter must be PSD (eigvalsh on the oracle, small n)
    x, _ = make_inputs(60, 3, 1, seed=8)
    k = np.asarray(kernel_matrix(x, x, -0.5, 0.0, kind="matern52"))
    w = np.linalg.eigvalsh(k + 1e-5 * np.eye(60))
    assert w.min() > 0


def test_float64_path():
    with jax.enable_x64(True):
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (64, 3)))
        v = jnp.asarray(np.random.RandomState(1).normal(size=(64, 2)))
        got = kernel_matmul(x, v, -0.5, 0.0, -2.0, kind="rbf", block_n=16, block_m=16)
        want = kernel_matmul_ref(x, v, -0.5, 0.0, -2.0, kind="rbf")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-10)


def test_vmem_estimate_within_budget():
    # default tiles must fit comfortably in 16 MiB of VMEM (paper-scale t)
    assert vmem_estimate_bytes(d=128, t=16) < 2 * 1024 * 1024


def test_unknown_kind_raises():
    x, v = make_inputs(16, 2, 1, seed=9)
    with pytest.raises(ValueError):
        kernel_matmul(x, v, 0.0, 0.0, 0.0, kind="nope")
