"""L2 model-graph correctness: BBMM terms vs exact dense oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def problem(n=64, d=3, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (n, d), minval=-1.0, maxval=1.0)
    y = jnp.sin(3.0 * x[:, 0]) + 0.1 * jax.random.normal(ky, (n,))
    params = jnp.asarray([-0.5, 0.0, -2.0], jnp.float32)  # logℓ, log s, log σ²
    return x, y, params


def rademacher(n, t, seed=0):
    bits = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (n, t))
    return jnp.where(bits, 1.0, -1.0).astype(jnp.float32)


@pytest.mark.parametrize("kind", ["rbf", "matern52"])
def test_datafit_matches_dense(kind):
    x, y, params = problem()
    z = rademacher(x.shape[0], 8, 1)
    u0, datafit, _a, _b, _q, _t = model.bbmm_terms(
        x, y, z, params, n_iters=64, kind=kind
    )
    from compile.kernels.ref import kernel_matrix

    k = kernel_matrix(x, x, params[0], params[1], kind=kind)
    khat = k + jnp.exp(params[2]) * jnp.eye(x.shape[0])
    alpha = jnp.linalg.solve(khat, y)
    np.testing.assert_allclose(float(datafit), float(y @ alpha), rtol=2e-3)
    np.testing.assert_allclose(np.asarray(u0), np.asarray(alpha), atol=5e-3)


def test_quad_and_trace_feed_correct_gradient():
    # assemble the BBMM gradient from the artifact outputs (as the Rust
    # coordinator does) and compare against jax.grad of the exact NMLL
    x, y, params = problem(n=48)
    n = x.shape[0]
    z = rademacher(n, 512, 2)  # many probes to kill MC noise
    u0, datafit, alphas, betas, quad, trace = model.bbmm_terms(
        x, y, z, params, n_iters=48
    )
    grad_est = 0.5 * (-np.asarray(quad) + np.asarray(trace))
    want = np.asarray(model.exact_grad_reference(x, y, params))
    np.testing.assert_allclose(grad_est, want, rtol=0.15, atol=0.05)


def test_slq_logdet_from_artifact_outputs():
    # Rust-side assembly: n·mean_i e₁ᵀlog(T̃ᵢ)e₁ vs slogdet
    x, y, params = problem(n=40, seed=3)
    n = x.shape[0]
    z = rademacher(n, 256, 4)
    _u0, _df, alphas, betas, _q, _t = model.bbmm_terms(x, y, z, params, n_iters=40)
    from compile.mbcg import tridiag_from_coeffs
    from compile.kernels.ref import kernel_matrix

    tt = np.asarray(tridiag_from_coeffs(jnp.asarray(alphas), jnp.asarray(betas)))
    est = 0.0
    for i in range(tt.shape[0]):
        evals, vecs = np.linalg.eigh(tt[i])
        est += n * float((vecs[0] ** 2 * np.log(np.maximum(evals, 1e-30))).sum())
    est /= tt.shape[0]
    k = kernel_matrix(x, x, params[0], params[1])
    khat = np.asarray(k + jnp.exp(params[2]) * jnp.eye(n))
    _sign, want = np.linalg.slogdet(khat)
    assert abs(est - want) / abs(want) < 0.1, (est, want)


def test_predict_terms_match_dense_posterior():
    x, y, params = problem(n=56, seed=5)
    ks = jax.random.uniform(jax.random.PRNGKey(6), (10, x.shape[1]), minval=-1, maxval=1)
    mean, var = model.predict_terms(x, y, ks, params, n_iters=56)
    from compile.kernels.ref import kernel_matrix

    k = kernel_matrix(x, x, params[0], params[1])
    khat = k + jnp.exp(params[2]) * jnp.eye(x.shape[0])
    kstar = kernel_matrix(x, ks, params[0], params[1])
    alpha = jnp.linalg.solve(khat, y)
    want_mean = kstar.T @ alpha
    solved = jnp.linalg.solve(khat, kstar)
    want_var = jnp.exp(params[1]) - jnp.sum(kstar * solved, axis=0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(want_mean), atol=5e-3)
    np.testing.assert_allclose(np.asarray(var), np.asarray(want_var), atol=5e-3)


def test_predict_variance_nonnegative_and_bounded_by_prior():
    x, y, params = problem(n=32, seed=7)
    ks = jax.random.uniform(jax.random.PRNGKey(8), (20, x.shape[1]), minval=-3, maxval=3)
    _mean, var = model.predict_terms(x, y, ks, params, n_iters=32)
    v = np.asarray(var)
    assert (v >= 0).all()
    assert (v <= float(jnp.exp(params[1])) + 1e-4).all()


def test_nmll_reference_self_consistency():
    # oracle sanity: better lengthscale ⇒ lower NMLL on smooth data
    x, y, params = problem(n=64, seed=9)
    bad = params.at[0].set(3.0)
    assert float(model.nmll_reference(x, y, params)) < float(
        model.nmll_reference(x, y, bad)
    )
