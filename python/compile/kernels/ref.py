"""Pure-jnp correctness oracle for the fused kernel mat-mul.

Materialises the full kernel matrix and multiplies — the thing the L1
Pallas kernel (and the paper's BBMM framing) deliberately avoids doing.
Every Pallas output is pytest-checked against these functions.

Parameterisation matches the Rust side: log-space hyperparameters,
``K̂ = s·k(r/ℓ) + σ²I`` with ``s = exp(log_os)``, ``ℓ = exp(log_ls)``,
``σ² = exp(log_noise)``.
"""

import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5


def sq_dists(x1, x2):
    """Pairwise squared distances between rows of x1 (n×d) and x2 (m×d)."""
    # |a-b|² = |a|² + |b|² − 2ab, clamped for numerical safety
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True)
    r2 = n1 + n2.T - 2.0 * (x1 @ x2.T)
    return jnp.maximum(r2, 0.0)


def kernel_matrix(x1, x2, log_ls, log_os, kind="rbf"):
    """Noiseless kernel matrix K(x1, x2) for the given covariance family.

    kind:
      rbf          s·exp(−r²/2ℓ²)
      matern52     s·(1+√5r/ℓ+5r²/3ℓ²)·exp(−√5r/ℓ)
      rbf_dls      ∂RBF/∂log ℓ        = K ⊙ (r²/ℓ²)
      matern52_dls ∂Matérn52/∂log ℓ   = s·e^{−u}·u²(1+u)/3,  u = √5r/ℓ
    """
    ls = jnp.exp(log_ls)
    s = jnp.exp(log_os)
    r2 = sq_dists(x1, x2)
    if kind == "rbf":
        return s * jnp.exp(-r2 / (2.0 * ls * ls))
    if kind == "rbf_dls":
        k = s * jnp.exp(-r2 / (2.0 * ls * ls))
        return k * (r2 / (ls * ls))
    r = jnp.sqrt(r2 + 1e-30)
    u = SQRT5 * r / ls
    if kind == "matern52":
        return s * (1.0 + u + u * u / 3.0) * jnp.exp(-u)
    if kind == "matern52_dls":
        return s * jnp.exp(-u) * u * u * (1.0 + u) / 3.0
    raise ValueError(f"unknown kind {kind!r}")


def kernel_matmul_ref(x, v, log_ls, log_os, log_noise, kind="rbf"):
    """(K + σ²I) · V by materialising K — the oracle for the Pallas kernel.

    For derivative kinds (``*_dls``) no noise is added (∂K̂/∂log ℓ has no
    diagonal term); pass ``log_noise=None`` to skip the diagonal too.
    """
    k = kernel_matrix(x, x, log_ls, log_os, kind=kind)
    out = k @ v
    if log_noise is not None and not kind.endswith("_dls"):
        out = out + jnp.exp(log_noise) * v
    return out
