"""L1 Pallas kernel: fused blackbox kernel mat-mul ``(K(X,X) + σ²I)·V``.

This is the compute hot-spot of every mBCG iteration (paper §4: one
matrix-matrix multiply with K̂ per iteration). The kernel never
materialises the n×n matrix K in HBM: a 2-D grid tiles (rows × columns);
each step loads one X row-tile and one (X column-tile, V row-tile) pair
into VMEM, forms the bn×bm kernel block on the fly, and feeds the
block × V-tile product to the MXU, accumulating into the output row-tile.

TPU mapping of the paper's GPU insight (DESIGN.md §Hardware-Adaptation):
the paper replaces Cholesky's sequential panels with big GEMMs that
saturate CUDA cores; here BlockSpec expresses the HBM↔VMEM schedule the
paper wrote with threadblocks, and both the r² expansion (−2·X_i X_jᵀ)
and the K-block × V-tile contraction run on the MXU systolic array.

VMEM per grid step (f32): bn·d + bm·d + bm·t + bn·bm + bn·t floats
≈ 128·128·4B ≙ 64KiB for the K block at the default tile — far inside
the 16MiB VMEM budget; see EXPERIMENTS.md §Perf for the full estimate.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs under the Rust runtime. Real-TPU compilation is a compile-only
target (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5

#: default tile sizes (rows of output × columns of K per step)
BLOCK_N = 128
BLOCK_M = 128


def _kernel_block(xi, xj, log_ls, log_os, kind):
    """bn×bm kernel block between row-tile xi and column-tile xj."""
    ls = jnp.exp(log_ls)
    s = jnp.exp(log_os)
    # r² via the MXU-friendly expansion |a|² + |b|² − 2abᵀ
    n1 = jnp.sum(xi * xi, axis=1, keepdims=True)
    n2 = jnp.sum(xj * xj, axis=1, keepdims=True)
    r2 = jnp.maximum(n1 + n2.T - 2.0 * jnp.dot(xi, xj.T), 0.0)
    if kind == "rbf":
        return s * jnp.exp(-r2 / (2.0 * ls * ls))
    if kind == "rbf_dls":
        return s * jnp.exp(-r2 / (2.0 * ls * ls)) * (r2 / (ls * ls))
    r = jnp.sqrt(r2 + 1e-30)
    u = SQRT5 * r / ls
    if kind == "matern52":
        return s * (1.0 + u + u * u / 3.0) * jnp.exp(-u)
    if kind == "matern52_dls":
        return s * jnp.exp(-u) * u * u * (1.0 + u) / 3.0
    raise ValueError(f"unknown kind {kind!r}")


def _fused_matmul_kernel(x_i_ref, x_j_ref, v_j_ref, p_ref, o_ref, *, kind):
    """One (i, j) grid step: o[i-tile] += K(x[i-tile], x[j-tile]) @ v[j-tile]."""
    j = pl.program_id(1)
    xi = x_i_ref[...]
    xj = x_j_ref[...]
    vj = v_j_ref[...]
    log_ls = p_ref[0]
    log_os = p_ref[1]
    k_block = _kernel_block(xi, xj, log_ls, log_os, kind)
    contrib = jnp.dot(k_block, vj)  # MXU contraction

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("kind", "block_n", "block_m", "interpret")
)
def kernel_matmul(
    x,
    v,
    log_ls,
    log_os,
    log_noise,
    kind="rbf",
    block_n=BLOCK_N,
    block_m=BLOCK_M,
    interpret=True,
):
    """Fused ``(K + σ²I) @ V`` without materialising K.

    * ``x`` — (n, d) inputs; ``v`` — (n, t) right-hand sides.
    * log-space hyperparameters as 0-d arrays / scalars.
    * derivative kinds (``*_dls``) omit the σ² diagonal term.

    Rows are zero-padded to tile multiples; padded V rows are zero so
    phantom columns contribute nothing, and phantom output rows are
    sliced away.
    """
    import math

    n, d = x.shape
    t = v.shape[1]
    bn = min(block_n, max(8, n))
    bm = min(block_m, max(8, n))
    # pad rows to a size divisible by both tile extents
    lcm = bn * bm // math.gcd(bn, bm)
    n_pad = ((n + lcm - 1) // lcm) * lcm
    xp = jnp.concatenate([x, jnp.zeros((n_pad - n, d), x.dtype)], axis=0)
    vp = jnp.concatenate([v, jnp.zeros((n_pad - n, t), v.dtype)], axis=0)
    params = jnp.stack(
        [jnp.asarray(log_ls, x.dtype), jnp.asarray(log_os, x.dtype)]
    )

    grid = (n_pad // bn, n_pad // bm)
    out = pl.pallas_call(
        functools.partial(_fused_matmul_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),  # X row-tile
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),  # X column-tile
            pl.BlockSpec((bm, t), lambda i, j: (j, 0)),  # V row-tile
            pl.BlockSpec((2,), lambda i, j: (0,)),  # hyperparameters
        ],
        out_specs=pl.BlockSpec((bn, t), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, t), v.dtype),
        interpret=interpret,
    )(xp, xp, vp, params)
    out = out[:n]
    if not kind.endswith("_dls") and log_noise is not None:
        out = out + jnp.exp(jnp.asarray(log_noise, v.dtype)) * v
    return out


def vmem_estimate_bytes(d, t, block_n=BLOCK_N, block_m=BLOCK_M, dtype_bytes=4):
    """Static VMEM footprint estimate per grid step (for DESIGN.md §Perf)."""
    return dtype_bytes * (
        block_n * d  # X row-tile
        + block_m * d  # X column-tile
        + block_m * t  # V tile
        + block_n * block_m  # K block
        + block_n * t  # output accumulator
    )
