"""L2: modified batched conjugate gradients (paper Algorithm 2) in JAX.

Fixed iteration count (static shapes for AOT), multiple right-hand sides,
and per-RHS CG coefficient streams (α, β) from which the Lanczos
tridiagonal matrices are rebuilt (Observation 3 / Saad §6.7.3).

The matrix is only touched through a mat-mul closure — at lowering time
that closure is the L1 Pallas fused kernel mat-mul, so the whole mBCG loop
lowers into a single HLO while-loop around the Pallas kernel body.

No ``jnp.linalg`` calls anywhere: LAPACK-backed ops lower to jaxlib custom
calls that the Rust runtime's xla_extension 0.5.1 cannot resolve. The
eigendecomposition of the p×p tridiagonals therefore happens on the Rust
side (O(tp²) — negligible, paper App. B); here we only emit coefficients.
"""

import jax
import jax.numpy as jnp

_TINY = 1e-30


def mbcg(matmul, b, n_iters):
    """Batched CG on ``A X = B`` with coefficient recording.

    * ``matmul(M)`` — applies the implicit SPD matrix to an (n, s) matrix.
    * ``b`` — (n, s) right-hand sides.
    * ``n_iters`` — fixed iteration count p (static).

    Returns ``(solves, alphas, betas)`` with shapes (n, s), (p, s), (p, s).
    Converged columns are protected by masking: once a column's residual
    is ~0 its α/β freeze to (0, 0) and its iterate stops moving, matching
    the Rust engine's freezing semantics.
    """
    n, s = b.shape
    u0 = jnp.zeros_like(b)
    r0 = b
    d0 = r0
    rz0 = jnp.sum(r0 * r0, axis=0)  # (s,)
    alphas0 = jnp.zeros((n_iters, s), b.dtype)
    betas0 = jnp.zeros((n_iters, s), b.dtype)

    def body(j, carry):
        u, r, d, rz, alphas, betas = carry
        v = matmul(d)
        dv = jnp.sum(d * v, axis=0)
        active = rz > _TINY
        alpha = jnp.where(active, rz / jnp.where(dv == 0, 1.0, dv), 0.0)
        u = u + alpha[None, :] * d
        r = r - alpha[None, :] * v
        rz_new = jnp.sum(r * r, axis=0)
        beta = jnp.where(active, rz_new / jnp.where(rz == 0, 1.0, rz), 0.0)
        d = r + beta[None, :] * d
        alphas = alphas.at[j].set(alpha)
        betas = betas.at[j].set(beta)
        return u, r, d, rz_new, alphas, betas

    u, _r, _d, _rz, alphas, betas = jax.lax.fori_loop(
        0, n_iters, body, (u0, r0, d0, rz0, alphas0, betas0)
    )
    return u, alphas, betas


def tridiag_from_coeffs(alphas, betas):
    """Dense (s, p, p) Lanczos tridiagonal batch from CG coefficients.

    ``T[j,j] = 1/α_j + β_{j−1}/α_{j−1}``, ``T[j,j+1] = √β_j/α_j``.
    Frozen iterations (α = 0) contribute identity-like padding rows that
    the caller masks by the per-column effective iteration count.
    """
    p, s = alphas.shape
    safe_a = jnp.where(alphas == 0, 1.0, alphas)
    diag = 1.0 / safe_a  # (p, s)
    prev_term = jnp.concatenate(
        [jnp.zeros((1, s), alphas.dtype), betas[:-1] / safe_a[:-1]], axis=0
    )
    diag = diag + prev_term
    off = jnp.sqrt(jnp.maximum(betas[:-1], 0.0)) / safe_a[:-1]  # (p−1, s)

    t = jnp.zeros((s, p, p), alphas.dtype)
    ii = jnp.arange(p)
    t = t.at[:, ii, ii].set(diag.T)
    jj = jnp.arange(p - 1)
    t = t.at[:, jj, jj + 1].set(off.T)
    t = t.at[:, jj + 1, jj].set(off.T)
    return t
