"""AOT lowering: JAX/Pallas BBMM graphs → HLO **text** artifacts.

HLO text (not ``.serialize()``): the Rust runtime's xla_extension 0.5.1
rejects jax ≥ 0.5 serialized protos (64-bit instruction ids); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Every artifact is a fixed-shape variant; the Rust runtime keys its
executable cache by artifact name. A ``manifest.json`` records shapes so
the Rust side can validate inputs.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.kernel_matmul import kernel_matmul


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts(n=256, d=4, t=8, p=20, m_test=64, kinds=("rbf", "matern52")):
    """Return {name: (lowered, manifest_entry)} for all artifact variants."""
    arts = {}
    for kind in kinds:
        # training-step graph: one mBCG call + derivative mat-muls
        name = f"mll_{kind}_n{n}_d{d}_t{t}_p{p}"
        fn = functools.partial(model.bbmm_terms, n_iters=p, kind=kind)
        lowered = jax.jit(fn).lower(f32(n, d), f32(n), f32(n, t), f32(3))
        arts[name] = (
            lowered,
            {
                "inputs": {
                    "x": [n, d],
                    "y": [n],
                    "z": [n, t],
                    "params": [3],
                },
                "outputs": ["u0", "datafit", "alphas", "betas", "quad", "trace"],
                "kind": kind,
                "p": p,
            },
        )
        # serving graph: batched predictive mean + variance. Prediction-time
        # solves need tighter accuracy than training-step estimates, so the
        # CG budget is deeper than the training artifact's p (paper §6 uses
        # p=20 for training; predictions run CG to convergence).
        p_pred = max(3 * p, 64)
        name = f"predict_{kind}_n{n}_d{d}_m{m_test}"
        fn = functools.partial(model.predict_terms, n_iters=p_pred, kind=kind)
        lowered = jax.jit(fn).lower(f32(n, d), f32(n), f32(m_test, d), f32(3))
        arts[name] = (
            lowered,
            {
                "inputs": {
                    "x": [n, d],
                    "y": [n],
                    "x_star": [m_test, d],
                    "params": [3],
                },
                "outputs": ["mean", "var"],
                "kind": kind,
                "p": p_pred,
            },
        )
    # raw L1 kernel mat-mul (smoke/bench artifact for the Rust runtime)
    name = f"kernel_matmul_rbf_n{n}_d{d}_t{t}"

    def kmm(x, v, params):
        return (
            kernel_matmul(x, v, params[0], params[1], params[2], kind="rbf"),
        )

    lowered = jax.jit(kmm).lower(f32(n, d), f32(n, t), f32(3))
    arts[name] = (
        lowered,
        {
            "inputs": {"x": [n, d], "v": [n, t], "params": [3]},
            "outputs": ["khat_v"],
            "kind": "rbf",
        },
    )
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--t", type=int, default=8)
    ap.add_argument("--p", type=int, default=20)
    ap.add_argument("--m-test", type=int, default=64)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    arts = build_artifacts(n=args.n, d=args.d, t=args.t, p=args.p, m_test=args.m_test)
    for name, (lowered, entry) in arts.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = entry
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
