"""L2: the BBMM inference graph (paper §4) as pure JAX, calling the L1
Pallas fused kernel mat-mul.

Two lowering targets (see aot.py):

* ``bbmm_terms``   — the training-step graph: one mBCG call over the RHS
  block ``[y z₁ … z_t]`` plus the derivative mat-muls, emitting every
  ingredient of the NMLL and its gradient. The O(tp²) tridiagonal
  eigen-solve for the SLQ log-det is *not* in the graph (LAPACK custom
  calls don't exist in the Rust runtime's XLA); the Rust coordinator
  finishes it from the returned α/β streams — the same negligible
  post-processing split as paper App. B.
* ``predict_terms`` — the serving graph: batched predictive mean and
  latent variance for a block of test points from a single mBCG call.

Raw hyperparameters are log-space, matching the Rust side:
``params = [log ℓ, log s, log σ²]``.
"""

import functools

import jax.numpy as jnp

from compile.kernels.kernel_matmul import kernel_matmul
from compile.kernels.ref import kernel_matrix
from compile.mbcg import mbcg

LN_2PI = 1.8378770664093453


def _matmul_fns(x, params, kind):
    """(K̂·M, dK̂/dlog ℓ·M, K·M-noiseless) closures over the Pallas kernel."""
    log_ls, log_os, log_noise = params[0], params[1], params[2]

    def khat(m):
        return kernel_matmul(x, m, log_ls, log_os, log_noise, kind=kind)

    def dk_dls(m):
        return kernel_matmul(x, m, log_ls, log_os, None, kind=kind + "_dls")

    def k_nonoise(m):  # = dK̂/dlog s (since s = e^{log s} scales K linearly)
        return kernel_matmul(x, m, log_ls, log_os, None, kind=kind)

    return khat, dk_dls, k_nonoise


def bbmm_terms(x, y, z, params, n_iters=20, kind="rbf"):
    """All BBMM inference ingredients from ONE mBCG call (paper §4).

    Inputs: ``x (n,d)``, ``y (n,)``, probe block ``z (n,t)`` (Rademacher,
    drawn by the Rust coordinator so it controls the seed), ``params (3,)``.

    Returns a tuple of arrays (AOT-friendly, no pytrees):
      u0        (n,)   — K̂⁻¹y
      datafit   ()     — yᵀK̂⁻¹y
      alphas    (p,t)  — probe-column CG coefficients
      betas     (p,t)
      quad      (3,)   — u₀ᵀ (dK̂/dθ_j) u₀ per raw parameter
      trace     (3,)   — mean_i zᵢ-solveᵀ (dK̂/dθ_j) zᵢ  (eq. 4)
    """
    n = x.shape[0]
    t = z.shape[1]
    khat, dk_dls, k_nonoise = _matmul_fns(x, params, kind)
    sigma2 = jnp.exp(params[2])

    b = jnp.concatenate([y[:, None], z], axis=1)  # (n, 1+t)
    solves, alphas, betas = mbcg(khat, b, n_iters)
    u0 = solves[:, 0]
    uz = solves[:, 1:]  # K̂⁻¹ Z

    datafit = jnp.dot(y, u0)

    # derivative mat-muls, shared between quad and trace terms:
    # one batched call per parameter on [u0 | Z]
    block = jnp.concatenate([u0[:, None], z], axis=1)  # (n, 1+t)
    d_ls = dk_dls(block)
    d_os = k_nonoise(block)
    # dK̂/dlog σ² · M = σ² M
    quad = jnp.stack(
        [
            jnp.dot(u0, d_ls[:, 0]),
            jnp.dot(u0, d_os[:, 0]),
            sigma2 * jnp.dot(u0, u0),
        ]
    )
    trace = jnp.stack(
        [
            jnp.mean(jnp.sum(uz * d_ls[:, 1:], axis=0)),
            jnp.mean(jnp.sum(uz * d_os[:, 1:], axis=0)),
            sigma2 * jnp.mean(jnp.sum(uz * z, axis=0)),
        ]
    )
    # probe α/β only (column 0 is the y-solve)
    return u0, datafit, alphas[:, 1:], betas[:, 1:], quad, trace


def predict_terms(x, y, x_star, params, n_iters=50, kind="rbf"):
    """Predictive mean + latent variance for a test block (paper eq. 1).

    One mBCG call over ``[y  K_{Xx*}]`` gives both terms:
      mean  (m,) = k_{Xx*}ᵀ K̂⁻¹ y
      var   (m,) = k(x*,x*) − k_{Xx*}ᵀ K̂⁻¹ k_{Xx*}
    """
    khat, _, _ = _matmul_fns(x, params, kind)
    log_ls, log_os = params[0], params[1]
    k_star = kernel_matrix(x, x_star, log_ls, log_os, kind=kind)  # (n, m)
    prior_diag = jnp.exp(log_os) * jnp.ones(x_star.shape[0], x.dtype)

    b = jnp.concatenate([y[:, None], k_star], axis=1)
    solves, _a, _b = mbcg(khat, b, n_iters)
    mean = jnp.sum(k_star * solves[:, :1], axis=0)
    quad = jnp.sum(k_star * solves[:, 1:], axis=0)
    var = jnp.maximum(prior_diag - quad, 0.0)
    return mean, var


def nmll_reference(x, y, params, kind="rbf"):
    """Exact NMLL via dense materialisation (test oracle only — uses
    slogdet/solve, never lowered to an artifact)."""
    n = x.shape[0]
    k = kernel_matrix(x, x, params[0], params[1], kind=kind)
    khat = k + jnp.exp(params[2]) * jnp.eye(n, dtype=x.dtype)
    alpha = jnp.linalg.solve(khat, y)
    _sign, logdet = jnp.linalg.slogdet(khat)
    return 0.5 * (jnp.dot(y, alpha) + logdet + n * LN_2PI)


def exact_grad_reference(x, y, params, kind="rbf"):
    """Autodiff gradient of the exact NMLL (oracle for the trace terms)."""
    import jax

    return jax.grad(functools.partial(nmll_reference, x, y, kind=kind))(params)
