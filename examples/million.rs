//! **Million-point GP** — the paper's scale target, served by the
//! distributed shard backend: `K(X, X)` at n = 10⁶ is 8 TB of f64, so no
//! placement may ever materialise it. Shard rows live on forked
//! `shard-worker` processes (this binary re-execs itself as the worker),
//! each streaming its kernel rows on the fly under its own
//! materialisation budget; the driver runs partitioned-kernel mBCG with
//! one O(n·t) broadcast/gather round per iteration (Wang et al. 2019),
//! then serves predictions by chunked cross-covariance contraction
//! against the solved representer weights — never holding more than one
//! chunk of `K_*` rows.
//!
//! ```bash
//! cargo run --release --example million            # n = 1_000_000 (hours on a laptop)
//! BBMM_MILLION_N=100000 cargo run --release --example million
//! BBMM_EXAMPLE_SMOKE=1 cargo run --release --example million   # CI-sized, ~seconds
//! ```
//!
//! Tunables: `BBMM_MILLION_N` (rows), `BBMM_MILLION_WORKERS` (processes),
//! `BBMM_MILLION_ITERS` (mBCG iteration cap), `BBMM_MILLION_BUDGET_MB`
//! (per-worker materialisation budget), `BBMM_MILLION_TRANSPORT=shm|tcp`
//! (data plane — default `shm`, the zero-copy shared-memory lane, which
//! degrades to TCP where no segment can map), `BBMM_MILLION_NUMA=auto|off`
//! (worker placement across NUMA nodes — default `auto`),
//! `BBMM_PRECISION=f64|mixed` (tile-compute precision — inherited by the
//! forked workers through the environment, so driver and fleet always
//! agree). Smoke mode shrinks to n = 3000 / 2 workers, parity-checks the
//! distributed solve against the in-process placement to 1e-8 before
//! serving, and asserts the shm lane moved zero payload bytes through the
//! socket when the segment mapped.

use bbmm_gp::kernels::{Kernel, Rbf, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_op, MbcgOptions};
use bbmm_gp::linalg::op::{mmm, MmmPlan};
use bbmm_gp::runtime::dist::{
    worker, MultiProcessBackend, NumaMode, ShardBackend, ShmOptions, Transport, WorkerLaunch,
};
use bbmm_gp::tensor::{simd, Mat};
use bbmm_gp::util::{par, Rng};
use std::sync::Arc;
use std::time::Instant;

const NOISE: f64 = 0.1;
const TEST_POINTS: usize = 64;
const CHUNK_ROWS: usize = 65_536;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn truth(row: &[f64]) -> f64 {
    (2.0 * row[0]).sin() + 0.5 * row[1].cos()
}

fn main() {
    // this binary forks itself: `million shard-worker --connect <addr>`
    if worker::maybe_run_worker() {
        return;
    }
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let (n, workers, shards, iters) = if smoke {
        (3_000, 2, 8, 30)
    } else {
        (
            env_usize("BBMM_MILLION_N", 1_000_000),
            env_usize("BBMM_MILLION_WORKERS", 4),
            16,
            env_usize("BBMM_MILLION_ITERS", 5),
        )
    };
    let budget_mb = env_usize("BBMM_MILLION_BUDGET_MB", 1024);
    let transport = match std::env::var("BBMM_MILLION_TRANSPORT").as_deref() {
        Ok("tcp") => Transport::Tcp,
        _ => Transport::Shm(ShmOptions::default()),
    };
    let numa = match std::env::var("BBMM_MILLION_NUMA").as_deref() {
        Ok("off") => NumaMode::Off,
        _ => NumaMode::Auto,
    };
    let kernel = Rbf::new(0.5, 1.0);
    println!(
        "million: n={n} workers={workers} shards={shards} iters={iters} \
         budget={budget_mb}MB/worker transport={} numa={numa} threads={} \
         precision={} simd={} (aggregate K would be {:.1} GB — never built)",
        match &transport {
            Transport::Tcp => "tcp",
            Transport::Shm(_) => "shm",
        },
        par::num_threads(),
        mmm::default_precision().name(),
        simd::active().name(),
        (n as f64) * (n as f64) * 8.0 / 1e9
    );

    // ---- synthetic regression data (generated, not stored densely) -----
    let t0 = Instant::now();
    let mut rng = Rng::new(1_000_000);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| truth(x.row(i)) + 0.05 * rng.normal())
        .collect();
    let xt = Mat::from_fn(TEST_POINTS, 2, |_, _| rng.uniform_in(-0.9, 0.9));
    println!("data: {n} rows generated in {:.2}s", t0.elapsed().as_secs_f64());

    // ---- fork the worker fleet and load the shard partition ------------
    let t0 = Instant::now();
    let proc = Arc::new(
        MultiProcessBackend::launch_with(
            x.clone(),
            &kernel,
            NOISE,
            shards,
            workers,
            budget_mb,
            WorkerLaunch::default(),
            transport,
            numa,
        )
        .expect("fork shard workers"),
    );
    println!(
        "fleet: {} ({:.2}s to fork + load)",
        proc.describe(),
        t0.elapsed().as_secs_f64()
    );
    let routed = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), NOISE, shards)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);

    // ---- training-phase linear algebra: α = K̂⁻¹y via distributed mBCG --
    let b = Mat::from_vec(n, 1, y);
    let opts = MbcgOptions {
        max_iters: iters,
        tol: 1e-8,
        n_solve_only: 1,
    };
    let t0 = Instant::now();
    let result = mbcg_op(&routed, &b, |m| m.clone(), &opts);
    let solve_s = t0.elapsed().as_secs_f64();
    let stats = proc.stats();
    // each mBCG iteration pays one K̂·d product: 2n² flops at t = 1
    let solve_gflops =
        result.iterations as f64 * 2.0 * (n as f64) * (n as f64) / solve_s.max(1e-9) / 1e9;
    println!(
        "solve: {} mBCG iterations in {:.2}s ({solve_gflops:.2} GFLOP/s effective, \
         precision={}, simd={}) — {} round trips ({} zero-copy), {:.1} MB out / \
         {:.1} MB back ({:.2} MB per round: O(n·t), independent of K), \
         control plane {:.1} kB",
        result.iterations,
        solve_s,
        mmm::default_precision().name(),
        simd::active().name(),
        stats.rounds,
        stats.shm_rounds,
        stats.bytes_tx as f64 / 1e6,
        stats.bytes_rx as f64 / 1e6,
        (stats.bytes_tx + stats.bytes_rx) as f64 / 1e6 / stats.rounds.max(1) as f64,
        stats.ctrl_bytes as f64 / 1e3
    );
    let alpha = result.solves;

    // smoke only: the distributed placement must match in-process exactly
    // (the bench and tests gate this too; here it guards the CI path)
    if smoke {
        let mut inproc =
            ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), NOISE, shards);
        // match the workers' execution exactly: they stream rows (never
        // panel) and inherit the same BBMM_PRECISION default through the
        // environment, so pinning the reference to Stream keeps the parity
        // bit-exact under mixed precision too
        inproc.set_plan(MmmPlan::Stream);
        let want = mbcg_op(&inproc, &b, |m| m.clone(), &opts);
        let scale = want.solves.fro_norm().max(1.0);
        let diff = alpha.max_abs_diff(&want.solves) / scale;
        assert!(diff < 1e-8, "distributed solve diverged from in-process: {diff}");
        println!("parity: distributed == in-process to {diff:.2e}");
        // zero-copy contract: with the segment mapped, mBCG payload never
        // touches the socket — only control-plane frames do
        if proc.shm_active() {
            let s = proc.stats();
            assert!(
                s.bytes_tx == 0 && s.bytes_rx == 0,
                "shm lane leaked payload onto the socket ({} tx / {} rx)",
                s.bytes_tx,
                s.bytes_rx
            );
            println!("zero-copy: {} rounds, 0 payload bytes on the socket", s.shm_rounds);
        }
    }

    // ---- serving: chunked cross-covariance against the solved weights --
    // k_*ᵀ α accumulated CHUNK_ROWS training rows at a time, so the
    // resident cross block is TEST_POINTS × CHUNK_ROWS regardless of n
    let t0 = Instant::now();
    let mut mean = vec![0.0; TEST_POINTS];
    let mut row0 = 0;
    while row0 < n {
        let rows = CHUNK_ROWS.min(n - row0);
        for j in 0..TEST_POINTS {
            let q = xt.row(j);
            let mut acc = 0.0;
            for i in row0..row0 + rows {
                acc += kernel.eval(q, x.row(i)) * alpha.get(i, 0);
            }
            mean[j] += acc;
        }
        row0 += rows;
    }
    let total_err: f64 = (0..TEST_POINTS)
        .map(|j| (mean[j] - truth(xt.row(j))).abs())
        .sum();
    let mae = total_err / TEST_POINTS as f64;
    println!(
        "serve: {TEST_POINTS} predictions in {:.2}s — MAE vs noiseless truth {mae:.4}",
        t0.elapsed().as_secs_f64()
    );
    if smoke {
        assert!(mae < 0.5, "posterior mean off: {mae}");
    }

    // ---- hyperparameter push over the wire (one training-loop step) ----
    let mut raw = kernel.params();
    raw[0] += 0.1; // nudge log ℓ, as an optimiser step would
    proc.set_params(&raw, Some(NOISE));
    let t0 = Instant::now();
    let refreshed = mbcg_op(&routed, &b, |m| m.clone(), &opts);
    println!(
        "re-solve after hyperparameter push: {} iterations in {:.2}s",
        refreshed.iterations,
        t0.elapsed().as_secs_f64()
    );
    assert!(
        proc.stats().restarts == 0,
        "workers crashed during the run ({} restarts)",
        proc.stats().restarts
    );
    println!("million OK — {n} rows, {workers} worker processes, K never materialised");
}
