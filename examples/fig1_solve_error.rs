//! **Figure 1**: relative solve error `‖K̂u − y‖/‖y‖` of mBCG vs Cholesky,
//! in single and double precision, across dataset sizes.
//!
//! The paper's point: on ill-conditioned exact kernel matrices, f32
//! Cholesky loses accuracy badly while mBCG (run to its tolerance) stays
//! accurate without needing f64 — CG has a regularising effect, Cholesky
//! amplifies rounding in the factorization.
//!
//! Output: results/fig1.{txt,csv}
//!
//! ```bash
//! cargo run --release --example fig1_solve_error [-- --full]
//! ```

use bbmm_gp::bench::Table;
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::linalg::pivoted_cholesky::pivoted_cholesky_dense;
use bbmm_gp::linalg::preconditioner::{PartialCholPrecond, Preconditioner};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Rng;

/// relative residual in f64 arithmetic against the f64 matrix
fn rel_residual(k: &Mat, x: &[f64], y: &[f64]) -> f64 {
    let kx = k.matvec(x);
    let num: f64 = (0..y.len())
        .map(|i| (kx[i] - y[i]) * (kx[i] - y[i]))
        .sum::<f64>()
        .sqrt();
    let den: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    num / den
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end to
    // end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let sizes: &[usize] = if smoke {
        &[250, 500]
    } else if args.flag("full") {
        &[250, 500, 1000, 2000, 3500]
    } else {
        &[250, 500, 1000, 2000]
    };
    let mut table = Table::new(&[
        "n",
        "noise",
        "chol_f32",
        "chol_jitter",
        "chol_f64",
        "mbcg_f32",
        "mbcg_f64",
        "mbcg_iters",
    ]);
    println!("Figure 1 — solve error, mBCG vs Cholesky (f32/f64)\n");
    for &noise in &[1e-2f64, 1e-4] {
        for &n in sizes {
            // ill-conditioned exact RBF kernel: modest lengthscale, small noise
            let mut rng = Rng::new(n as u64);
            let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
            let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.6, 1.0)), noise);
            let k64 = op.dense();
            let y64 = rng.normal_vec(n);
            let k32: Mat<f32> = k64.cast();
            let y32: Vec<f32> = y64.iter().map(|&v| v as f32).collect();

            // Cholesky solves
            let chol64 = Cholesky::new_with_jitter(&k64).unwrap();
            let x_chol64 = chol64.solve_vec(&y64);
            let err_chol64 = rel_residual(&k64, &x_chol64, &y64);
            // the paper's §6 point: f32 Cholesky may only factor after adding
            // "jitter" to the diagonal — which silently changes the system.
            // We record the jitter and measure the residual against the TRUE
            // (unjittered, f64) matrix.
            let (err_chol32, chol_jitter) = match Cholesky::new_with_jitter(&k32) {
                Ok(ch) => {
                    let x32 = ch.solve_vec(&y32);
                    let x32_64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
                    (rel_residual(&k64, &x32_64, &y64), ch.jitter)
                }
                Err(_) => (f64::NAN, f64::NAN), // f32 factorization failed outright
            };

            // mBCG solves with the paper's rank-5 pivoted-Cholesky
            // preconditioner ("we recommend always using" it, §6)
            let mut k_noiseless = k64.clone();
            k_noiseless.add_diag(-noise);
            let pc = pivoted_cholesky_dense(&k_noiseless, args.usize_or("rank", 20).unwrap(), 0.0);
            let pre64 = PartialCholPrecond::new(pc.l.clone(), noise);
            let opts64 = MbcgOptions {
                max_iters: n,
                tol: 1e-12,
                n_solve_only: 1,
            };
            let res64 = mbcg(
                |m| k64.matmul(m),
                &Mat::col_from_slice(&y64),
                |m| pre64.solve_mat(m),
                &opts64,
            );
            let err_mbcg64 = rel_residual(&k64, &res64.solves.col(0), &y64);

            let opts32 = MbcgOptions {
                max_iters: n,
                tol: 1e-7,
                n_solve_only: 1,
            };
            let res32 = mbcg(
                |m: &Mat<f32>| k32.matmul(m),
                &Mat::col_from_slice(&y32),
                |m: &Mat<f32>| pre64.solve_mat(&m.cast()).cast(),
                &opts32,
            );
            let x32_64: Vec<f64> = res32.solves.col(0).iter().map(|&v| v as f64).collect();
            let err_mbcg32 = rel_residual(&k64, &x32_64, &y64);

            table.row(&[
                n.to_string(),
                format!("{noise:.0e}"),
                format!("{err_chol32:.3e}"),
                format!("{chol_jitter:.1e}"),
                format!("{err_chol64:.3e}"),
                format!("{err_mbcg32:.3e}"),
                format!("{err_mbcg64:.3e}"),
                res32.iterations.to_string(),
            ]);
            let _ = op.noise();
        }
    }
    table.print();
    table.save("fig1").expect("save results");
    println!(
        "\npaper shape check: mBCG solves without jitter at every conditioning; \
         f32 Cholesky residual grows with n and may require jitter (a silently \
         perturbed system) — see EXPERIMENTS.md F1 for discussion"
    );
}
