//! **LOVE posterior cache**: constant-time predictive variances and
//! correlated posterior sampling from cached factors.
//!
//! Trains an exact GP, then answers the same predictive queries two ways:
//!
//! 1. the solve path — every `predict` pays a fresh dispatched mBCG solve
//! 2. the LOVE path — the posterior is frozen once (`α = K̂⁻¹y` plus a
//!    rank-r Lanczos root of `K̂⁻¹`) and every query afterwards is two
//!    skinny GEMMs, O(n·r) per test point
//!
//! The two paths must agree to tight tolerance (rank 64 covers the RBF
//! spectrum here); the LOVE path is then orders of magnitude faster per
//! query and additionally supports `sample_posterior` — correlated draws
//! across the whole test block from the cached root, no fresh solve.
//!
//! ```bash
//! cargo run --release --example love [-- --n 2000 --rank 64 --queries 200]
//! ```

use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::mll::BbmmEngine;
use bbmm_gp::gp::{Engine, ExactGp};
use bbmm_gp::kernels::Rbf;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::{Rng, Timer};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let n = args.usize_or("n", if smoke { 300 } else { 2_000 }).unwrap();
    let rank = args.usize_or("rank", 64).unwrap();
    let queries = args.usize_or("queries", if smoke { 20 } else { 200 }).unwrap();

    let ds = generate_sized("love_demo", n, 3, 11);
    println!("exact GP on n={} d={} — LOVE rank {rank}\n", ds.n_train(), ds.dim());

    let mut rng = Rng::new(1);
    let xs = Mat::from_fn(queries, ds.dim(), |_, _| rng.uniform_in(-1.0, 1.0));

    // ---- solve path: every predict call pays a dispatched solve --------
    let mut gp = ExactGp::new(
        ds.x_train.clone(),
        ds.y_train.clone(),
        Box::new(Rbf::new(0.5, 1.0)),
        0.05,
        Engine::Bbmm(BbmmEngine::default()),
    );
    let timer = Timer::start();
    let solve_pred = gp.predict(&xs);
    let solve_s = timer.elapsed_s();
    println!("solve path: {queries} queries in {solve_s:.3}s (one mBCG solve per block)");

    // ---- LOVE path: freeze once, then O(n·r) per query -----------------
    gp.set_love_rank(Some(rank));
    let timer = Timer::start();
    let warm = gp.predict(&xs); // first call builds the cached posterior
    let build_s = timer.elapsed_s();
    let timer = Timer::start();
    let love_pred = gp.predict(&xs); // every later call answers from cache
    let love_s = timer.elapsed_s();
    println!("LOVE path:  build+first block {build_s:.3}s, cached block {love_s:.4}s");
    println!("posterior cache: {}", gp.posterior_cache().stats());

    // the two paths answer the same question — report the worst gap
    let mut dmean = 0.0f64;
    let mut dvar = 0.0f64;
    for j in 0..queries {
        dmean = dmean.max((love_pred.mean[j] - solve_pred.mean[j]).abs());
        dvar = dvar.max((love_pred.var[j] - solve_pred.var[j]).abs());
        assert!((warm.mean[j] - love_pred.mean[j]).abs() < 1e-12, "cache must be deterministic");
    }
    println!("max |Δmean| = {dmean:.2e}, max |Δvar| = {dvar:.2e} (rank {rank} vs solve path)\n");

    // ---- correlated posterior draws from the cached root ---------------
    let n_draws = 6;
    let show = queries.min(5);
    let draws = gp.sample_posterior(&xs, n_draws, 42);
    println!("{n_draws} correlated posterior draws at the first {show} test points:");
    for i in 0..show {
        let row: Vec<String> = (0..n_draws).map(|j| format!("{:+.3}", draws.get(i, j))).collect();
        println!(
            "  x[{i}]: mean {:+.3} ± {:.3} | draws [{}]",
            love_pred.mean[i],
            love_pred.var[i].sqrt(),
            row.join(", ")
        );
    }
    println!(
        "\nper-query cost: solve path O(n·iters·n) vs LOVE O(n·r) — \
         see benches/love_predict.rs for the measured trajectory"
    );
}
