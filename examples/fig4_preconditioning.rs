//! **Figure 4**: the effect of pivoted-Cholesky preconditioning.
//!
//! Top: CG relative residual vs iteration for preconditioner rank
//! k ∈ {0, 2, 5, 9}, on deep-RBF (protein) and deep-Matérn-5/2 (kegg)
//! kernels with *learned* hyperparameters (we first run a short training
//! pass, as the paper does).
//!
//! Bottom: test MAE as a function of prediction wall-clock (varied through
//! the CG iteration budget), rank 0 vs rank 5 — showing the rank-5
//! preconditioner buys accuracy at ~zero time cost.
//!
//! Output: results/fig4_residuals_<dataset>.{txt,csv},
//!         results/fig4_mae_tradeoff_<dataset>.{txt,csv}
//!
//! ```bash
//! cargo run --release --example fig4_preconditioning [-- --n 2000 --full]
//! ```

use bbmm_gp::bench::Table;
use bbmm_gp::data::synthetic::{generate_sized, Dataset};
use bbmm_gp::gp::mll::{BbmmEngine, InferenceEngine};
use bbmm_gp::gp::predict::mae;
use bbmm_gp::kernels::{DeepFeatureMap, DenseKernelOp, Kernel, Matern52, Rbf};
use bbmm_gp::linalg::cg::pcg;
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::linalg::preconditioner::Preconditioner;
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{TrainConfig, Trainer};
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::{Rng, Timer};

/// deep-kernel feature expansion: random MLP d→32→8→1, then base kernel
/// (the 1-D feature head used by the paper's SKI+DKL configuration).
/// Features are z-scored (train statistics) so the base kernel's
/// lengthscale is on a meaningful scale — as a trained DKL would produce.
fn deep_features(ds: &Dataset, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    let map = DeepFeatureMap::new(&[ds.dim(), 32, 8, 1], &mut rng);
    let mut tr = map.forward(&ds.x_train);
    let mut te = map.forward(&ds.x_test);
    for c in 0..tr.cols() {
        let n = tr.rows();
        let mean: f64 = (0..n).map(|r| tr.get(r, c)).sum::<f64>() / n as f64;
        let var: f64 = (0..n).map(|r| (tr.get(r, c) - mean).powi(2)).sum::<f64>() / n as f64;
        let sd = var.sqrt().max(1e-9);
        for r in 0..n {
            tr.set(r, c, (tr.get(r, c) - mean) / sd);
        }
        for r in 0..te.rows() {
            te.set(r, c, (te.get(r, c) - mean) / sd);
        }
    }
    (tr, te)
}

fn learn_hypers(
    feat: &Mat,
    y: &[f64],
    kernel: Box<dyn Kernel>,
    iters: usize,
) -> DenseKernelOp {
    let mut op = DenseKernelOp::new(feat.clone(), kernel, 0.05);
    let mut params = op.params();
    let mut engine = BbmmEngine::new(20, 10, 5, 11);
    let mut trainer = Trainer::new(TrainConfig {
        iters,
        lr: 0.1,
        ..Default::default()
    });
    let yv = y.to_vec();
    trainer.run(&mut params, |raw| {
        op.set_params(raw);
        engine.mll_and_grad(&op, &yv)
    });
    op.set_params(&params);
    op
}

fn build_precond(op: &DenseKernelOp, rank: usize) -> Box<dyn Preconditioner> {
    // generic §4.1 builder: pivoted Cholesky over the composition's
    // noise-free part (via noise_split), Woodbury'd against σ²
    bbmm_gp::linalg::op::build_preconditioner(op, rank)
}

fn residual_curves(name: &str, op: &DenseKernelOp, y: &[f64], max_iters: usize) {
    println!("\n--- Figure 4 top: CG residual vs iteration ({name}) ---\n");
    let checkpoints: Vec<usize> = (1..=max_iters).collect();
    let mut curves: Vec<(usize, Vec<f64>)> = Vec::new();
    for &rank in &[0usize, 2, 5, 9] {
        let pre = build_precond(op, rank);
        let res = pcg(
            |v| {
                let m = Mat::col_from_slice(v);
                op.matmul(&m).col(0)
            },
            y,
            |r| pre.solve_vec(r),
            max_iters,
            0.0,
        );
        curves.push((rank, res.residual_history));
    }
    let mut table = Table::new(&["iter", "rank0", "rank2", "rank5", "rank9"]);
    for (i, &it) in checkpoints.iter().enumerate() {
        if it % 5 != 0 && it != 1 && it < max_iters {
            continue; // thin the printed rows; csv keeps them via save below
        }
        let cell = |c: &Vec<f64>| {
            c.get(i)
                .map(|v| format!("{v:.3e}"))
                .unwrap_or_else(|| "conv".to_string())
        };
        table.row(&[
            it.to_string(),
            cell(&curves[0].1),
            cell(&curves[1].1),
            cell(&curves[2].1),
            cell(&curves[3].1),
        ]);
    }
    table.print();
    table.save(&format!("fig4_residuals_{name}")).unwrap();
    // shape check: higher rank converges in fewer iterations to 1e-6
    let iters_to = |hist: &Vec<f64>| {
        hist.iter()
            .position(|&r| r < 1e-6)
            .map(|i| i + 1)
            .unwrap_or(max_iters + 1)
    };
    println!(
        "iters to 1e-6: rank0={} rank2={} rank5={} rank9={}",
        iters_to(&curves[0].1),
        iters_to(&curves[1].1),
        iters_to(&curves[2].1),
        iters_to(&curves[3].1)
    );
}

fn mae_tradeoff(name: &str, op: &DenseKernelOp, ds: &Dataset, feat_test: &Mat) {
    println!("\n--- Figure 4 bottom: test MAE vs prediction wall-clock ({name}) ---\n");
    let y = &ds.y_train;
    let k_star = op.cross(feat_test, op.x());
    let mut table = Table::new(&["cg_iters", "rank", "time_s", "mae"]);
    for &rank in &[0usize, 5] {
        let pre = build_precond(op, rank);
        for &p in &[2usize, 4, 8, 12, 16, 24] {
            let timer = Timer::start();
            let res = mbcg(
                |m| op.matmul(m),
                &Mat::col_from_slice(y),
                |m| pre.solve_mat(m),
                &MbcgOptions {
                    max_iters: p,
                    tol: 0.0,
                    n_solve_only: 1,
                },
            );
            let alpha = res.solves.col(0);
            let mean: Vec<f64> = (0..k_star.rows())
                .map(|i| {
                    k_star
                        .row(i)
                        .iter()
                        .zip(alpha.iter())
                        .map(|(a, b)| a * b)
                        .sum()
                })
                .collect();
            let t = timer.elapsed_s();
            table.row(&[
                p.to_string(),
                rank.to_string(),
                format!("{t:.4}"),
                format!("{:.4}", mae(&mean, &ds.y_test)),
            ]);
        }
    }
    table.print();
    table.save(&format!("fig4_mae_tradeoff_{name}")).unwrap();
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let default_n = if args.flag("full") {
        4000
    } else if smoke {
        400
    } else {
        1500
    };
    let n = args.usize_or("n", default_n).unwrap();
    let train_iters = args.usize_or("iters", if smoke { 4 } else { 15 }).unwrap();
    let max_cg = args.usize_or("max-cg", if smoke { 30 } else { 80 }).unwrap();

    // NOTE on hyperparameters: the paper trains the full deep kernel
    // (MLP + GP hypers) before measuring convergence. Our feature
    // extractor is a *random* MLP (DESIGN.md §5 substitution), and
    // maximising the mll against uninformative features drives the
    // lengthscale toward zero — a flat-spectrum regime where no low-rank
    // preconditioner (including the paper's) can help. The residual
    // curves therefore use representative trained-model hyperparameters
    // (ℓ = 0.4, s = 1, σ² = 5·10⁻³ on standardised features — the regime
    // trained DKL models land in); the MAE-vs-time comparison uses the
    // actually-trained hypers end to end.
    let fixed_rbf = [0.4f64.ln(), 0.0, 5e-3f64.ln()];
    // Matérn-5/2 has polynomial (not exponential) spectral decay, so the
    // representative trained regime sits at a longer lengthscale
    let fixed_matern = [1.2f64.ln(), 0.0, 5e-3f64.ln()];

    // protein with a deep RBF kernel (paper's left column)
    {
        let ds = generate_sized("protein", n, 9, 1);
        let (feat_train, feat_test) = deep_features(&ds, 21);
        let mut curve_op =
            DenseKernelOp::new(feat_train.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
        curve_op.set_params(&fixed_rbf);
        residual_curves("protein_deep_rbf", &curve_op, &ds.y_train, max_cg);
        let op = learn_hypers(&feat_train, &ds.y_train, Box::new(Rbf::new(0.5, 1.0)), train_iters);
        mae_tradeoff("protein_deep_rbf", &op, &ds, &feat_test);
    }
    // kegg with a deep Matérn-5/2 kernel (paper's right column)
    {
        let ds = generate_sized("kegg", n, 20, 2);
        let (feat_train, feat_test) = deep_features(&ds, 22);
        let mut curve_op =
            DenseKernelOp::new(feat_train.clone(), Box::new(Matern52::new(0.5, 1.0)), 0.05);
        curve_op.set_params(&fixed_matern);
        residual_curves("kegg_deep_matern52", &curve_op, &ds.y_train, max_cg);
        let op = learn_hypers(
            &feat_train,
            &ds.y_train,
            Box::new(Matern52::new(0.5, 1.0)),
            train_iters,
        );
        mae_tradeoff("kegg_deep_matern52", &op, &ds, &feat_test);
    }
    println!("\npaper shape check: rank↑ ⇒ residual↓ at fixed iters; rank5 MAE ≤ rank0 at equal time");
}
