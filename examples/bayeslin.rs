//! Bayesian linear regression through the blackbox interface (paper §5):
//! `K̂ = v·XXᵀ + σ²I` with mat-muls distributed as `v·X(XᵀM) + σ²M`, so
//! BBMM runs in O(ptnd) — the complexity of purpose-built Bayesian linear
//! regression solvers, recovered "with no additional derivation".
//!
//! The demo fits weights on a synthetic linear task via the GP posterior,
//! compares against the closed-form ridge solution, and times BBMM's
//! operator against a dense O(n²) kernel mat-mul to show the O(nd) win.
//!
//! ```bash
//! cargo run --release --example bayeslin [-- --n 20000 --d 20]
//! ```

use bbmm_gp::bench::bench_budget;
use bbmm_gp::kernels::LinearKernelOp;
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let n = args.usize_or("n", if smoke { 2_000 } else { 20_000 }).unwrap();
    let d = args.usize_or("d", 20).unwrap();
    let noise: f64 = 0.05;
    let prior_var = 10.0;

    // synthetic linear task
    let mut rng = Rng::new(1);
    let w_true: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let x = Mat::from_fn(n, d, |_, _| rng.normal());
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let xi = x.row(i);
            xi.iter().zip(w_true.iter()).map(|(a, b)| a * b).sum::<f64>()
                + noise.sqrt() * rng.normal()
        })
        .collect();

    let op = LinearKernelOp::new(x.clone(), prior_var, noise);

    // BBMM solve α = K̂⁻¹y — O(p·t·n·d) through the distributed mat-mul
    let res = mbcg(
        |m| op.matmul(m),
        &Mat::col_from_slice(&y),
        |m| m.clone(),
        &MbcgOptions {
            max_iters: 2 * d + 20, // rank-d + noise system: CG needs ~d+1 iters
            tol: 1e-10,
            n_solve_only: 1,
        },
    );
    println!(
        "mBCG converged in {} iterations (system rank d+… = {})",
        res.iterations,
        d + 1
    );
    let alpha = res.solves.col(0);

    // implied weight posterior mean: w = v·Xᵀα; compare to ridge solution
    let mut w_gp: Vec<f64> = vec![0.0; d];
    for i in 0..n {
        let xi = x.row(i);
        for c in 0..d {
            w_gp[c] += prior_var * xi[c] * alpha[i];
        }
    }
    // ridge: (XᵀX + σ²/v I)⁻¹ Xᵀ y
    let xtx = {
        let mut m = x.t_matmul(&x);
        m.add_diag(noise / prior_var);
        m
    };
    let xty = x.t_matmul(&Mat::col_from_slice(&y)).col(0);
    let w_ridge = Cholesky::new(&xtx).unwrap().solve_vec(&xty);

    let mut max_diff = 0.0f64;
    let mut max_err = 0.0f64;
    for c in 0..d {
        max_diff = max_diff.max((w_gp[c] - w_ridge[c]).abs());
        max_err = max_err.max((w_gp[c] - w_true[c]).abs());
    }
    println!("max |w_bbmm − w_ridge| = {max_diff:.2e}   max |w_bbmm − w_true| = {max_err:.3}");
    assert!(max_diff < 1e-6, "BBMM must recover the ridge solution exactly");
    assert!(max_err < 0.05, "weights should be close to truth");

    // complexity demo: the O(tnd) operator vs an O(tn²) dense mat-mul
    let v = Mat::from_fn(n.min(4000), 8, |_, _| rng.normal());
    let x_small = Mat::from_fn(n.min(4000), d, |_, _| rng.normal());
    let op_small = LinearKernelOp::new(x_small, prior_var, noise);
    let fast = bench_budget("linear operator O(tnd)", 1.0, || {
        let _ = op_small.matmul(&v);
    });
    let dense_k = op_small.dense();
    let slow = bench_budget("dense kernel O(tn²)  ", 1.0, || {
        let _ = dense_k.matmul(&v);
    });
    println!(
        "structured matmul is {:.0}× faster at n={} d={d}",
        slow.median_s() / fast.median_s(),
        n.min(4000)
    );
    println!("bayeslin OK");
}
