//! Multi-restart training demo: a whole hyperparameter sweep trained in
//! lockstep — ONE batched mBCG call per Adam step for every candidate —
//! first as a shared-covariance noise grid (the fused fast path), then as
//! random restarts with per-candidate kernels. Prints per-candidate
//! trajectories, the batched-vs-sequential operator accounting, and the
//! winner's held-out error.
//!
//! ```bash
//! cargo run --release --example sweep [-- --n 400 --restarts 6 --iters 20]
//! ```

use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{BatchBbmmEngine, BbmmEngine};
use bbmm_gp::gp::predict::mae;
use bbmm_gp::kernels::{Kernel, Rbf};
use bbmm_gp::train::{multi_restart_inits, noise_grid_inits, TrainConfig};
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Timer;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let n = args.usize_or("n", if smoke { 150 } else { 400 }).unwrap();
    let restarts = args.usize_or("restarts", if smoke { 2 } else { 6 }).unwrap();
    let iters = args.usize_or("iters", if smoke { 5 } else { 20 }).unwrap();

    let ds = generate_sized("sweep_demo", n, 3, 7);
    println!("dataset: n_train={} d={}", ds.n_train(), ds.dim());
    let kernel = Rbf::new(0.5, 1.0);
    let mut template = Kernel::params(&kernel);
    template.push((0.1f64).ln());
    let config = TrainConfig {
        iters,
        lr: 0.1,
        ..Default::default()
    };

    // ---- 1. shared-covariance noise grid (fused fast path) --------------
    let noises = [0.01, 0.05, 0.2, 0.8];
    println!("\n== noise-grid sweep: {} candidates share one covariance ==", noises.len());
    let inits = noise_grid_inits(&template, &noises);
    let mut engine = BatchBbmmEngine::new(20, 10, 5, 1);
    let timer = Timer::start();
    let report = ExactGp::fit_sweep(
        &ds.x_train,
        &ds.y_train,
        &kernel,
        &inits,
        &mut engine,
        config.clone(),
    );
    println!("swept in {:.2}s", timer.elapsed_s());
    for line in report.summary_lines() {
        println!("{line}");
    }
    println!(
        "last step paid {} operator products (a sequential loop: {})",
        engine.last_stats.batched_products, engine.last_stats.system_iterations
    );

    // ---- 2. random multi-restart sweep (per-candidate kernels) ----------
    println!("\n== multi-restart sweep: {restarts} random inits ==");
    let inits = multi_restart_inits(&template, restarts, 0.8, 7);
    let mut engine = BatchBbmmEngine::new(20, 10, 5, 2);
    let timer = Timer::start();
    let report = ExactGp::fit_sweep(
        &ds.x_train,
        &ds.y_train,
        &kernel,
        &inits,
        &mut engine,
        config,
    );
    println!("swept in {:.2}s", timer.elapsed_s());
    for line in report.summary_lines() {
        println!("{line}");
    }

    // ---- 3. materialise + evaluate the winner ---------------------------
    match ExactGp::from_sweep(
        ds.x_train.clone(),
        ds.y_train.clone(),
        &kernel,
        &report,
        Engine::Bbmm(BbmmEngine::default()),
    ) {
        None => println!("every candidate diverged — no model"),
        Some(mut gp) => {
            let pred = gp.predict(&ds.x_test);
            println!(
                "\nwinner: params {:?} — test MAE {:.4}",
                report.best_params().unwrap(),
                mae(&pred.mean, &ds.y_test)
            );
        }
    }
}
