//! Serving demo: train a small exact GP, expose it through the dynamic
//! batcher + TCP server, then fire a concurrent client load against it and
//! report latency percentiles + throughput — the L3 coordinator exercised
//! end to end.
//!
//! ```bash
//! cargo run --release --example serve [-- --clients 16 --requests 50]
//! ```

use bbmm_gp::coordinator::{serve, BatchPolicy, DynamicBatcher, PredictFn, ServerConfig};
use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::BbmmEngine;
use bbmm_gp::kernels::Rbf;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::{Rng, Timer};
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let n = args.usize_or("n", if smoke { 200 } else { 800 }).unwrap();
    let clients = args.usize_or("clients", if smoke { 8 } else { 16 }).unwrap();
    let reqs_per_client = args.usize_or("requests", if smoke { 10 } else { 50 }).unwrap();

    // ---- train ----------------------------------------------------------
    let ds = generate_sized("serve_demo", n, 4, 3);
    println!("training exact GP on n={} d={}…", ds.n_train(), ds.dim());
    let gp = std::sync::Mutex::new(ExactGp::new(
        ds.x_train.clone(),
        ds.y_train.clone(),
        Box::new(Rbf::new(0.5, 1.0)),
        0.05,
        Engine::Bbmm(BbmmEngine::default()),
    ));
    let dim = ds.dim();

    // ---- serve ----------------------------------------------------------
    let predict: PredictFn = Box::new(move |xs: &Mat| gp.lock().unwrap().predict(xs));
    let batcher = Arc::new(DynamicBatcher::new(
        dim,
        BatchPolicy {
            max_batch: 64,
            max_wait: std::time::Duration::from_millis(3),
            ..BatchPolicy::default()
        },
        predict,
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let config = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        operator: "AddedDiag(KernelCov)".to_string(),
        shard_count: 1,
        stop: Arc::clone(&stop),
    };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server_batcher = Arc::clone(&batcher);
    let server = std::thread::spawn(move || {
        serve(config, server_batcher, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();
    println!("server listening on {addr}");

    // ---- concurrent client load -----------------------------------------
    let timer = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut latencies = Vec::with_capacity(reqs_per_client);
            for _ in 0..reqs_per_client {
                let x: Vec<String> = (0..4)
                    .map(|_| format!("{:.5}", rng.uniform_in(-1.0, 1.0)))
                    .collect();
                let line = x.join(",") + "\n";
                let t = Timer::start();
                conn.write_all(line.as_bytes()).unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                latencies.push(t.elapsed_s());
                assert!(
                    !resp.starts_with("ERR"),
                    "server error: {resp}"
                );
            }
            conn.write_all(b"QUIT\n").ok();
            latencies
        }));
    }
    let mut all: Vec<f64> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let total_s = timer.elapsed_s();
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| all[(p * (all.len() - 1) as f64) as usize] * 1e3;
    println!(
        "\n{} requests from {clients} clients in {total_s:.2}s — {:.0} req/s",
        all.len(),
        all.len() as f64 / total_s
    );
    println!(
        "latency ms: p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        q(0.50),
        q(0.90),
        q(0.99),
        all.last().unwrap() * 1e3
    );
    println!("batcher: {}", batcher.metrics.summary());
    assert!(batcher.metrics.mean_batch_size() > 1.5, "batching must coalesce under load");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    println!("serve demo OK");
}
