//! **End-to-end driver**: train a SKI + deep-kernel GP on a large
//! synthetic workload with the full BBMM stack, logging the NMLL curve,
//! then evaluate test MAE and serving throughput.
//!
//! This is the repo's "real small workload" proof that all layers compose:
//! data generation → deep feature map → SKI operator (sparse W × FFT
//! Toeplitz) → mBCG engine → Adam loop → batched prediction. Default n is
//! 100k (minutes on this testbed); `--full` runs the paper's song-scale
//! n = 515k. The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example train_large_ski [-- --n 100000 --iters 40]
//! ```

use bbmm_gp::bench::Table;
use bbmm_gp::gp::mll::{BbmmEngine, InferenceEngine};
use bbmm_gp::gp::predict::{mae, rmse};
use bbmm_gp::gp::SkiOp;
use bbmm_gp::kernels::{DeepFeatureMap, Rbf};
use bbmm_gp::train::{TrainConfig, Trainer};
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::{Rng, Timer};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let full = args.flag("full");
    let default_n = if full {
        515_345
    } else if smoke {
        5_000
    } else {
        100_000
    };
    let n = args.usize_or("n", default_n).unwrap();
    let d = args.usize_or("d", if full { 90 } else { 8 }).unwrap();
    let grid_m = args.usize_or("inducing", if smoke { 1_000 } else { 10_000 }).unwrap();
    let iters = args.usize_or("iters", if smoke { 5 } else { 40 }).unwrap();

    println!("=== end-to-end SKI+DKL training: n={n} d={d} grid_m={grid_m} ===");
    // Workload: a single-index regression task y = g(wᵀx) + ε — the
    // structure deep-kernel-learning + 1-D SKI is built for (the trained
    // MLP's job in [52] is to learn exactly such a projection; DESIGN.md
    // §5). g = sin(3u) + u/2 gives both nonlinear and linear signal.
    let timer = Timer::start();
    let ds = {
        let mut gen_rng = Rng::new(7);
        let w_true: Vec<f64> = {
            let mut w: Vec<f64> = (0..d).map(|_| gen_rng.normal()).collect();
            let nrm = w.iter().map(|v| v * v).sum::<f64>().sqrt();
            w.iter_mut().for_each(|v| *v /= nrm);
            w
        };
        let mut x = bbmm_gp::tensor::Mat::zeros(n, d);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut u = 0.0;
            for c in 0..d {
                let v = gen_rng.uniform_in(-1.0, 1.0);
                x.set(i, c, v);
                u += v * w_true[c];
            }
            y[i] = (3.0 * u).sin() + 0.5 * u + 0.1 * gen_rng.normal();
        }
        // standardise y, split 90/10 (test capped at 2000 like generate_sized)
        let mean = y.iter().sum::<f64>() / n as f64;
        let sd = (y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64)
            .sqrt()
            .max(1e-12);
        y.iter_mut().for_each(|v| *v = (*v - mean) / sd);
        let n_test = (n / 10).min(2000).max(1);
        let n_train = n - n_test;
        let take = |lo: usize, hi: usize| {
            let mut xm = bbmm_gp::tensor::Mat::zeros(hi - lo, d);
            let mut ym = Vec::with_capacity(hi - lo);
            for (r, i) in (lo..hi).enumerate() {
                xm.row_mut(r).copy_from_slice(x.row(i));
                ym.push(y[i]);
            }
            (xm, ym)
        };
        let (x_train, y_train) = take(0, n_train);
        let (x_test, y_test) = take(n_train, n);
        bbmm_gp::data::Dataset {
            name: "single_index".to_string(),
            x_train,
            y_train,
            x_test,
            y_test,
        }
    };
    println!(
        "data generated in {:.1}s (train {} / test {})",
        timer.elapsed_s(),
        ds.n_train(),
        ds.y_test.len()
    );

    // Deep kernel stand-in (DESIGN.md §5): the paper *trains* the DKL MLP,
    // so its 1-D feature is target-informative. We can't backprop an MLP
    // here, so we emulate a trained extractor: the supervised PLS
    // direction w ∝ Xᵀy (the first thing a trained head learns) blended
    // with a random MLP's nonlinear feature, then standardised.
    let mut rng = Rng::new(13);
    let dkl = DeepFeatureMap::new(&[ds.dim(), 32, 8, 1], &mut rng);
    let mlp_train = dkl.forward(&ds.x_train);
    let mlp_test = dkl.forward(&ds.x_test);
    let d_in = ds.dim();
    let mut w = vec![0.0f64; d_in];
    for i in 0..ds.n_train() {
        let xi = ds.x_train.row(i);
        for c in 0..d_in {
            w[c] += xi[c] * ds.y_train[i];
        }
    }
    let wn = w.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
    w.iter_mut().for_each(|v| *v /= wn);
    let feature = |x: &bbmm_gp::tensor::Mat, mlp: &bbmm_gp::tensor::Mat| -> Vec<f64> {
        (0..x.rows())
            .map(|i| {
                let lin: f64 = x.row(i).iter().zip(w.iter()).map(|(a, b)| a * b).sum();
                lin + 0.25 * mlp.get(i, 0)
            })
            .collect()
    };
    let mut z = feature(&ds.x_train, &mlp_train);
    let mut z_test = feature(&ds.x_test, &mlp_test);
    // standardise on train statistics
    let zm = z.iter().sum::<f64>() / z.len() as f64;
    let zv = z.iter().map(|v| (v - zm) * (v - zm)).sum::<f64>() / z.len() as f64;
    let zs = zv.sqrt().max(1e-12);
    z.iter_mut().for_each(|v| *v = (*v - zm) / zs);
    z_test.iter_mut().for_each(|v| *v = (*v - zm) / zs);

    let mut op = SkiOp::new(z, grid_m, Box::new(Rbf::new(0.3, 1.0)), 0.1);
    let y = ds.y_train.clone();
    let mut params = op.params();
    let mut engine = BbmmEngine::new(20, 10, 0, 17);

    let mut trainer = Trainer::new(TrainConfig {
        iters,
        lr: 0.1,
        verbose: true,
        ..Default::default()
    });
    let t_train = Timer::start();
    let best = trainer.run(&mut params, |raw| {
        op.set_params(raw);
        engine.mll_and_grad(&op, &y)
    });
    let train_s = t_train.elapsed_s();

    // ---- loss curve table (the EXPERIMENTS.md record) -------------------
    let mut curve = Table::new(&["iter", "nmll", "grad_norm", "elapsed_s", "cg_iters"]);
    for rec in &trainer.history {
        curve.row(&[
            rec.iter.to_string(),
            format!("{:.4}", rec.nmll),
            format!("{:.3e}", rec.grad_norm),
            format!("{:.2}", rec.elapsed_s),
            rec.cg_iterations.to_string(),
        ]);
    }
    curve.save("train_large_ski_curve").unwrap();
    let first = trainer.history.first().unwrap().nmll;
    println!(
        "\ntraining: {iters} Adam steps in {train_s:.1}s ({:.2}s/step) — nmll {first:.2} → {best:.2}",
        train_s / iters as f64
    );
    assert!(best < first, "training must reduce nmll");

    // ---- evaluation ------------------------------------------------------
    op.set_params(&params);
    let t_pred = Timer::start();
    let k_star = op.cross(&z_test);
    let solves = bbmm_gp::linalg::mbcg::mbcg(
        |m| bbmm_gp::linalg::op::LinearOp::matmul(&op, m),
        &bbmm_gp::tensor::Mat::col_from_slice(&y),
        |m| m.clone(),
        &bbmm_gp::linalg::mbcg::MbcgOptions {
            max_iters: 100,
            tol: 1e-9,
            n_solve_only: 1,
        },
    )
    .solves;
    let alpha = solves.col(0);
    let mean: Vec<f64> = (0..z_test.len())
        .map(|i| {
            k_star
                .row(i)
                .iter()
                .zip(alpha.iter())
                .map(|(a, b)| a * b)
                .sum()
        })
        .collect();
    let pred_s = t_pred.elapsed_s();
    let test_mae = mae(&mean, &ds.y_test);
    let test_rmse = rmse(&mean, &ds.y_test);
    let mean_baseline = mae(&vec![0.0; ds.y_test.len()], &ds.y_test);
    println!(
        "prediction: {} test points in {pred_s:.2}s ({:.0} pts/s)",
        z_test.len(),
        z_test.len() as f64 / pred_s
    );
    println!("test MAE {test_mae:.4} RMSE {test_rmse:.4} (mean-predictor MAE {mean_baseline:.4})");
    assert!(
        test_mae < 0.9 * mean_baseline,
        "model must beat the mean predictor"
    );

    let mut summary = Table::new(&["metric", "value"]);
    summary.row(&["n_train".into(), ds.n_train().to_string()]);
    summary.row(&["grid_m".into(), grid_m.to_string()]);
    summary.row(&["adam_steps".into(), iters.to_string()]);
    summary.row(&["train_s".into(), format!("{train_s:.1}")]);
    summary.row(&["s_per_step".into(), format!("{:.2}", train_s / iters as f64)]);
    summary.row(&["nmll_first".into(), format!("{first:.2}")]);
    summary.row(&["nmll_best".into(), format!("{best:.2}")]);
    summary.row(&["test_mae".into(), format!("{test_mae:.4}")]);
    summary.row(&["test_rmse".into(), format!("{test_rmse:.4}")]);
    summary.row(&["pred_pts_per_s".into(), format!("{:.0}", z_test.len() as f64 / pred_s)]);
    summary.print();
    summary.save("train_large_ski_summary").unwrap();
    println!("end-to-end SKI training OK");
}
