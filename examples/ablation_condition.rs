//! **Ablation A1 (Lemma 1 / Theorem 1)**: the condition number
//! κ(P̂_k⁻¹ K̂) decays (near-)exponentially with the pivoted-Cholesky rank k
//! on RBF kernel matrices, and CG iterations-to-convergence track it.
//!
//! κ is computed as ‖P̂⁻¹K̂‖₂ · ‖K̂⁻¹P̂‖₂ (the definition in Lemma 1) via
//! power iteration on each operator. Output: results/ablation_condition.*
//!
//! ```bash
//! cargo run --release --example ablation_condition [-- --n 800]
//! ```

use bbmm_gp::bench::Table;
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::linalg::cg::pcg;
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::linalg::pivoted_cholesky::pivoted_cholesky_dense;
use bbmm_gp::linalg::preconditioner::{PartialCholPrecond, Preconditioner};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Rng;

/// ‖A‖₂ of a linear operator via power iteration (A need not be symmetric,
/// but P̂⁻¹K̂ is similar to an SPD matrix so the dominant eigenvalue is real).
fn op_norm(apply: impl Fn(&[f64]) -> Vec<f64>, n: usize, iters: usize, rng: &mut Rng) -> f64 {
    let mut v = rng.normal_vec(n);
    let mut lambda = 0.0;
    for _ in 0..iters {
        let w = apply(&v);
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lambda = norm;
        v = w.iter().map(|x| x / norm).collect();
    }
    // Rayleigh-style refinement
    let w = apply(&v);
    let num: f64 = v.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
    if num > 0.0 {
        num
    } else {
        lambda
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let n = args.usize_or("n", if smoke { 200 } else { 800 }).unwrap();
    let noise = args.f64_or("noise", 1e-3).unwrap();
    let mut rng = Rng::new(3);
    // univariate RBF kernel — the setting of Lemma 1
    let x = Mat::from_fn(n, 1, |_, _| rng.uniform());
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.2, 1.0)), noise);
    let k_noiseless = {
        let mut k = op.dense();
        k.add_diag(-noise);
        k
    };
    let khat = op.dense();
    let khat_chol = Cholesky::new_with_jitter(&khat).unwrap();
    let y = rng.normal_vec(n);

    let mut table = Table::new(&["rank_k", "kappa", "err_trace", "cg_iters_1e-8"]);
    println!("Ablation A1 — κ(P̂⁻¹K̂) and CG iterations vs preconditioner rank (n={n})\n");
    for &rank in &[0usize, 1, 2, 3, 5, 7, 9, 12, 16] {
        let (kappa, err_trace, pre): (f64, f64, Option<PartialCholPrecond>) = if rank == 0 {
            // unpreconditioned: κ(K̂) via power iteration on K̂ and K̂⁻¹
            let lmax = op_norm(|v| khat.matvec(v), n, 60, &mut rng);
            let lmin_inv = op_norm(|v| khat_chol.solve_vec(v), n, 60, &mut rng);
            (lmax * lmin_inv, f64::NAN, None)
        } else {
            let pc = pivoted_cholesky_dense(&k_noiseless, rank, 0.0);
            let err = pc.error_trace;
            let pre = PartialCholPrecond::new(pc.l, noise);
            let a = op_norm(|v| pre.solve_vec(&khat.matvec(v)), n, 60, &mut rng);
            let b = op_norm(
                |v| {
                    // K̂⁻¹ P̂ v = K̂⁻¹ (LLᵀv + σ²v)
                    let pv = phat_apply(&pre, v, noise);
                    khat_chol.solve_vec(&pv)
                },
                n,
                60,
                &mut rng,
            );
            (a * b, err, Some(pre))
        };
        // CG iterations to 1e-8 with this preconditioner
        let iters = {
            let precond = |r: &[f64]| -> Vec<f64> {
                match &pre {
                    None => r.to_vec(),
                    Some(p) => p.solve_vec(r),
                }
            };
            pcg(|v| khat.matvec(v), &y, precond, 4 * n, 1e-8).iterations
        };
        table.row(&[
            rank.to_string(),
            format!("{kappa:.3e}"),
            if err_trace.is_nan() {
                "-".to_string()
            } else {
                format!("{err_trace:.3e}")
            },
            iters.to_string(),
        ]);
    }
    table.print();
    table.save("ablation_condition").unwrap();
    println!("\npaper shape check (Lemma 1): κ and Tr(E) fall ~exponentially in k; CG iters follow");
}

/// apply P̂ = LLᵀ + σ²I
fn phat_apply(pre: &PartialCholPrecond, v: &[f64], sigma2: f64) -> Vec<f64> {
    let l = pre.l();
    let ltv = l.t_matmul(&Mat::col_from_slice(v));
    let llv = l.matmul(&ltv).col(0);
    (0..v.len()).map(|i| llv[i] + sigma2 * v[i]).collect()
}
