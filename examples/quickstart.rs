//! Quickstart: the full three-layer path, end to end.
//!
//! 1. Load the AOT artifacts (`make artifacts`) — JAX/Pallas BBMM graphs
//!    lowered to HLO text at build time.
//! 2. Execute the training-step artifact from Rust via PJRT: one mBCG call
//!    returns solves, CG coefficients, and gradient ingredients.
//! 3. Finish the SLQ log-det in Rust (tridiagonal eigensolve on the α/β
//!    streams), assemble NMLL + gradient, and cross-check everything
//!    against the pure-Rust engines on the same data.
//! 4. Run the serving artifact for batched predictions.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use bbmm_gp::gp::mll::{CholeskyEngine, InferenceEngine};
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::linalg::mbcg::tridiag_from_coeffs;
use bbmm_gp::linalg::tridiag::SymTridiagEig;
use bbmm_gp::runtime::{default_artifact_dir, Runtime, TensorF32};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

const N: usize = 256;
const D: usize = 4;
const T: usize = 8;
const LN_2PI: f64 = 1.8378770664093453;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = default_artifact_dir();
    let mut rt = Runtime::cpu(&dir)?;
    if !rt.backend_available() {
        eprintln!(
            "pjrt backend unavailable (build with `--features pjrt` + a vendored xla crate) \
             — skipping quickstart"
        );
        return Ok(());
    }
    println!("PJRT platform: {}", rt.platform());
    let mll_name = "mll_rbf_n256_d4_t8_p20";
    let predict_name = "predict_rbf_n256_d4_m64";
    if !rt.artifact_exists(mll_name) {
        eprintln!("artifacts missing — run `make artifacts` first (dir: {dir:?})");
        std::process::exit(1);
    }
    rt.load(mll_name)?;
    rt.load(predict_name)?;
    println!("loaded artifacts: {:?}", rt.loaded_names());

    // ---- synthetic training data (f32, fixed artifact shapes) ----------
    let mut rng = Rng::new(42);
    let mut x = vec![0f32; N * D];
    for v in x.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    let mut y = vec![0f32; N];
    for i in 0..N {
        let xi = &x[i * D..(i + 1) * D];
        y[i] = (3.0 * xi[0]).sin() + 0.5 * xi[1] + 0.05 * rng.normal() as f32;
    }
    let mut z = vec![0f32; N * T];
    for v in z.iter_mut() {
        *v = rng.rademacher() as f32;
    }
    let params = [-0.5f32, 0.0, -2.0]; // log ℓ, log s, log σ²

    // ---- 2) execute the training-step artifact -------------------------
    let outs = rt.execute_f32(
        mll_name,
        &[
            TensorF32 {
                data: &x,
                dims: vec![N as i64, D as i64],
            },
            TensorF32 {
                data: &y,
                dims: vec![N as i64],
            },
            TensorF32 {
                data: &z,
                dims: vec![N as i64, T as i64],
            },
            TensorF32 {
                data: &params,
                dims: vec![3],
            },
        ],
    )?;
    let (u0, datafit, alphas, betas, quad, trace) =
        (&outs[0], outs[1][0] as f64, &outs[2], &outs[3], &outs[4], &outs[5]);
    println!("artifact returned {} outputs; datafit = {datafit:.4}", outs.len());

    // ---- 3) Rust-side SLQ post-processing (paper App. B) ---------------
    let p = alphas.len() / T;
    let mut logdet = 0.0;
    for c in 0..T {
        let a: Vec<f64> = (0..p).map(|j| alphas[j * T + c] as f64).collect();
        let b: Vec<f64> = (0..p).map(|j| betas[j * T + c] as f64).collect();
        let eff = a.iter().take_while(|v| v.abs() > 0.0).count();
        if eff == 0 {
            continue;
        }
        let tri = tridiag_from_coeffs(&a[..eff], &b[..eff.saturating_sub(1)]);
        let eig = SymTridiagEig::new(&tri.diag, &tri.offdiag);
        logdet += N as f64 * eig.log_quadrature();
    }
    logdet /= T as f64;
    let nmll = 0.5 * (datafit + logdet + N as f64 * LN_2PI);
    let grad: Vec<f64> = (0..3)
        .map(|j| 0.5 * (-(quad[j] as f64) + trace[j] as f64))
        .collect();
    println!("BBMM (artifact): nmll {nmll:.4}  logdet {logdet:.4}  grad {grad:?}");

    // ---- cross-check against the pure-Rust exact engine -----------------
    let x64 = Mat::from_vec(N, D, x.iter().map(|&v| v as f64).collect());
    let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let op = DenseKernelOp::new(
        x64,
        Box::new(Rbf::new((-0.5f64).exp(), 1.0)),
        (-2.0f64).exp(),
    );
    let exact = CholeskyEngine.mll_and_grad(&op, &y64);
    println!(
        "Cholesky (exact): nmll {:.4}  logdet {:.4}  grad {:?}",
        exact.nmll, exact.logdet, exact.grad
    );
    // tolerances: datafit is deterministic; log-det carries t=8-probe MC
    // noise + p=20 truncation bias (paper defaults), so compare against the
    // log-det's own magnitude
    assert!(
        (datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-3,
        "datafit {datafit} vs {}",
        exact.datafit
    );
    assert!(
        (logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.10,
        "logdet {logdet} vs {}",
        exact.logdet
    );
    for j in 0..3 {
        assert!(
            (grad[j] - exact.grad[j]).abs() < 0.25 * (1.0 + exact.grad[j].abs()),
            "grad[{j}] {} vs {}",
            grad[j],
            exact.grad[j]
        );
    }
    let exact_u0 = exact_solve(&op, &y64);
    let u0_err: f64 = (0..N)
        .map(|i| (u0[i] as f64 - exact_u0[i]).abs())
        .fold(0.0, f64::max);
    println!("max |u0 − K̂⁻¹y| = {u0_err:.2e}");

    // ---- 4) serving artifact: batched predictions ----------------------
    let m = 64usize;
    let mut xs = vec![0f32; m * D];
    for v in xs.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    let pred = rt.execute_f32(
        predict_name,
        &[
            TensorF32 {
                data: &x,
                dims: vec![N as i64, D as i64],
            },
            TensorF32 {
                data: &y,
                dims: vec![N as i64],
            },
            TensorF32 {
                data: &xs,
                dims: vec![m as i64, D as i64],
            },
            TensorF32 {
                data: &params,
                dims: vec![3],
            },
        ],
    )?;
    let (mean, var) = (&pred[0], &pred[1]);
    // sanity: predictions at sensible scale, variances in (0, prior]
    let mae: f32 = (0..m)
        .map(|i| {
            let xi = &xs[i * D..(i + 1) * D];
            let truth = (3.0 * xi[0]).sin() + 0.5 * xi[1];
            (mean[i] - truth).abs()
        })
        .sum::<f32>()
        / m as f32;
    println!("served {m} predictions: MAE vs noiseless truth {mae:.4}");
    assert!(mae < 0.2, "posterior mean off: {mae}");
    assert!(var.iter().all(|&v| (0.0..=1.01).contains(&v)));
    println!("quickstart OK — three layers verified end to end");
    Ok(())
}

fn exact_solve(op: &DenseKernelOp, y: &[f64]) -> Vec<f64> {
    use bbmm_gp::linalg::op::LinearOp;
    let ch = bbmm_gp::linalg::cholesky::Cholesky::new_with_jitter(&op.dense()).unwrap();
    ch.solve_vec(y)
}
