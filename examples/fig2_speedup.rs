//! **Figure 2**: speedup of BBMM over the per-model baseline inference
//! engine, one training iteration (NMLL + gradient) per measurement.
//!
//! - Exact GPs: BBMM vs dense Cholesky (GPFlow-equivalent), paper left.
//! - SGPR: BBMM vs O(nm²) Woodbury-Cholesky SGPR, paper middle.
//! - SKI(+deep kernel): BBMM vs Dong et al. sequential engine, paper right.
//!
//! Absolute numbers are testbed-specific (the paper used a Titan Xp); the
//! *shape* — BBMM wins, and the win grows with n — is the reproduction
//! target. Output: results/fig2_<model>.{txt,csv}
//!
//! ```bash
//! cargo run --release --example fig2_speedup [-- --model exact|sgpr|ski|all --full]
//! ```

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::data::synthetic::{generate, DatasetSpec, UCI_EXACT, UCI_SGPR, UCI_SKI};
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::gp::{DongEngine, SgprCholeskyEngine, SgprOp, SkiOp};
use bbmm_gp::kernels::{DeepFeatureMap, DenseKernelOp, Rbf};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "all").to_string();
    let full = args.flag("full");
    if model == "exact" || model == "all" {
        run_exact(full);
    }
    if model == "sgpr" || model == "all" {
        run_sgpr(full);
    }
    if model == "ski" || model == "all" {
        run_ski(full);
    }
}

/// BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end to end
/// at toy sizes — same code path, seconds not minutes
fn smoke() -> bool {
    std::env::var("BBMM_EXAMPLE_SMOKE").is_ok()
}

/// per-measurement time budget: slashed in smoke mode
fn budget() -> f64 {
    if smoke() { 0.2 } else { 3.0 }
}

/// quick mode caps n so the whole figure regenerates in minutes; --full
/// runs the paper's dataset sizes
fn capped(specs: &[DatasetSpec], cap: usize, full: bool) -> Vec<DatasetSpec> {
    specs
        .iter()
        .map(|s| DatasetSpec {
            name: s.name,
            n: if full { s.n } else { s.n.min(cap) },
            d: s.d,
        })
        .collect()
}

fn run_exact(full: bool) {
    println!("\n=== Figure 2 (left): Exact GPs — BBMM vs Cholesky ===\n");
    let mut table = Table::new(&["dataset", "n", "d", "chol_s", "bbmm_s", "speedup"]);
    for spec in capped(UCI_EXACT, if smoke() { 300 } else { 1200 }, full) {
        let ds = generate(&spec, 0);
        let y = ds.y_train.clone();
        let mut op = DenseKernelOp::new(ds.x_train.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let _ = &mut op;
        let chol = bench_budget(&format!("exact/chol/{}", spec.name), budget(), || {
            let _ = CholeskyEngine.mll_and_grad(&op, &y);
        });
        let mut bbmm_engine = BbmmEngine::default();
        let bbmm = bench_budget(&format!("exact/bbmm/{}", spec.name), budget(), || {
            let _ = bbmm_engine.mll_and_grad(&op, &y);
        });
        table.row(&[
            spec.name.to_string(),
            ds.n_train().to_string(),
            spec.d.to_string(),
            format!("{:.3}", chol.median_s()),
            format!("{:.3}", bbmm.median_s()),
            format!("{:.1}x", chol.median_s() / bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("fig2_exact").unwrap();
}

fn run_sgpr(full: bool) {
    println!("\n=== Figure 2 (middle): SGPR — BBMM vs Woodbury-Cholesky ===\n");
    let m_inducing = if smoke() {
        50
    } else if full {
        300
    } else {
        150
    };
    let mut table = Table::new(&["dataset", "n", "m", "chol_s", "bbmm_s", "speedup"]);
    for spec in capped(UCI_SGPR, if smoke() { 800 } else { 8000 }, full) {
        let ds = generate(&spec, 0);
        let y = ds.y_train.clone();
        let mut rng = Rng::new(1);
        let mut u = Mat::zeros(m_inducing, ds.dim());
        for r in 0..m_inducing {
            let src = rng.below(ds.n_train());
            u.row_mut(r).copy_from_slice(ds.x_train.row(src));
        }
        let op = SgprOp::new(ds.x_train.clone(), u, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let chol = bench_budget(&format!("sgpr/chol/{}", spec.name), budget(), || {
            let _ = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
        });
        // SGPR's SoR system is well-conditioned; the paper's SGPR runs skip
        // the pivoted-Cholesky preconditioner (rank 0)
        let mut engine = BbmmEngine::new(20, 10, 0, 7);
        let bbmm = bench_budget(&format!("sgpr/bbmm/{}", spec.name), budget(), || {
            let _ = engine.mll_and_grad(&op, &y);
        });
        table.row(&[
            spec.name.to_string(),
            ds.n_train().to_string(),
            m_inducing.to_string(),
            format!("{:.3}", chol.median_s()),
            format!("{:.3}", bbmm.median_s()),
            format!("{:.1}x", chol.median_s() / bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("fig2_sgpr").unwrap();
}

fn run_ski(full: bool) {
    println!("\n=== Figure 2 (right): SKI+DKL — BBMM vs Dong et al. ===\n");
    let grid_m = if smoke() {
        500
    } else if full {
        10_000
    } else {
        2_000
    };
    let mut table = Table::new(&["dataset", "n", "grid_m", "dong_s", "bbmm_s", "speedup"]);
    for spec in capped(UCI_SKI, if smoke() { 2_000 } else { 60_000 }, full) {
        let ds = generate(&spec, 0);
        let y = ds.y_train.clone();
        // deep kernel: random MLP features → 1-D grid (paper's SKI+DKL)
        let mut rng = Rng::new(2);
        let dkl = DeepFeatureMap::new(&[ds.dim(), 32, 8, 1], &mut rng);
        let feat = dkl.forward(&ds.x_train);
        let z: Vec<f64> = (0..ds.n_train()).map(|i| feat.get(i, 0)).collect();
        let op = SkiOp::new(z, grid_m, Box::new(Rbf::new(0.3, 1.0)), 0.05);
        let mut dong_engine = DongEngine::new(20, 10, 3);
        let dong = bench_budget(&format!("ski/dong/{}", spec.name), budget(), || {
            let _ = dong_engine.mll_and_grad(&op, &y);
        });
        let mut bbmm_engine = BbmmEngine::new(20, 10, 0, 3);
        let bbmm = bench_budget(&format!("ski/bbmm/{}", spec.name), budget(), || {
            let _ = bbmm_engine.mll_and_grad(&op, &y);
        });
        table.row(&[
            spec.name.to_string(),
            ds.n_train().to_string(),
            grid_m.to_string(),
            format!("{:.3}", dong.median_s()),
            format!("{:.3}", bbmm.median_s()),
            format!("{:.1}x", dong.median_s() / bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("fig2_ski").unwrap();
}
