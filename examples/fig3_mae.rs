//! **Figure 3**: final test MAE, BBMM vs Cholesky-based inference —
//! Exact GPs with RBF and Matérn-5/2 kernels, and SGPR with Matérn-5/2.
//!
//! The claim: BBMM is *at least as accurate* as Cholesky inference, dataset
//! by dataset (parity or small BBMM wins from CG's regularising effect).
//! Output: results/fig3_exact_<kernel>.{txt,csv}, results/fig3_sgpr.{txt,csv}
//!
//! ```bash
//! cargo run --release --example fig3_mae [-- --full --iters 25]
//! ```

use bbmm_gp::bench::Table;
use bbmm_gp::data::synthetic::{generate, DatasetSpec, UCI_EXACT, UCI_SGPR};
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::gp::predict::{mae, predict_mean};
use bbmm_gp::gp::{SgprCholeskyEngine, SgprOp};
use bbmm_gp::kernels::{DenseKernelOp, Kernel, Matern52, Rbf};
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{TrainConfig, Trainer};
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::Rng;

fn kernel_by_name(name: &str) -> Box<dyn Kernel> {
    match name {
        "matern52" => Box::new(Matern52::new(0.5, 1.0)),
        _ => Box::new(Rbf::new(0.5, 1.0)),
    }
}

/// Train an exact GP with the given engine and report test MAE.
fn exact_mae(
    ds: &bbmm_gp::data::Dataset,
    kernel_name: &str,
    use_bbmm: bool,
    iters: usize,
) -> f64 {
    let y = ds.y_train.clone();
    let mut op = DenseKernelOp::new(ds.x_train.clone(), kernel_by_name(kernel_name), 0.1);
    let mut params = op.params();
    let mut engine: Box<dyn InferenceEngine> = if use_bbmm {
        Box::new(BbmmEngine::default())
    } else {
        Box::new(CholeskyEngine)
    };
    let mut trainer = Trainer::new(TrainConfig {
        iters,
        lr: 0.1,
        ..Default::default()
    });
    trainer.run(&mut params, |raw| {
        op.set_params(raw);
        engine.mll_and_grad(&op, &y)
    });
    // evaluate with the matching predictor
    let nk = op.n_params() - 1;
    let mut kernel = kernel_by_name(kernel_name);
    kernel.set_params(&params[..nk]);
    let noise = params[nk].exp();
    let eng = if use_bbmm {
        Engine::Bbmm(BbmmEngine::new(100, 10, 5, 9))
    } else {
        Engine::Cholesky
    };
    let mut gp = ExactGp::new(ds.x_train.clone(), y, kernel, noise, eng);
    let pred = gp.predict(&ds.x_test);
    mae(&pred.mean, &ds.y_test)
}

/// Train SGPR with BBMM or Woodbury-Cholesky; report test MAE.
fn sgpr_mae(ds: &bbmm_gp::data::Dataset, m: usize, use_bbmm: bool, iters: usize) -> f64 {
    let y = ds.y_train.clone();
    let mut rng = Rng::new(4);
    let mut u = Mat::zeros(m, ds.dim());
    for r in 0..m {
        let src = rng.below(ds.n_train());
        u.row_mut(r).copy_from_slice(ds.x_train.row(src));
    }
    let mut op = SgprOp::new(
        ds.x_train.clone(),
        u,
        Box::new(Matern52::new(0.5, 1.0)),
        0.1,
    );
    let mut params = op.params();
    let mut bbmm_engine = BbmmEngine::new(20, 10, 0, 5);
    let mut trainer = Trainer::new(TrainConfig {
        iters,
        lr: 0.1,
        ..Default::default()
    });
    trainer.run(&mut params, |raw| {
        op.set_params(raw);
        if use_bbmm {
            bbmm_engine.mll_and_grad(&op, &y)
        } else {
            SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y)
        }
    });
    op.set_params(&params);
    // predictive mean with the SoR cross-covariance
    let k_star = op.cross_sor(&ds.x_test);
    let mean = predict_mean(
        &k_star,
        |mm| {
            mbcg(
                |v| bbmm_gp::linalg::op::LinearOp::matmul(&op, v),
                mm,
                |r| r.clone(),
                &MbcgOptions {
                    max_iters: 200,
                    tol: 1e-10,
                    n_solve_only: mm.cols(),
                },
            )
            .solves
        },
        &y,
    );
    mae(&mean, &ds.y_test)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // BBMM_EXAMPLE_SMOKE: the CI examples job runs every example end
    // to end at toy sizes — same code path, seconds not minutes
    let smoke = std::env::var("BBMM_EXAMPLE_SMOKE").is_ok();
    let full = args.flag("full");
    let default_iters = if full {
        25
    } else if smoke {
        6
    } else {
        15
    };
    let iters = args.usize_or("iters", default_iters).unwrap();
    let cap_exact = if full {
        usize::MAX
    } else if smoke {
        250
    } else {
        900
    };
    let cap_sgpr = if full {
        usize::MAX
    } else if smoke {
        800
    } else {
        5000
    };
    let m_inducing = if full {
        300
    } else if smoke {
        40
    } else {
        100
    };

    for kernel_name in ["rbf", "matern52"] {
        println!("\n=== Figure 3: Exact GPs, {kernel_name} kernel ===\n");
        let mut table = Table::new(&["dataset", "n", "mae_chol", "mae_bbmm", "delta"]);
        for spec in UCI_EXACT {
            let spec = DatasetSpec {
                name: spec.name,
                n: spec.n.min(cap_exact),
                d: spec.d,
            };
            let ds = generate(&spec, 0);
            let m_chol = exact_mae(&ds, kernel_name, false, iters);
            let m_bbmm = exact_mae(&ds, kernel_name, true, iters);
            table.row(&[
                spec.name.to_string(),
                ds.n_train().to_string(),
                format!("{m_chol:.4}"),
                format!("{m_bbmm:.4}"),
                format!("{:+.4}", m_bbmm - m_chol),
            ]);
            println!("{}: chol {m_chol:.4} bbmm {m_bbmm:.4}", spec.name);
        }
        table.print();
        table.save(&format!("fig3_exact_{kernel_name}")).unwrap();
    }

    println!("\n=== Figure 3 (right): SGPR, Matérn-5/2 ===\n");
    let mut table = Table::new(&["dataset", "n", "m", "mae_chol", "mae_bbmm", "delta"]);
    for spec in UCI_SGPR {
        let spec = DatasetSpec {
            name: spec.name,
            n: spec.n.min(cap_sgpr),
            d: spec.d,
        };
        let ds = generate(&spec, 0);
        let m_chol = sgpr_mae(&ds, m_inducing, false, iters);
        let m_bbmm = sgpr_mae(&ds, m_inducing, true, iters);
        table.row(&[
            spec.name.to_string(),
            ds.n_train().to_string(),
            m_inducing.to_string(),
            format!("{m_chol:.4}"),
            format!("{m_bbmm:.4}"),
            format!("{:+.4}", m_bbmm - m_chol),
        ]);
        println!("{}: chol {m_chol:.4} bbmm {m_bbmm:.4}", spec.name);
    }
    table.print();
    table.save("fig3_sgpr").unwrap();
    println!("\npaper shape check: mae_bbmm ≤ mae_chol + noise, on every dataset");
}
