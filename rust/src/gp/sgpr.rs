//! SGPR / Subset-of-Regressors operator (paper §5; Titsias [45]):
//!
//! ```text
//! K̂ ≈ K_XU K_UU⁻¹ K_UX + σ²I = A·Aᵀ + σ²I,   A = K_XU·L_uu⁻ᵀ
//! ```
//!
//! written as the composition `AddedDiagOp(LowRankOp(A))`. The blackbox
//! mat-mul distributes as `A(AᵀM) + σ²M` — O(tnm) per call — and, because
//! the composition advertises its low-rank factor, the generic solve
//! dispatcher ([`crate::linalg::op::solve()`]) takes the **direct Woodbury**
//! path for SGPR with no model-specific engine: `SgprCholeskyEngine` below
//! is now only the *full-gradient* O(nm² + m³) baseline, and it is
//! reachable through the generic engine dispatch (it downcasts, and falls
//! back to the dense Cholesky engine for non-SGPR operators instead of
//! panicking).

use crate::gp::mll::BatchBbmmEngine;
use crate::gp::predict::{predict_with_plan, Prediction};
use crate::kernels::Kernel;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::op::{AddedDiagOp, BatchOp, LinearOp, LowRankOp, SolveOptions, SolvePlanCache};
use crate::tensor::Mat;
use crate::train::{SweepReport, SweepTrainer, TrainConfig};

/// SoR kernel operator with inducing points `U (m×d)` — a named wrapper
/// over `AddedDiagOp(LowRankOp(K_XU·L_uu⁻ᵀ))`.
pub struct SgprOp {
    x: Mat,
    u: Mat,
    kernel: Box<dyn Kernel>,
    /// cached K_XU (n×m) for current hyperparameters
    kxu: Mat,
    /// cached Cholesky of K_UU (+ tiny jitter)
    kuu_chol: Cholesky,
    /// the composed operator `A·Aᵀ + σ²I`
    op: AddedDiagOp<LowRankOp>,
}

impl SgprOp {
    /// Build over training inputs, inducing points, and a kernel.
    pub fn new(x: Mat, u: Mat, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        assert!(noise > 0.0);
        assert_eq!(x.cols(), u.cols());
        let (kxu, kuu_chol, a) = Self::build_cache(&x, &u, kernel.as_ref());
        SgprOp {
            x,
            u,
            kernel,
            kxu,
            kuu_chol,
            op: AddedDiagOp::new(LowRankOp::new(a), noise),
        }
    }

    /// Caches: K_XU, chol(K_UU), and the SoR factor `A = K_XU·L_uu⁻ᵀ`
    /// (row i of A is `L_uu⁻¹·k_iU` — n forward solves, O(nm²) once per
    /// hyperparameter update, amortised across every matmul/solve after).
    fn build_cache(x: &Mat, u: &Mat, kernel: &dyn Kernel) -> (Mat, Cholesky, Mat) {
        let n = x.rows();
        let m = u.rows();
        let kxu = Mat::from_fn(n, m, |i, j| kernel.eval(x.row(i), u.row(j)));
        let mut kuu = Mat::from_fn(m, m, |i, j| kernel.eval(u.row(i), u.row(j)));
        kuu.symmetrize();
        // standard inducing-point jitter
        kuu.add_diag(1e-6);
        let kuu_chol = Cholesky::new_with_jitter(&kuu).expect("K_UU not PD");
        let mut a = Mat::zeros(n, m);
        for i in 0..n {
            let ai = kuu_chol.forward_solve(kxu.row(i));
            a.row_mut(i).copy_from_slice(&ai);
        }
        (kxu, kuu_chol, a)
    }

    /// Training inputs.
    pub fn x(&self) -> &Mat {
        &self.x
    }

    /// Inducing points.
    pub fn u(&self) -> &Mat {
        &self.u
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    /// The SoR low-rank factor `A` (n×m, `K_SoR = A·Aᵀ`).
    pub fn sor_factor(&self) -> &Mat {
        self.op.inner().factor()
    }

    /// Raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite raw parameters (rebuilds the factor caches).
    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.kernel.n_params();
        self.kernel.set_params(&raw[..nk]);
        let (kxu, kuu_chol, a) = Self::build_cache(&self.x, &self.u, self.kernel.as_ref());
        self.kxu = kxu;
        self.kuu_chol = kuu_chol;
        self.op = AddedDiagOp::from_raw(LowRankOp::new(a), raw[nk]);
    }

    /// `K_SoR(A, X) = K_AU K_UU⁻¹ K_UX` rows for test points (predictions).
    pub fn cross_sor(&self, a: &Mat) -> Mat {
        let m = self.u.rows();
        let kau = Mat::from_fn(a.rows(), m, |i, j| self.kernel.eval(a.row(i), self.u.row(j)));
        // K_AU · K_UU⁻¹ · K_UX = K_AU · (K_UU⁻¹ K_XUᵀ)
        let solved = self.kuu_chol.solve_mat(&self.kxu.transpose()); // m×n
        kau.matmul(&solved)
    }

    /// gradient matrices for parameter p: (dK_XU, dK_UU)
    fn grad_mats(&self, p: usize) -> (Mat, Mat) {
        let n = self.x.rows();
        let m = self.u.rows();
        let nk = self.kernel.n_params();
        let mut g = vec![0.0; nk];
        let mut dkxu = Mat::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                self.kernel.eval_grad(self.x.row(i), self.u.row(j), &mut g);
                dkxu.set(i, j, g[p]);
            }
        }
        let mut dkuu = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                self.kernel.eval_grad(self.u.row(i), self.u.row(j), &mut g);
                dkuu.set(i, j, g[p]);
            }
        }
        (dkxu, dkuu)
    }
}

/// SGPR as a *predicting model*: the operator plus targets plus a cached
/// solve plan. The Woodbury capacitance factorisation is built on the
/// first predict and reused across calls; a hyperparameter update changes
/// the operator's content fingerprint, so the next predict rebuilds the
/// plan exactly once ([`SolvePlanCache`] invalidation).
pub struct SgprModel {
    op: SgprOp,
    y: Vec<f64>,
    plans: SolvePlanCache,
}

impl SgprModel {
    /// Tie an SGPR operator to its training targets.
    pub fn new(op: SgprOp, y: Vec<f64>) -> Self {
        assert_eq!(op.n(), y.len());
        SgprModel {
            op,
            y,
            plans: SolvePlanCache::new(),
        }
    }

    /// The underlying operator composition.
    pub fn op(&self) -> &SgprOp {
        &self.op
    }

    /// Training targets.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// The model's solve-plan cache (observable counters).
    pub fn plan_cache(&self) -> &SolvePlanCache {
        &self.plans
    }

    /// Overwrite raw parameters (the cached plan self-invalidates through
    /// the operator fingerprint on the next predict).
    pub fn set_params(&mut self, raw: &[f64]) {
        self.op.set_params(raw);
    }

    /// **Batched multi-restart SGPR training**: b candidates over the same
    /// inducing-point set stepped in lockstep — one batched MLL + gradient
    /// evaluation (one `mbcg_batch` call across the b SoR operators) per
    /// Adam step. Candidate parameters are `[kernel params…, log σ²]`.
    ///
    /// Each candidate owns its own [`SgprOp`] (the SoR factor cache is
    /// per-candidate, rebuilt on each parameter update); the batch is the
    /// general elementwise [`BatchOp`], so every candidate keeps SGPR's
    /// exact custom `dmatmul` gradient math while sharing the single
    /// iteration loop and per-candidate early stopping.
    pub fn fit_sweep(
        x: &Mat,
        y: &[f64],
        u: &Mat,
        kernel: &dyn Kernel,
        inits: &[Vec<f64>],
        engine: &mut BatchBbmmEngine,
        config: TrainConfig,
    ) -> SweepReport {
        assert_eq!(x.rows(), y.len());
        let nk = kernel.n_params();
        assert!(!inits.is_empty(), "fit_sweep: empty candidate set");
        for raw in inits {
            assert_eq!(raw.len(), nk + 1, "fit_sweep: candidate must be [kernel…, log σ²]");
        }
        let mut ops: Vec<SgprOp> = inits
            .iter()
            .map(|raw| {
                let mut k = kernel.boxed_clone();
                k.set_params(&raw[..nk]);
                SgprOp::new(x.clone(), u.clone(), k, raw[nk].exp().max(1e-12))
            })
            .collect();
        let mut trainer = SweepTrainer::new(config, inits.to_vec());
        let _best = trainer.run(|active| {
            for (i, raw) in active {
                let op = &mut ops[*i];
                // only the kernel parameters drive the O(n·m²) SoR cache
                // rebuild — skip it when they are unchanged (iteration 0
                // right after the constructor, or a noise-only move) and
                // install the raw noise directly
                if op.kernel.params() != raw[..nk] {
                    op.set_params(raw);
                } else {
                    op.op.set_raw_value(raw[nk]);
                }
            }
            let els: Vec<&dyn LinearOp> =
                active.iter().map(|(i, _)| &ops[*i] as &dyn LinearOp).collect();
            let batch = BatchOp::new(els.clone());
            // solves run batched; gradients stay on SgprOp's custom dmatmul
            engine.mll_and_grad_batch_on(&batch, &els, y)
        });
        trainer.into_report()
    }

    /// Predictive mean+variance at test inputs, through the cached plan
    /// (direct Woodbury for the SGPR composition — no CG at all).
    pub fn predict(&self, xs: &Mat, opts: &SolveOptions) -> Prediction {
        let k_star = self.op.cross_sor(xs);
        let diag: Vec<f64> = (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect();
        let plan = self.plans.get_or_plan("sgpr", &self.op, opts);
        predict_with_plan(&self.op, &k_star, &diag, &self.y, &plan, opts)
    }
}

impl LinearOp for SgprOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.kernel.n_params() + 1
    }

    /// `d(K_SoR)/dθ · M = dK_XU S + K_XU K_UU⁻¹ (dK_UXᵀ M − dK_UU S)` with
    /// `S = K_UU⁻¹ K_UX M`.
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let nk = self.kernel.n_params();
        if param == nk {
            let mut out = m.clone();
            out.scale_assign(self.noise());
            return out;
        }
        let (dkxu, dkuu) = self.grad_mats(param);
        let kux_m = self.kxu.t_matmul(m); // m×t
        let s = self.kuu_chol.solve_mat(&kux_m); // S = K_UU⁻¹ K_UX M
        let term1 = dkxu.matmul(&s); // dK_XU S
        let dkux_m = dkxu.t_matmul(m); // dK_UX M
        let dkuu_s = dkuu.matmul(&s); // dK_UU S
        let inner = dkux_m.sub(&dkuu_s);
        let solved = self.kuu_chol.solve_mat(&inner);
        let term2 = self.kxu.matmul(&solved);
        // plus the symmetric transpose part of dK_XU:
        //   d(K_XU A K_UX) = dK_XU·A·K_UX + K_XU·dA·K_UX + K_XU·A·dK_UX
        // term1 covers the first, term2 covers dA & dK_UX pieces — where
        // dA = −K_UU⁻¹ dK_UU K_UU⁻¹.
        term1.add(&term2)
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> SgprOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let u = Mat::from_fn(m, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        SgprOp::new(x, u, Box::new(Rbf::new(0.5, 1.0)), 0.1)
    }

    #[test]
    fn matmul_matches_dense_sor() {
        let op = setup(40, 8, 1);
        let dense = op.dense();
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(40, 3, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = dense.matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn dense_row_consistency() {
        let op = setup(20, 6, 3);
        let d = op.diag();
        for i in 0..20 {
            let r = op.row(i);
            assert!((r[i] - d[i]).abs() < 1e-10, "row/diag mismatch at {i}");
        }
        // the noise-free part drops σ² everywhere on the diagonal
        let (cov, s2) = op.noise_split().unwrap();
        for i in 0..20 {
            assert!((cov.diag()[i] + s2 - d[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_dispatch_takes_the_woodbury_path() {
        use crate::linalg::op::{solve, solve_strategy, SolveHint, SolveOptions};
        let op = setup(50, 7, 9);
        assert_eq!(solve_strategy(&op), SolveHint::Woodbury);
        let mut rng = Rng::new(10);
        let b = Mat::from_fn(50, 2, |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        let want = Cholesky::new_with_jitter(&op.dense()).unwrap().solve_mat(&b);
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn dmatmul_matches_finite_differences() {
        let mut op = setup(15, 5, 4);
        let mut rng = Rng::new(5);
        let m = Mat::from_fn(15, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..op.n_params() {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 2e-4,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }

    #[test]
    fn sgpr_model_caches_the_woodbury_plan_across_predicts() {
        use crate::linalg::op::SolveOptions;
        let op = setup(80, 10, 11);
        let mut rng = Rng::new(12);
        let y: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let mut model = SgprModel::new(op, y.clone());
        let xs = Mat::from_fn(9, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let opts = SolveOptions::default();
        let p1 = model.predict(&xs, &opts);
        let p2 = model.predict(&xs, &opts);
        assert_eq!(model.plan_cache().misses(), 1);
        assert_eq!(model.plan_cache().hits(), 1);
        // reference: dense Cholesky posterior through the same rhs math
        let kd = model.op().dense();
        let ch = Cholesky::new_with_jitter(&kd).unwrap();
        let k_star = model.op().cross_sor(&xs);
        let diag: Vec<f64> = (0..9)
            .map(|i| model.op().kernel().eval(xs.row(i), xs.row(i)))
            .collect();
        let want = crate::gp::predict::predict(&k_star, &diag, |m| ch.solve_mat(m), &y);
        for j in 0..9 {
            assert!((p1.mean[j] - want.mean[j]).abs() < 1e-7, "mean {j}");
            assert_eq!(p1.mean[j], p2.mean[j]);
        }
        // hyperparameter change invalidates exactly once
        let mut raw = model.op().params();
        raw[0] += 0.25;
        model.set_params(&raw);
        let _ = model.predict(&xs, &opts);
        assert_eq!(model.plan_cache().invalidations(), 1);
    }

    #[test]
    fn sor_approaches_exact_kernel_with_many_inducing_points() {
        // when U = X the SoR matrix equals the exact kernel matrix
        let mut rng = Rng::new(6);
        let x = Mat::from_fn(20, 1, |_, _| rng.uniform());
        let op = SgprOp::new(x.clone(), x.clone(), Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let exact = crate::kernels::DenseKernelOp::new(x, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let diff = op.dense().max_abs_diff(&exact.dense());
        assert!(diff < 1e-3, "diff={diff}"); // jitter on K_UU allows small gap
    }

    #[test]
    fn sgpr_gp_regression_works_end_to_end() {
        // SGPR posterior mean approximates the function — solved through
        // the generic dispatcher (which goes direct Woodbury for SGPR)
        let n = 300;
        let m = 30;
        let mut rng = Rng::new(7);
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| (4.0 * x.get(i, 0)).sin() + 0.05 * rng.normal())
            .collect();
        let u = Mat::from_fn(m, 1, |i, _| -1.0 + 2.0 * (i as f64 + 0.5) / m as f64);
        let op = SgprOp::new(x, u, Box::new(Rbf::new(0.3, 1.0)), 0.05);
        let res = crate::linalg::op::solve(
            &op,
            &Mat::col_from_slice(&y),
            &crate::linalg::op::SolveOptions::default(),
        );
        let xs = Mat::from_fn(50, 1, |i, _| -0.9 + 1.8 * (i as f64) / 49.0);
        let k_star = op.cross_sor(&xs);
        let alpha = res.col(0);
        let mut mae = 0.0;
        for i in 0..50 {
            let mu: f64 = k_star
                .row(i)
                .iter()
                .zip(alpha.iter())
                .map(|(a, b)| a * b)
                .sum();
            mae += (mu - (4.0 * xs.get(i, 0)).sin()).abs();
        }
        mae /= 50.0;
        assert!(mae < 0.1, "mae={mae}");
    }
}

// ---------------------------------------------------------------------------
// Cholesky-based SGPR baseline (GPflow-equivalent, paper Figure 2 middle)
// ---------------------------------------------------------------------------

/// The standard O(nm² + m³) Cholesky-based SGPR inference engine, computed
/// through the Woodbury identity on the m×m "capacitance" system — exactly
/// the linear algebra GPflow's SGPR implementation performs. This is the
/// baseline BBMM's SGPR speedups in Figure 2 (middle) are measured against.
///
/// With `A = L_uu⁻¹ K_UX` and `B = I + σ⁻² A Aᵀ`:
///   log|K̂|  = log|B| + n log σ²
///   K̂⁻¹ v   = σ⁻² (v − Aᵀ B⁻¹ A v)
/// and all gradient traces reduce to O(nm²) contractions against the dense
/// derivative blocks dK_XU / dK_UU.
pub struct SgprCholeskyEngine;

impl crate::gp::mll::InferenceEngine for SgprCholeskyEngine {
    /// Generic-dispatch entry point. Downcasts to the concrete [`SgprOp`]
    /// for the fast Woodbury path; any other operator falls back to the
    /// exact dense Cholesky engine. (The seed version panicked here —
    /// regression-tested by the generic-dispatch test in this file's
    /// `cholesky_baseline_tests` module.)
    fn mll_and_grad(&mut self, op: &dyn LinearOp, y: &[f64]) -> crate::gp::mll::MllGrad {
        if let Some(sgpr) = op.as_any().and_then(|a| a.downcast_ref::<SgprOp>()) {
            return self.mll_and_grad_sgpr(sgpr, y);
        }
        crate::gp::mll::CholeskyEngine.mll_and_grad(op, y)
    }

    fn name(&self) -> &'static str {
        "sgpr-cholesky"
    }
}

impl SgprCholeskyEngine {
    /// Exact SGPR NMLL + gradient in O(nm² + m³).
    pub fn mll_and_grad_sgpr(&self, op: &SgprOp, y: &[f64]) -> crate::gp::mll::MllGrad {
        const LN_2PI: f64 = 1.8378770664093453;
        let n = op.n();
        let m = op.u.rows();
        let sigma2 = op.noise();

        // A = L_uu⁻¹ K_UX (m×n)
        let kux = op.kxu.transpose(); // m×n
        let mut a = Mat::zeros(m, n);
        for c in 0..n {
            let col = op.kuu_chol.forward_solve(&kux.col(c));
            a.set_col(c, &col);
        }
        // B = I + σ⁻² A Aᵀ (m×m)
        let mut b = a.matmul_t(&a);
        b.scale_assign(1.0 / sigma2);
        b.add_diag(1.0);
        b.symmetrize();
        let b_chol = Cholesky::new_with_jitter(&b).expect("B must be PD");

        // α = K̂⁻¹ y = σ⁻²(y − σ⁻² Aᵀ B⁻¹ A y)
        let khat_solve_vec = |v: &[f64]| -> Vec<f64> {
            let av = a.matvec(v);
            let binv_av = b_chol.solve_vec(&av);
            let at_binv_av = a.t_matmul(&Mat::col_from_slice(&binv_av)).col(0);
            (0..n)
                .map(|i| (v[i] - at_binv_av[i] / sigma2) / sigma2)
                .collect()
        };
        let alpha = khat_solve_vec(y);
        let datafit: f64 = y.iter().zip(alpha.iter()).map(|(p, q)| p * q).sum();
        let logdet = b_chol.logdet() + n as f64 * sigma2.ln();
        let nmll = 0.5 * (datafit + logdet + n as f64 * LN_2PI);

        // P = K̂⁻¹ K_XU (n×m), G = P K_UU⁻¹ (n×m), H = K_UU⁻¹ K_UX P K_UU⁻¹
        let mut p_mat = Mat::zeros(n, m);
        for c in 0..m {
            let col = khat_solve_vec(&op.kxu.col(c));
            p_mat.set_col(c, &col);
        }
        let g = {
            // solve K_UU X = Pᵀ column-wise, transpose back
            let pt = p_mat.transpose(); // m×n
            let solved = op.kuu_chol.solve_mat(&pt); // m×n
            solved.transpose() // n×m
        };
        let kux_p = op.kxu.t_matmul(&p_mat); // m×m = K_UX P
        let h = {
            let tmp = op.kuu_chol.solve_mat(&kux_p); // K_UU⁻¹ K_UX P
            let tmp_t = tmp.transpose();
            op.kuu_chol.solve_mat(&tmp_t).transpose() // (… K_UU⁻¹) via symmetry
        };

        // α-side projections for the quadratic terms
        let kux_alpha = op.kxu.t_matmul(&Mat::col_from_slice(&alpha)).col(0); // m
        let w_kux_alpha = op.kuu_chol.solve_vec(&kux_alpha); // m = K_UU⁻¹K_UXα

        let nk = op.kernel.n_params();
        let mut grad = Vec::with_capacity(nk + 1);
        let mut gbuf = vec![0.0; nk];
        for param in 0..nk {
            // dense derivative blocks (the gradient-path cost of the baseline)
            let mut tr = 0.0; // Tr(K̂⁻¹ dK̂)
            let mut quad = 0.0; // αᵀ dK̂ α
            // dK_XU part: 2·Σ G ⊙ dK_XU  and 2·αᵀ dK_XU (K_UU⁻¹K_UXα)
            for i in 0..n {
                for j in 0..m {
                    op.kernel.eval_grad(op.x.row(i), op.u.row(j), &mut gbuf);
                    let d = gbuf[param];
                    tr += 2.0 * g.get(i, j) * d;
                    quad += 2.0 * alpha[i] * d * w_kux_alpha[j];
                }
            }
            // dK_UU part: −Σ H ⊙ dK_UU and −(K_UU⁻¹K_UXα)ᵀ dK_UU (…)
            for i in 0..m {
                for j in 0..m {
                    op.kernel.eval_grad(op.u.row(i), op.u.row(j), &mut gbuf);
                    let d = gbuf[param];
                    tr -= h.get(i, j) * d;
                    quad -= w_kux_alpha[i] * d * w_kux_alpha[j];
                }
            }
            grad.push(0.5 * (-quad + tr));
        }
        // noise parameter: Tr(K̂⁻¹) = σ⁻²(n − m + Tr(B⁻¹))
        // (since AAᵀ = σ²(B − I) ⇒ σ⁻²Tr(B⁻¹AAᵀ) = m − Tr(B⁻¹))
        let binv = b_chol.solve_mat(&Mat::eye(m));
        let tr_binv: f64 = (0..m).map(|i| binv.get(i, i)).sum();
        let tr_kinv = (n as f64 - m as f64 + tr_binv) / sigma2;
        let quad_noise: f64 = sigma2 * alpha.iter().map(|v| v * v).sum::<f64>();
        grad.push(0.5 * (-quad_noise + sigma2 * tr_kinv));

        crate::gp::mll::MllGrad {
            nmll,
            grad,
            iterations: 1,
            logdet,
            datafit,
        }
    }
}

#[cfg(test)]
mod cholesky_baseline_tests {
    use super::*;
    use crate::gp::mll::InferenceEngine;
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> (SgprOp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let u = Mat::from_fn(m, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| (3.0 * x.get(i, 0)).sin() + 0.05 * rng.normal())
            .collect();
        (SgprOp::new(x, u, Box::new(Rbf::new(0.5, 1.0)), 0.1), y)
    }

    #[test]
    fn woodbury_mll_matches_dense_cholesky() {
        let (op, y) = setup(60, 8, 1);
        let fast = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
        let dense = crate::gp::mll::CholeskyEngine.mll_and_grad(&op, &y);
        assert!(
            (fast.nmll - dense.nmll).abs() < 1e-6 * dense.nmll.abs().max(1.0),
            "{} vs {}",
            fast.nmll,
            dense.nmll
        );
        assert!((fast.logdet - dense.logdet).abs() < 1e-6 * dense.logdet.abs().max(1.0));
    }

    #[test]
    fn woodbury_gradient_matches_dense_cholesky() {
        let (op, y) = setup(40, 6, 2);
        let fast = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
        let dense = crate::gp::mll::CholeskyEngine.mll_and_grad(&op, &y);
        for p in 0..op.n_params() {
            assert!(
                (fast.grad[p] - dense.grad[p]).abs() < 1e-5 * (1.0 + dense.grad[p].abs()),
                "param {p}: {} vs {}",
                fast.grad[p],
                dense.grad[p]
            );
        }
    }

    #[test]
    fn woodbury_gradient_matches_finite_differences() {
        let (mut op, y) = setup(35, 5, 3);
        let res = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
        let raw = op.params();
        let h = 1e-5;
        for p in 0..raw.len() {
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y).nmll;
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y).nmll;
            op.set_params(&raw);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (fd - res.grad[p]).abs() < 1e-3 * (1.0 + fd.abs()),
                "param {p}: fd {fd} vs {}",
                res.grad[p]
            );
        }
    }

    #[test]
    fn generic_dispatch_reaches_the_direct_path_and_never_panics() {
        // the previously-panicking call shape: engine invoked through the
        // generic `&dyn LinearOp` surface
        let (op, y) = setup(30, 5, 4);
        let mut engine = SgprCholeskyEngine;
        let via_dyn = {
            let dyn_op: &dyn LinearOp = &op;
            engine.mll_and_grad(dyn_op, &y)
        };
        let direct = engine.mll_and_grad_sgpr(&op, &y);
        assert!((via_dyn.nmll - direct.nmll).abs() < 1e-12);
        // and a non-SGPR operator falls back to the dense engine
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(20, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let dense_op = crate::kernels::DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1);
        let y2: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let fallback = engine.mll_and_grad(&dense_op, &y2);
        let want = crate::gp::mll::CholeskyEngine.mll_and_grad(&dense_op, &y2);
        assert!((fallback.nmll - want.nmll).abs() < 1e-12);
    }
}
