//! SKI / KISS-GP operator (paper §5; Wilson & Nickisch [50]):
//!
//! ```text
//! K̂ ≈ W K_UU Wᵀ + σ²I
//! ```
//!
//! written as the composition `AddedDiagOp(InterpOp(GridToeplitzOp))`: `W`
//! is the sparse local-cubic-convolution interpolation matrix
//! ([`crate::linalg::op::SparseInterp`], 4 non-zeros per row) and `K_UU` a
//! stationary kernel on a **regular 1-D grid** — hence symmetric Toeplitz,
//! giving O(m log m) mat-vecs via [`crate::linalg::ToeplitzOp`]. A
//! blackbox mat-mul is therefore O(t·n + t·m log m), which is what lets
//! the Figure-2(right) experiments run at n = 500,000. The only SKI-
//! specific code left is [`GridToeplitzOp`] (the kernel-parameterised grid
//! covariance, ~60 lines) — interpolation, noise, preconditioning, and
//! solving are all generic algebra.
//!
//! Multi-dimensional inputs enter through a deep feature map ([52]) whose
//! final layer is 1-D — the paper's SKI+DKL configuration.

use crate::kernels::Kernel;
use crate::linalg::op::{AddedDiagOp, InterpOp, LinearOp, SparseInterp, ToeplitzLinOp};
use crate::linalg::toeplitz::ToeplitzOp;
use crate::tensor::Mat;

/// Stationary kernel evaluated on a regular grid: a [`ToeplitzLinOp`]
/// `K_UU` plus one Toeplitz per kernel-parameter derivative, all applied
/// via FFT. This is the inner operator of the SKI sandwich — the Toeplitz
/// read surface (`diag`/`row`/`entry`/`dense`) is wholly delegated; only
/// the kernel parameterisation lives here.
pub struct GridToeplitzOp {
    kernel: Box<dyn Kernel>,
    /// grid spacing
    h: f64,
    m: usize,
    /// cached Toeplitz K_UU
    kuu: ToeplitzLinOp,
    /// cached Toeplitz dK_UU/draw_p per kernel parameter
    dkuu: Vec<ToeplitzOp>,
}

impl GridToeplitzOp {
    /// Build over an `m`-point grid with spacing `h`.
    pub fn new(kernel: Box<dyn Kernel>, h: f64, m: usize) -> Self {
        let (kuu, dkuu) = Self::build_toeplitz(kernel.as_ref(), h, m);
        GridToeplitzOp {
            kernel,
            h,
            m,
            kuu,
            dkuu,
        }
    }

    fn build_toeplitz(kernel: &dyn Kernel, h: f64, m: usize) -> (ToeplitzLinOp, Vec<ToeplitzOp>) {
        let nk = kernel.n_params();
        let mut col = Vec::with_capacity(m);
        let mut dcols: Vec<Vec<f64>> = vec![Vec::with_capacity(m); nk];
        let mut g = vec![0.0; nk];
        let origin = [0.0];
        for i in 0..m {
            let xi = [i as f64 * h];
            col.push(kernel.eval(&origin, &xi));
            kernel.eval_grad(&origin, &xi, &mut g);
            for (p, dc) in dcols.iter_mut().enumerate() {
                dc.push(g[p]);
            }
        }
        (
            ToeplitzLinOp::new(col),
            dcols.into_iter().map(ToeplitzOp::new).collect(),
        )
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    /// First column of the Toeplitz grid covariance.
    pub fn first_column(&self) -> &[f64] {
        self.kuu.toeplitz().first_column()
    }

    /// Overwrite kernel hyperparameters (rebuilds the Toeplitz caches).
    pub fn set_kernel_params(&mut self, raw: &[f64]) {
        self.kernel.set_params(raw);
        let (kuu, dkuu) = Self::build_toeplitz(self.kernel.as_ref(), self.h, self.m);
        self.kuu = kuu;
        self.dkuu = dkuu;
    }
}

impl LinearOp for GridToeplitzOp {
    crate::linear_op_delegate!(kuu);

    fn n_params(&self) -> usize {
        self.kernel.n_params()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.dkuu[param].matmul(m)
    }
}

/// The SKI kernel operator — a named wrapper over
/// `AddedDiagOp(InterpOp(GridToeplitzOp))` plus the 1-D features it was
/// built from (needed for test-time cross-covariances).
pub struct SkiOp {
    /// 1-D features (raw inputs or deep-kernel features), length n
    z: Vec<f64>,
    grid_lo: f64,
    grid_h: f64,
    m: usize,
    op: AddedDiagOp<InterpOp<GridToeplitzOp>>,
}

impl SkiOp {
    /// Build over 1-D features with an `m`-point grid spanning the data
    /// (with a 2-cell margin so every point has a full cubic stencil).
    pub fn new(z: Vec<f64>, m: usize, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        assert!(noise > 0.0);
        let (zmin, zmax) = z
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
                (lo.min(v), hi.max(v))
            });
        let span = (zmax - zmin).max(1e-9);
        let h = span / (m as f64 - 5.0); // leaves ≥2 cells margin each side
        let lo = zmin - 2.0 * h;
        let hi = lo + h * (m - 1) as f64;
        let interp = SparseInterp::new(&z, lo, hi, m);
        let grid = GridToeplitzOp::new(kernel, h, m);
        SkiOp {
            z,
            grid_lo: lo,
            grid_h: h,
            m,
            op: AddedDiagOp::new(InterpOp::new(interp, grid), noise),
        }
    }

    /// Grid descriptor `(lo, spacing, m)`.
    pub fn grid(&self) -> (f64, f64, usize) {
        (self.grid_lo, self.grid_h, self.m)
    }

    /// The 1-D features the operator was built over.
    pub fn features(&self) -> &[f64] {
        &self.z
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.op.inner().inner().kernel()
    }

    /// The interpolation matrix `W`.
    pub fn interp(&self) -> &SparseInterp {
        self.op.inner().interp()
    }

    /// Raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel().params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite raw parameters (rebuilds the grid Toeplitz caches).
    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.kernel().n_params();
        self.op.inner_mut().inner_mut().set_kernel_params(&raw[..nk]);
        self.op.set_raw_value(raw[nk]);
    }

    /// SKI cross-covariance rows for *test* features: `W* K_UU Wᵀ`.
    pub fn cross(&self, z_test: &[f64]) -> Mat {
        let hi = self.grid_lo + self.grid_h * (self.m - 1) as f64;
        let w_star = SparseInterp::new(z_test, self.grid_lo, hi, self.m);
        // (n*×m) · T · (m×n): build T Wᵀ column block implicitly — for each
        // test row, u = T w*, then dot against training stencils.
        let n = self.z.len();
        let interp = self.interp();
        let mut out = Mat::zeros(z_test.len(), n);
        for i in 0..z_test.len() {
            let (ids, ws) = w_star.row_stencil(i);
            let u = self.toeplitz_times_sparse(ids, ws);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                let (jds, jws) = interp.row_stencil(j);
                let mut s = 0.0;
                for b in 0..4 {
                    s += jws[b] * u[jds[b]];
                }
                *o = s;
            }
        }
        out
    }

    /// `u = T w` where w is 4-sparse: u[r] = Σ_a w_a c[|r − j_a|] — O(4m).
    fn toeplitz_times_sparse(&self, ids: &[usize; 4], ws: &[f64; 4]) -> Vec<f64> {
        let col = self.op.inner().inner().first_column();
        let mut u = vec![0.0; self.m];
        for a in 0..4 {
            let ja = ids[a];
            let wa = ws[a];
            for (r, uv) in u.iter_mut().enumerate() {
                *uv += wa * col[r.abs_diff(ja)];
            }
        }
        u
    }
}

impl LinearOp for SkiOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.op.n_params()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.op.dmatmul(param, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> SkiOp {
        let mut rng = Rng::new(seed);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        SkiOp::new(z, m, Box::new(Rbf::new(0.3, 1.0)), 0.1)
    }

    #[test]
    fn interpolation_weights_sum_to_one() {
        let op = setup(200, 50, 1);
        for i in 0..200 {
            let (_ids, ws) = op.interp().row_stencil(i);
            let s: f64 = ws.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "row {i}: {s}");
        }
    }

    #[test]
    fn matmul_matches_dense_ski_matrix() {
        let op = setup(60, 32, 2);
        let dense = op.dense();
        let mut rng = Rng::new(3);
        let m = Mat::from_fn(60, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = dense.matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn row_and_diag_consistent_with_dense() {
        let op = setup(40, 24, 4);
        let d = op.diag();
        for i in [0usize, 7, 39] {
            let r = op.row(i);
            assert!((r[i] - d[i]).abs() < 1e-10);
        }
        // diagonal includes σ²; the noise-free part is the sandwich alone
        let (cov, s2) = op.noise_split().unwrap();
        for i in [0usize, 7, 39] {
            assert!((cov.diag()[i] + s2 - d[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn ski_approximates_exact_kernel() {
        // dense SKI matrix ≈ exact RBF kernel matrix when the grid is fine
        let n = 50;
        let mut rng = Rng::new(5);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let op = SkiOp::new(z.clone(), 400, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let x = Mat::from_vec(n, 1, z);
        let exact = DenseKernelOp::new(x, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let diff = op.dense().max_abs_diff(&exact.dense());
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn dmatmul_matches_finite_differences() {
        let mut op = setup(30, 40, 6);
        let mut rng = Rng::new(7);
        let m = Mat::from_fn(30, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..op.n_params() {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 1e-4,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }

    #[test]
    fn cross_covariance_matches_dense_for_training_points() {
        let op = setup(25, 30, 8);
        let z = op.features().to_vec();
        let cross = op.cross(&z);
        // cross at training features == noise-free K rows (the sandwich
        // part of the composition)
        let (cov, _s2) = op.noise_split().unwrap();
        for i in [0usize, 10, 24] {
            let r = cov.row(i);
            for j in 0..25 {
                assert!((cross.get(i, j) - r[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn large_n_matmul_is_linear_time() {
        // smoke test: n = 100k SKI matmul with t=8 well under a second
        let n = 100_000;
        let mut rng = Rng::new(9);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let op = SkiOp::new(z, 2000, Box::new(Rbf::new(0.1, 1.0)), 0.1);
        let m = Mat::from_fn(n, 8, |_, _| rng.normal());
        let t = crate::util::Timer::start();
        let out = op.matmul(&m);
        assert!(out.data().iter().all(|v| v.is_finite()));
        assert!(t.elapsed_s() < 2.0, "took {}", t.elapsed_s());
    }
}
