//! SKI / KISS-GP operator (paper §5; Wilson & Nickisch [50]):
//!
//! ```text
//! K̂ ≈ W K_UU Wᵀ + σ²I
//! ```
//!
//! with `W` a sparse local-cubic-convolution interpolation matrix (4
//! non-zeros per row) and `K_UU` a stationary kernel on a **regular 1-D
//! grid** — hence symmetric Toeplitz, giving O(m log m) mat-vecs via
//! [`crate::linalg::ToeplitzOp`]. A blackbox mat-mul is therefore
//! O(t·n + t·m log m), which is what lets the Figure-2(right) experiments
//! run at n = 500,000.
//!
//! Multi-dimensional inputs enter through a deep feature map ([52]) whose
//! final layer is 1-D — the paper's SKI+DKL configuration.

use crate::kernels::{Kernel, KernelOperator};
use crate::linalg::toeplitz::ToeplitzOp;
use crate::tensor::Mat;
use crate::util::par;

/// Keys cubic-convolution interpolation kernel (a = −1/2).
#[inline]
fn cubic_weight(s: f64) -> f64 {
    let s = s.abs();
    if s < 1.0 {
        (1.5 * s - 2.5) * s * s + 1.0
    } else if s < 2.0 {
        ((-0.5 * s + 2.5) * s - 4.0) * s + 2.0
    } else {
        0.0
    }
}

/// Sparse interpolation matrix: 4 non-zeros per row.
pub struct SparseInterp {
    /// grid indices per row (4 each)
    idx: Vec<[usize; 4]>,
    /// interpolation weights per row (4 each, summing to 1)
    w: Vec<[f64; 4]>,
    m: usize,
}

impl SparseInterp {
    /// Build cubic interpolation weights for points `z` (1-D features) onto
    /// a regular grid `[lo, hi]` with `m` nodes. Points are clamped to the
    /// interpolable interior.
    pub fn new(z: &[f64], lo: f64, hi: f64, m: usize) -> Self {
        assert!(m >= 4, "need at least 4 grid points for cubic interpolation");
        assert!(hi > lo);
        let h = (hi - lo) / (m - 1) as f64;
        let mut idx = Vec::with_capacity(z.len());
        let mut w = Vec::with_capacity(z.len());
        for &zi in z {
            // position in grid units, clamped so the 4-point stencil fits
            let p = ((zi - lo) / h).clamp(1.0, (m - 3) as f64 + 0.999_999);
            let j0 = p.floor() as usize;
            let u = p - j0 as f64;
            let ids = [j0 - 1, j0, j0 + 1, j0 + 2];
            let ws = [
                cubic_weight(1.0 + u),
                cubic_weight(u),
                cubic_weight(1.0 - u),
                cubic_weight(2.0 - u),
            ];
            idx.push(ids);
            w.push(ws);
        }
        SparseInterp { idx, w, m }
    }

    pub fn n(&self) -> usize {
        self.idx.len()
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// `W · M` — (n×m)·(m×t) in O(4·n·t).
    pub fn apply(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows(), self.m);
        let t = m.cols();
        let n = self.n();
        let mut out = Mat::zeros(n, t);
        let idx = &self.idx;
        let w = &self.w;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            for (ri, orow) in chunk.chunks_mut(t).enumerate() {
                let r = row_lo + ri;
                for a in 0..4 {
                    let wa = w[r][a];
                    let mrow = m.row(idx[r][a]);
                    for c in 0..t {
                        orow[c] += wa * mrow[c];
                    }
                }
            }
        });
        out
    }

    /// `Wᵀ · M` — (m×n)·(n×t) in O(4·n·t).
    pub fn apply_t(&self, mat: &Mat) -> Mat {
        assert_eq!(mat.rows(), self.n());
        let t = mat.cols();
        let mut out = Mat::zeros(self.m, t);
        // scatter-add; serial over n (t is small) — could shard by target
        for r in 0..self.n() {
            let mrow = mat.row(r);
            for a in 0..4 {
                let target = self.idx[r][a];
                let wa = self.w[r][a];
                let orow = out.row_mut(target);
                for c in 0..t {
                    orow[c] += wa * mrow[c];
                }
            }
        }
        out
    }

    /// weights/indices of row i (for O(1)-ish row access)
    pub fn row_stencil(&self, i: usize) -> (&[usize; 4], &[f64; 4]) {
        (&self.idx[i], &self.w[i])
    }
}

/// The SKI kernel operator.
pub struct SkiOp {
    /// 1-D features (raw inputs or deep-kernel features), length n
    z: Vec<f64>,
    interp: SparseInterp,
    kernel: Box<dyn Kernel>,
    raw_noise: f64,
    grid_lo: f64,
    grid_h: f64,
    m: usize,
    /// cached Toeplitz K_UU
    kuu: ToeplitzOp,
    /// cached Toeplitz dK_UU/draw_p per kernel parameter
    dkuu: Vec<ToeplitzOp>,
}

impl SkiOp {
    /// Build over 1-D features with an `m`-point grid spanning the data
    /// (with a 2-cell margin so every point has a full cubic stencil).
    pub fn new(z: Vec<f64>, m: usize, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        assert!(noise > 0.0);
        let (zmin, zmax) = z
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
                (lo.min(v), hi.max(v))
            });
        let span = (zmax - zmin).max(1e-9);
        let h = span / (m as f64 - 5.0); // leaves ≥2 cells margin each side
        let lo = zmin - 2.0 * h;
        let hi = lo + h * (m - 1) as f64;
        let interp = SparseInterp::new(&z, lo, hi, m);
        let (kuu, dkuu) = Self::build_toeplitz(kernel.as_ref(), h, m);
        SkiOp {
            z,
            interp,
            kernel,
            raw_noise: noise.ln(),
            grid_lo: lo,
            grid_h: h,
            m,
            kuu,
            dkuu,
        }
    }

    fn build_toeplitz(kernel: &dyn Kernel, h: f64, m: usize) -> (ToeplitzOp, Vec<ToeplitzOp>) {
        let nk = kernel.n_params();
        let mut col = Vec::with_capacity(m);
        let mut dcols: Vec<Vec<f64>> = vec![Vec::with_capacity(m); nk];
        let mut g = vec![0.0; nk];
        let origin = [0.0];
        for i in 0..m {
            let xi = [i as f64 * h];
            col.push(kernel.eval(&origin, &xi));
            kernel.eval_grad(&origin, &xi, &mut g);
            for (p, dc) in dcols.iter_mut().enumerate() {
                dc.push(g[p]);
            }
        }
        (
            ToeplitzOp::new(col),
            dcols.into_iter().map(ToeplitzOp::new).collect(),
        )
    }

    pub fn grid(&self) -> (f64, f64, usize) {
        (self.grid_lo, self.grid_h, self.m)
    }

    pub fn features(&self) -> &[f64] {
        &self.z
    }

    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.raw_noise);
        p
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.kernel.n_params();
        self.kernel.set_params(&raw[..nk]);
        self.raw_noise = raw[nk];
        let (kuu, dkuu) = Self::build_toeplitz(self.kernel.as_ref(), self.grid_h, self.m);
        self.kuu = kuu;
        self.dkuu = dkuu;
    }

    /// SKI cross-covariance rows for *test* features: `W* K_UU Wᵀ`.
    pub fn cross(&self, z_test: &[f64]) -> Mat {
        let hi = self.grid_lo + self.grid_h * (self.m - 1) as f64;
        let w_star = SparseInterp::new(z_test, self.grid_lo, hi, self.m);
        // (n*×m) · T · (m×n): build T Wᵀ column block implicitly — for each
        // test row, u = T w*, then dot against training stencils.
        let mut out = Mat::zeros(z_test.len(), self.n());
        for i in 0..z_test.len() {
            let (ids, ws) = w_star.row_stencil(i);
            let u = self.toeplitz_times_sparse(ids, ws);
            let orow = out.row_mut(i);
            for j in 0..self.n() {
                let (jds, jws) = self.interp.row_stencil(j);
                let mut s = 0.0;
                for b in 0..4 {
                    s += jws[b] * u[jds[b]];
                }
                orow[j] = s;
            }
        }
        out
    }

    /// `u = T w` where w is 4-sparse: u[r] = Σ_a w_a c[|r − j_a|] — O(4m).
    fn toeplitz_times_sparse(&self, ids: &[usize; 4], ws: &[f64; 4]) -> Vec<f64> {
        let col = self.kuu.first_column();
        let mut u = vec![0.0; self.m];
        for a in 0..4 {
            let ja = ids[a];
            let wa = ws[a];
            for (r, uv) in u.iter_mut().enumerate() {
                *uv += wa * col[r.abs_diff(ja)];
            }
        }
        u
    }
}

impl KernelOperator for SkiOp {
    fn n(&self) -> usize {
        self.z.len()
    }

    fn n_params(&self) -> usize {
        self.kernel.n_params() + 1
    }

    /// `K̂M = W (T (WᵀM)) + σ²M` — O(t(n + m log m)).
    fn matmul(&self, m: &Mat) -> Mat {
        let wtm = self.interp.apply_t(m); // m×t
        let t_wtm = self.kuu.matmul(&wtm); // m×t (FFT)
        let mut out = self.interp.apply(&t_wtm); // n×t
        let sigma2 = self.noise();
        for r in 0..out.rows() {
            let orow = out.row_mut(r);
            let mrow = m.row(r);
            for c in 0..orow.len() {
                orow[c] += sigma2 * mrow[c];
            }
        }
        out
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let nk = self.kernel.n_params();
        if param == nk {
            let mut out = m.clone();
            out.scale_assign(self.noise());
            return out;
        }
        let wtm = self.interp.apply_t(m);
        let dt_wtm = self.dkuu[param].matmul(&wtm);
        self.interp.apply(&dt_wtm)
    }

    fn diag(&self) -> Vec<f64> {
        // diag_i = wᵢᵀ T wᵢ over the 4-point stencil — O(16 n)
        let col = self.kuu.first_column();
        (0..self.n())
            .map(|i| {
                let (ids, ws) = self.interp.row_stencil(i);
                let mut s = 0.0;
                for a in 0..4 {
                    for b in 0..4 {
                        s += ws[a] * ws[b] * col[ids[a].abs_diff(ids[b])];
                    }
                }
                s
            })
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        // rowᵢ = wᵢ T Wᵀ — O(4m + 4n)
        let (ids, ws) = self.interp.row_stencil(i);
        let u = self.toeplitz_times_sparse(ids, ws);
        (0..self.n())
            .map(|j| {
                let (jds, jws) = self.interp.row_stencil(j);
                let mut s = 0.0;
                for b in 0..4 {
                    s += jws[b] * u[jds[b]];
                }
                s
            })
            .collect()
    }

    fn noise(&self) -> f64 {
        self.raw_noise.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> SkiOp {
        let mut rng = Rng::new(seed);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        SkiOp::new(z, m, Box::new(Rbf::new(0.3, 1.0)), 0.1)
    }

    #[test]
    fn interpolation_weights_sum_to_one() {
        let op = setup(200, 50, 1);
        for i in 0..200 {
            let (_ids, ws) = op.interp.row_stencil(i);
            let s: f64 = ws.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "row {i}: {s}");
        }
    }

    #[test]
    fn matmul_matches_dense_ski_matrix() {
        let op = setup(60, 32, 2);
        let dense = op.dense();
        let mut rng = Rng::new(3);
        let m = Mat::from_fn(60, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = dense.matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn row_and_diag_consistent_with_dense() {
        let op = setup(40, 24, 4);
        let d = op.diag();
        for i in [0usize, 7, 39] {
            let r = op.row(i);
            assert!((r[i] - d[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn ski_approximates_exact_kernel() {
        // dense SKI matrix ≈ exact RBF kernel matrix when the grid is fine
        let n = 50;
        let mut rng = Rng::new(5);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let op = SkiOp::new(z.clone(), 400, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let x = Mat::from_vec(n, 1, z);
        let exact = DenseKernelOp::new(x, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        let diff = op.dense().max_abs_diff(&exact.dense());
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn dmatmul_matches_finite_differences() {
        let mut op = setup(30, 40, 6);
        let mut rng = Rng::new(7);
        let m = Mat::from_fn(30, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..op.n_params() {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 1e-4,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }

    #[test]
    fn cross_covariance_matches_dense_for_training_points() {
        let op = setup(25, 30, 8);
        let z = op.features().to_vec();
        let cross = op.cross(&z);
        // cross at training features == noiseless K rows
        for i in [0usize, 10, 24] {
            let r = op.row(i);
            for j in 0..25 {
                assert!((cross.get(i, j) - r[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn large_n_matmul_is_linear_time() {
        // smoke test: n = 100k SKI matmul with t=8 well under a second
        let n = 100_000;
        let mut rng = Rng::new(9);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let op = SkiOp::new(z, 2000, Box::new(Rbf::new(0.1, 1.0)), 0.1);
        let m = Mat::from_fn(n, 8, |_, _| rng.normal());
        let t = crate::util::Timer::start();
        let out = op.matmul(&m);
        assert!(out.data().iter().all(|v| v.is_finite()));
        assert!(t.elapsed_s() < 2.0, "took {}", t.elapsed_s());
    }
}
