//! FITC (paper §5: "Augmenting the SoR approximation with a diagonal
//! correction, e.g. as in FITC [44], is similarly straightforward").
//!
//! `K̂ = K_SoR + diag(k_XX − q_XX) + σ²I` — in algebra terms
//! `AddedDiagOp(SumOp(LowRankOp(A), DiagOp(correction)))`: the SoR
//! low-rank core (shared with [`SgprOp`]) plus the exact-diagonal
//! correction as a [`DiagOp`] summand. As the paper promises, the extra
//! model code over SGPR is the correction build (~20 lines).

use crate::gp::sgpr::SgprOp;
use crate::kernels::Kernel;
use crate::linalg::op::{AddedDiagOp, DiagOp, LinearOp, LowRankOp, SumOp};
use crate::tensor::Mat;

/// FITC operator: SoR + exact-diagonal correction.
pub struct FitcOp {
    sor: SgprOp,
    /// the composed full operator `A·Aᵀ + diag(corr) + σ²I`
    op: AddedDiagOp<SumOp<LowRankOp, DiagOp>>,
}

impl FitcOp {
    /// Build over training inputs, inducing points, and a kernel.
    pub fn new(x: Mat, u: Mat, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        let sor = SgprOp::new(x, u, kernel, noise);
        let op = Self::build_composition(&sor);
        FitcOp { sor, op }
    }

    fn build_composition(sor: &SgprOp) -> AddedDiagOp<SumOp<LowRankOp, DiagOp>> {
        let factor = sor.sor_factor().clone();
        let lowrank = LowRankOp::new(factor);
        let q_diag = lowrank.diag(); // SoR diagonal (noise-free)
        let correction: Vec<f64> = (0..sor.n())
            .map(|i| {
                let k_ii = sor.kernel().eval(sor.x().row(i), sor.x().row(i));
                (k_ii - q_diag[i]).max(0.0)
            })
            .collect();
        let raw_noise = *sor.params().last().unwrap();
        AddedDiagOp::from_raw(SumOp::new(lowrank, DiagOp::new(correction)), raw_noise)
    }

    /// The exact-diagonal correction `k(xᵢ,xᵢ) − q(xᵢ,xᵢ)` (≥ 0).
    pub fn correction(&self) -> &[f64] {
        self.op.inner().b().values()
    }

    /// Raw parameter vector (same layout as SGPR).
    pub fn params(&self) -> Vec<f64> {
        self.sor.params()
    }

    /// Overwrite raw parameters (rebuilds SoR caches + correction).
    pub fn set_params(&mut self, raw: &[f64]) {
        self.sor.set_params(raw);
        self.op = Self::build_composition(&self.sor);
    }

    /// The underlying SoR operator.
    pub fn sor(&self) -> &SgprOp {
        &self.sor
    }
}

impl LinearOp for FitcOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.sor.n_params()
    }

    /// derivative: d(SoR)/dθ + d(diag corr)/dθ; the diagonal part is
    /// computed by central differences on the (cheap) correction vector.
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let mut out = self.sor.dmatmul(param, m);
        let nk = self.sor.n_params() - 1;
        if param < nk {
            // FD on the correction (O(nm) per eval — negligible)
            let mut raw = self.params();
            let h = 1e-6;
            let mut probe = FitcOp::new(
                self.sor.x().clone(),
                self.sor.u().clone(),
                self.sor.kernel().boxed_clone(),
                self.sor.noise(),
            );
            raw[param] += h;
            probe.set_params(&raw);
            let plus = probe.correction().to_vec();
            raw[param] -= 2.0 * h;
            probe.set_params(&raw);
            let minus = probe.correction().to_vec();
            for i in 0..self.n() {
                let dc = (plus[i] - minus[i]) / (2.0 * h);
                if dc == 0.0 {
                    continue;
                }
                let mrow = m.row(i);
                let orow = out.row_mut(i);
                for t in 0..orow.len() {
                    orow[t] += dc * mrow[t];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> FitcOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let u = Mat::from_fn(m, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        FitcOp::new(x, u, Box::new(Rbf::new(0.5, 1.0)), 0.1)
    }

    #[test]
    fn fitc_diagonal_matches_exact_kernel_diagonal() {
        // FITC's defining property: diag(K_FITC − σ²I) == diag(K_exact)
        let op = setup(30, 6, 1);
        let (cov, _s2) = op.noise_split().unwrap();
        let d = cov.diag();
        for i in 0..30 {
            let exact = op.sor().kernel().eval(op.sor().x().row(i), op.sor().x().row(i));
            assert!((d[i] - exact).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn matmul_matches_dense() {
        let op = setup(25, 5, 2);
        let dense = op.dense();
        let mut rng = Rng::new(3);
        let m = Mat::from_fn(25, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&dense.matmul(&m)) < 1e-8);
    }

    #[test]
    fn correction_is_nonnegative_and_zero_at_inducing_points() {
        // when U ⊂ X the corrected points coincide: q(x,x) = k(x,x)
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(20, 1, |_, _| rng.uniform());
        let u = Mat::from_fn(5, 1, |i, _| x.get(i, 0));
        let op = FitcOp::new(x, u, Box::new(Rbf::new(0.4, 1.0)), 0.1);
        for c in op.correction() {
            assert!(*c >= 0.0);
        }
        for i in 0..5 {
            assert!(op.correction()[i] < 1e-3, "inducing point {i}: {}", op.correction()[i]);
        }
    }

    #[test]
    fn bbmm_fitc_matches_cholesky() {
        let op = setup(40, 8, 5);
        let mut rng = Rng::new(6);
        let y = rng.normal_vec(40);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::new(80, 64, 0, 7);
        let est = bbmm.mll_and_grad(&op, &y);
        assert!((est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4);
        assert!((est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15);
    }

    #[test]
    fn dmatmul_matches_finite_differences_of_matmul() {
        let mut op = setup(15, 4, 8);
        let mut rng = Rng::new(9);
        let m = Mat::from_fn(15, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-5;
        for p in 0..op.n_params() {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 2e-3,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }
}
