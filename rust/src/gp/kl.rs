//! Extension feature (paper §7, "Non-Gaussian likelihoods"): the KL
//! divergence between two multivariate Gaussians — the computationally
//! dominant term of the variational ELBO — from a **single mBCG call**
//! per covariance operator.
//!
//! ```text
//! KL(N₁‖N₂) = ½ [ Tr(Σ₂⁻¹Σ₁) + (μ₂−μ₁)ᵀΣ₂⁻¹(μ₂−μ₁) − n
//!                 + log|Σ₂| − log|Σ₁| ]
//! ```
//!
//! One mBCG call against Σ₂ with RHS `[μ₂−μ₁, z₁…z_t]` yields the solve
//! for the quadratic term, the probe solves for the Hutchinson trace
//! `Tr(Σ₂⁻¹Σ₁) ≈ mean((Σ₂⁻¹zᵢ)ᵀ(Σ₁zᵢ))`, and the tridiagonals for
//! `log|Σ₂|`; a second (solve-free) mBCG provides `log|Σ₁|`.

use crate::linalg::mbcg::{mbcg, MbcgOptions};
use crate::linalg::op::LinearOp;
use crate::linalg::trace::paired_trace;
use crate::linalg::tridiag::SymTridiagEig;
use crate::tensor::Mat;
use crate::util::Rng;

/// Options for the stochastic KL estimator.
pub struct KlOptions {
    pub max_cg_iters: usize,
    pub n_probes: usize,
    pub seed: u64,
}

impl Default for KlOptions {
    fn default() -> Self {
        KlOptions {
            max_cg_iters: 50,
            n_probes: 16,
            seed: 0xC0FFEE,
        }
    }
}

/// Stochastic estimate of `KL(N(μ₁, Σ₁) ‖ N(μ₂, Σ₂))` using only blackbox
/// mat-muls with the two covariance operators.
pub fn mvn_kl_divergence(
    sigma1: &dyn LinearOp,
    sigma2: &dyn LinearOp,
    mu1: &[f64],
    mu2: &[f64],
    opts: &KlOptions,
) -> f64 {
    let n = sigma1.n();
    assert_eq!(sigma2.n(), n);
    assert_eq!(mu1.len(), n);
    assert_eq!(mu2.len(), n);
    let t = opts.n_probes;
    let mut rng = Rng::new(opts.seed);

    // RHS block for the Σ₂ system: [μ₂−μ₁  z₁ … z_t]
    let diff: Vec<f64> = (0..n).map(|i| mu2[i] - mu1[i]).collect();
    let mut b = Mat::zeros(n, 1 + t);
    b.set_col(0, &diff);
    let mut z = Mat::zeros(n, t);
    for c in 0..t {
        for r in 0..n {
            z.set(r, c, rng.rademacher());
        }
        b.set_col(1 + c, &z.col(c));
    }

    // ONE mBCG call on Σ₂: quadratic solve + probe solves + tridiagonals
    let res2 = mbcg(
        |m| sigma2.matmul(m),
        &b,
        |m| m.clone(),
        &MbcgOptions {
            max_iters: opts.max_cg_iters,
            tol: 1e-10,
            n_solve_only: 1,
        },
    );
    let quad: f64 = (0..n).map(|i| diff[i] * res2.solves.get(i, 0)).sum();

    // Tr(Σ₂⁻¹Σ₁) via paired probes
    let probe_solves = res2.solves.cols_range(1, 1 + t);
    let sigma1_z = sigma1.matmul(&z);
    let trace = paired_trace(&probe_solves, &sigma1_z);

    // log|Σ₂| from the mBCG tridiagonals (SLQ)
    let logdet2 = slq_from_tridiags(&res2.tridiags, n, t);

    // log|Σ₁| from a second, solve-free mBCG on Σ₁
    let res1 = mbcg(
        |m| sigma1.matmul(m),
        &z,
        |m| m.clone(),
        &MbcgOptions {
            max_iters: opts.max_cg_iters,
            tol: 1e-10,
            n_solve_only: 0,
        },
    );
    let logdet1 = slq_from_tridiags(&res1.tridiags, n, t);

    0.5 * (trace + quad - n as f64 + logdet2 - logdet1)
}

fn slq_from_tridiags(tridiags: &[crate::linalg::mbcg::TriDiag], n: usize, t: usize) -> f64 {
    let mut acc = 0.0;
    for tri in tridiags {
        if tri.n() == 0 {
            continue;
        }
        let eig = SymTridiagEig::new(&tri.diag, &tri.offdiag);
        acc += n as f64 * eig.log_quadrature();
    }
    acc / t as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Matern52, Rbf};
    use crate::linalg::cholesky::Cholesky;
    use crate::util::Rng;

    /// exact KL via dense factorizations
    fn dense_kl(s1: &Mat, s2: &Mat, mu1: &[f64], mu2: &[f64]) -> f64 {
        let n = s1.rows();
        let ch2 = Cholesky::new_with_jitter(s2).unwrap();
        let ch1 = Cholesky::new_with_jitter(s1).unwrap();
        let s2inv_s1 = ch2.solve_mat(s1);
        let tr: f64 = (0..n).map(|i| s2inv_s1.get(i, i)).sum();
        let diff: Vec<f64> = (0..n).map(|i| mu2[i] - mu1[i]).collect();
        let sol = ch2.solve_vec(&diff);
        let quad: f64 = diff.iter().zip(sol.iter()).map(|(a, b)| a * b).sum();
        0.5 * (tr + quad - n as f64 + ch2.logdet() - ch1.logdet())
    }

    fn ops(n: usize, seed: u64) -> (DenseKernelOp, DenseKernelOp, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let op1 = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.2);
        let op2 = DenseKernelOp::new(x, Box::new(Matern52::new(0.7, 1.2)), 0.3);
        let mu1 = rng.normal_vec(n);
        let mu2 = rng.normal_vec(n);
        (op1, op2, mu1, mu2)
    }

    #[test]
    fn kl_matches_dense_formula() {
        let n = 60;
        let (op1, op2, mu1, mu2) = ops(n, 1);
        use crate::linalg::op::LinearOp;
        let exact = dense_kl(&op1.dense(), &op2.dense(), &mu1, &mu2);
        // average several probe draws to tame MC noise
        let mut acc = 0.0;
        let reps = 5;
        for r in 0..reps {
            acc += mvn_kl_divergence(
                &op1,
                &op2,
                &mu1,
                &mu2,
                &KlOptions {
                    max_cg_iters: n,
                    n_probes: 64,
                    seed: 100 + r,
                },
            );
        }
        let est = acc / reps as f64;
        assert!(
            (est - exact).abs() / exact.abs().max(1.0) < 0.1,
            "est {est} vs exact {exact}"
        );
    }

    #[test]
    fn kl_of_identical_distributions_is_zero() {
        let n = 40;
        let (op1, _op2, mu1, _mu2) = ops(n, 2);
        let kl = mvn_kl_divergence(
            &op1,
            &op1,
            &mu1,
            &mu1,
            &KlOptions {
                max_cg_iters: n,
                n_probes: 32,
                seed: 3,
            },
        );
        assert!(kl.abs() < 0.5, "KL(p‖p) ≈ 0, got {kl}");
    }

    #[test]
    fn kl_is_nonnegative_in_expectation() {
        let n = 30;
        let (op1, op2, mu1, mu2) = ops(n, 4);
        let mut acc = 0.0;
        for r in 0..5 {
            acc += mvn_kl_divergence(
                &op1,
                &op2,
                &mu1,
                &mu2,
                &KlOptions {
                    max_cg_iters: n,
                    n_probes: 32,
                    seed: 200 + r,
                },
            );
        }
        assert!(acc / 5.0 > 0.0);
    }
}
