//! Gaussian-process models and inference engines.
//!
//! An *inference engine* (paper §4) computes the negative marginal
//! log-likelihood, its hyperparameter gradient, and predictive
//! distributions. Three engines are provided:
//!
//! - [`mll::BbmmEngine`] — **the paper's contribution**: one mBCG call
//!   produces every inference term (solves, SLQ log-det, stochastic trace).
//! - [`mll::CholeskyEngine`] — the O(n³) dense baseline (GPFlow-equivalent).
//! - [`dong::DongEngine`] — the Dong et al. [13] MVM baseline: sequential
//!   CG solves plus explicit Lanczos for the log-det (the engine the paper
//!   compares against for SKI in Figure 2, right).
//!
//! Models: [`exact::ExactGp`], [`sgpr::SgprOp`] (SGPR/SoR [45]),
//! [`ski::SkiOp`] (SKI/KISS-GP [50]).

pub mod dong;
pub mod exact;
pub mod fitc;
pub mod kl;
pub mod mll;
pub mod multitask;
pub mod posterior;
pub mod predict;
pub mod sgpr;
pub mod ski;

pub use dong::DongEngine;
pub use exact::{Engine, ExactGp};
pub use fitc::FitcOp;
pub use mll::{
    mll_and_grad_batch_with, BatchBbmmEngine, BatchInferenceEngine, BbmmEngine, CholeskyEngine,
    InferenceEngine, MllGrad,
};
pub use multitask::MultitaskOp;
pub use posterior::{LovePosterior, PosteriorCache};
pub use sgpr::{SgprCholeskyEngine, SgprModel, SgprOp};
pub use ski::SkiOp;
