//! **`PosteriorCache`** — the LOVE state cached *across* predictive
//! queries, the serve-path analogue of
//! [`SolvePlanCache`](crate::linalg::op::SolvePlanCache).
//!
//! A [`LovePosterior`] bundles everything a trained GP needs to answer
//! mean + variance + posterior-sample queries in O(n·r) per test point:
//! the exact mean solve `α = K̂⁻¹y` (one dispatched solve at build time)
//! and the rank-r LOVE factor `R ≈ K̂⁻¹`-root
//! ([`crate::linalg::love::LoveFactors`]). A predict call is then two
//! skinny GEMMs — `K_*·α` for the mean, `R·K_*ᵀ` for every variance —
//! with no mBCG iteration in sight.
//!
//! The cache keys posteriors by deployment slot (tenant name, `"default"`,
//! …) and invalidates on operator-content change: a `set_params` /
//! sweep hot-swap rewrites the operator's entries, its
//! [`fingerprint`](crate::linalg::op::LinearOp::fingerprint) moves, and
//! the next query rebuilds the posterior exactly once. A changed LOVE
//! rank likewise rebuilds.

use crate::gp::predict::Prediction;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::love::LoveFactors;
use crate::linalg::op::{solve, LinearOp, SolveOptions};
use crate::tensor::Mat;
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A trained GP posterior frozen into constant-time query form: the exact
/// mean solve plus the rank-r LOVE variance factor. Build once per
/// hyperparameter setting, answer every query from the cached state.
pub struct LovePosterior {
    /// operator content fingerprint at build time (drives invalidation)
    fingerprint: u64,
    /// requested rank (the achieved factor rank may be lower; see
    /// [`LovePosterior::rank`])
    rank_requested: usize,
    /// `α = K̂⁻¹y` — the mean weights, solved exactly at build time
    alpha: Vec<f64>,
    factors: LoveFactors,
}

impl LovePosterior {
    /// Freeze the posterior of `op` (the trained `K̂`) with targets `y`:
    /// one exact dispatched solve for `α = K̂⁻¹y`, then the rank-`rank`
    /// LOVE factor. `y` doubles as the Lanczos probe (the Krylov space is
    /// then aligned with the data; an all-zero `y` falls back to a ones
    /// probe).
    pub fn build(op: &dyn LinearOp, y: &[f64], rank: usize, opts: &SolveOptions) -> LovePosterior {
        let n = op.n();
        assert_eq!(y.len(), n, "LovePosterior: y length must match operator");
        let fingerprint = op.fingerprint();
        let alpha = solve(op, &Mat::col_from_slice(y), opts).col(0);
        let probe: Vec<f64> = if y.iter().any(|v| *v != 0.0) {
            y.to_vec()
        } else {
            vec![1.0; n]
        };
        let factors = LoveFactors::build_op(op, &probe, rank);
        LovePosterior {
            fingerprint,
            rank_requested: rank,
            alpha,
            factors,
        }
    }

    /// Mean + marginal variance for a test block — two skinny GEMMs
    /// against the cached state, O(n·r) per test point.
    ///
    /// * `k_star` — `n_test × n` cross-covariance `K(X*, X)`
    /// * `k_star_diag` — prior variances `k(x*, x*)` per test point
    pub fn predict(&self, k_star: &Mat, k_star_diag: &[f64]) -> Prediction {
        let n_test = k_star.rows();
        assert_eq!(k_star_diag.len(), n_test, "predict: diag length mismatch");
        let quads = self.factors.quad_diag(k_star);
        let mut mean = vec![0.0; n_test];
        let mut var = vec![0.0; n_test];
        for j in 0..n_test {
            let krow = k_star.row(j);
            mean[j] = krow.iter().zip(self.alpha.iter()).map(|(a, b)| a * b).sum();
            var[j] = (k_star_diag[j] - quads[j]).max(0.0);
        }
        Prediction { mean, var }
    }

    /// Full posterior covariance at a test block:
    /// `K_** − K_* K̂⁻¹ K_*ᵀ` from the cached factor. `prior_cov` is the
    /// `n_test × n_test` prior block `K(X*, X*)`.
    pub fn posterior_cov(&self, k_star: &Mat, prior_cov: &Mat) -> Mat {
        let s = k_star.rows();
        assert_eq!(prior_cov.rows(), s, "posterior_cov: prior block mismatch");
        assert_eq!(prior_cov.cols(), s, "posterior_cov: prior block mismatch");
        let quad = self.factors.quad_cross(k_star, k_star);
        // symmetrize: the quad block is symmetric up to roundoff, and the
        // sampler downstream Cholesky-factors this matrix
        Mat::from_fn(s, s, |i, j| {
            let q = 0.5 * (quad.get(i, j) + quad.get(j, i));
            prior_cov.get(i, j) - q
        })
    }

    /// Draw `n_samples` correlated posterior samples at a test block
    /// (CIQ-style, from the cached root — no fresh solve). Returns an
    /// `n_test × n_samples` matrix whose columns are draws from
    /// `N(μ(X*), K_** − K_* K̂⁻¹ K_*ᵀ)`.
    pub fn sample(
        &self,
        k_star: &Mat,
        prior_cov: &Mat,
        n_samples: usize,
        rng: &mut Rng,
    ) -> Mat {
        let s = k_star.rows();
        let mean = self.predict_mean(k_star);
        let mut cov = self.posterior_cov(k_star, prior_cov);
        // tiny absolute jitter: near-interpolation posteriors are
        // numerically semidefinite
        cov.add_diag(1e-12);
        let ch = Cholesky::new_with_jitter(&cov)
            .expect("sample: posterior covariance not factorizable");
        let mut z = Mat::zeros(s, n_samples);
        for j in 0..n_samples {
            for i in 0..s {
                z.set(i, j, rng.normal());
            }
        }
        let corr = ch.l().matmul(&z);
        Mat::from_fn(s, n_samples, |i, j| mean[i] + corr.get(i, j))
    }

    /// Mean only — one skinny GEMV against the cached `α`.
    pub fn predict_mean(&self, k_star: &Mat) -> Vec<f64> {
        assert_eq!(k_star.cols(), self.alpha.len(), "predict_mean: width mismatch");
        (0..k_star.rows())
            .map(|j| {
                k_star
                    .row(j)
                    .iter()
                    .zip(self.alpha.iter())
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }

    /// Operator fingerprint the posterior was built against.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Achieved LOVE rank (≤ requested when Lanczos truncated on an
    /// invariant subspace).
    pub fn rank(&self) -> usize {
        self.factors.rank()
    }

    /// The underlying LOVE factor.
    pub fn factors(&self) -> &LoveFactors {
        &self.factors
    }
}

struct Slot {
    fingerprint: u64,
    rank: usize,
    post: Arc<LovePosterior>,
}

/// Cache of frozen [`LovePosterior`]s keyed by deployment slot,
/// invalidated by operator fingerprint or LOVE-rank change — the
/// posterior-side sibling of
/// [`SolvePlanCache`](crate::linalg::op::SolvePlanCache).
#[derive(Default)]
pub struct PosteriorCache {
    slots: Mutex<HashMap<String, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

impl PosteriorCache {
    /// Empty cache.
    pub fn new() -> Self {
        PosteriorCache::default()
    }

    /// The posterior for `op`/`y` under slot `key`, building (miss) or
    /// rebuilding (fingerprint or rank change) as needed. Recomputes the
    /// O(n) content fingerprint per call; serving loops holding an
    /// immutable operator should fingerprint once and use
    /// [`PosteriorCache::get_or_build_with_fingerprint`].
    pub fn get_or_build(
        &self,
        key: &str,
        op: &dyn LinearOp,
        y: &[f64],
        rank: usize,
        opts: &SolveOptions,
    ) -> Arc<LovePosterior> {
        self.get_or_build_with_fingerprint(key, op.fingerprint(), op, y, rank, opts)
    }

    /// [`PosteriorCache::get_or_build`] with a caller-computed
    /// fingerprint — the hit path does no operator probing at all.
    pub fn get_or_build_with_fingerprint(
        &self,
        key: &str,
        fp: u64,
        op: &dyn LinearOp,
        y: &[f64],
        rank: usize,
        opts: &SolveOptions,
    ) -> Arc<LovePosterior> {
        let mut slots = self.slots.lock().unwrap();
        if let Some(slot) = slots.get(key) {
            if slot.fingerprint == fp && slot.rank == rank {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(&slot.post);
            }
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        // the lock is held across the rebuild on purpose: racing request
        // handlers must not freeze the same posterior twice
        let built = Arc::new(LovePosterior::build(op, y, rank, opts));
        slots.insert(
            key.to_string(),
            Slot {
                fingerprint: fp,
                rank,
                post: Arc::clone(&built),
            },
        );
        built
    }

    /// Cached posterior count.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no posterior is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached posterior (deployment reload).
    pub fn clear(&self) {
        self.slots.lock().unwrap().clear();
    }

    /// Queries answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// First-time posterior builds.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Rebuilds forced by an operator-content or rank change.
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// One-line counter summary for serving logs.
    pub fn stats(&self) -> String {
        format!(
            "posteriors={} hits={} misses={} invalidations={}",
            self.len(),
            self.hits(),
            self.misses(),
            self.invalidations()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::util::Rng;

    fn model(n: usize, seed: u64) -> (DenseKernelOp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.02 * rng.normal())
            .collect();
        (DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1), y)
    }

    fn dense_reference(op: &DenseKernelOp, y: &[f64], k_star: &Mat, diag: &[f64]) -> Prediction {
        let ch = Cholesky::new_with_jitter(&op.dense()).unwrap();
        crate::gp::predict::predict(k_star, diag, |m| ch.solve_mat(m), y)
    }

    #[test]
    fn full_rank_posterior_matches_dense_reference() {
        let n = 40;
        let (op, y) = model(n, 1);
        let mut rng = Rng::new(2);
        let xs = Mat::from_fn(6, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let k_star = op.cross(&xs, op.x());
        let diag: Vec<f64> = (0..6).map(|i| op.kernel().eval(xs.row(i), xs.row(i))).collect();
        let opts = SolveOptions {
            max_iters: 400,
            tol: 1e-12,
            precond_rank: 5,
        };
        let post = LovePosterior::build(&op, &y, n, &opts);
        let got = post.predict(&k_star, &diag);
        let want = dense_reference(&op, &y, &k_star, &diag);
        for j in 0..6 {
            assert!((got.mean[j] - want.mean[j]).abs() < 1e-7, "mean {j}");
            assert!(
                (got.var[j] - want.var[j]).abs() <= 1e-6 * want.var[j].abs().max(1e-9),
                "var {j}: {} vs {}",
                got.var[j],
                want.var[j]
            );
        }
    }

    #[test]
    fn cache_miss_hit_invalidate_cycle() {
        let cache = PosteriorCache::new();
        let (op, y) = model(30, 3);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_build("t", &op, &y, 16, &opts);
        let p2 = cache.get_or_build("t", &op, &y, 16, &opts);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must reuse the posterior");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 1, 0));
        // rank change rebuilds
        let p3 = cache.get_or_build("t", &op, &y, 24, &opts);
        assert!(!Arc::ptr_eq(&p2, &p3));
        assert_eq!(cache.invalidations(), 1);
        assert!(cache.stats().contains("invalidations=1"));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn fingerprint_change_invalidates() {
        let cache = PosteriorCache::new();
        let (mut op, y) = model(25, 4);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_build("t", &op, &y, 12, &opts);
        let mut raw = op.params();
        raw[0] += 0.3; // lengthscale moves → new fingerprint
        op.set_params(&raw);
        let p2 = cache.get_or_build("t", &op, &y, 12, &opts);
        assert!(!Arc::ptr_eq(&p1, &p2), "stale posterior must be rebuilt");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 0, 1));
    }

    #[test]
    fn sampling_matches_posterior_moments() {
        let n = 35;
        let (op, y) = model(n, 5);
        let xs = Mat::from_vec(3, 2, vec![-0.5, 0.2, 0.0, 0.0, 0.4, -0.3]);
        let k_star = op.cross(&xs, op.x());
        let prior = op.cross(&xs, &xs);
        let diag: Vec<f64> = (0..3).map(|i| prior.get(i, i)).collect();
        let opts = SolveOptions {
            max_iters: 400,
            tol: 1e-12,
            precond_rank: 5,
        };
        let post = LovePosterior::build(&op, &y, n, &opts);
        let want = post.predict(&k_star, &diag);
        let m = 4000;
        let mut rng = Rng::new(6);
        let draws = post.sample(&k_star, &prior, m, &mut rng);
        for i in 0..3 {
            let row = draws.row(i);
            let emp_mean = row.iter().sum::<f64>() / m as f64;
            let emp_var =
                row.iter().map(|v| (v - emp_mean) * (v - emp_mean)).sum::<f64>() / m as f64;
            assert!(
                (emp_mean - want.mean[i]).abs() < 0.05,
                "mean {i}: {emp_mean} vs {}",
                want.mean[i]
            );
            assert!(
                (emp_var - want.var[i]).abs() < 0.05,
                "var {i}: {emp_var} vs {}",
                want.var[i]
            );
        }
    }
}
