//! Multi-task Gaussian processes (paper §5; Bonilla et al. [5]).
//!
//! `K̂ = B ⊗ K_XX + σ²I` with `B = W Wᵀ + diag(v)` a learnable q×q task
//! covariance (low-rank-plus-diagonal, the standard ICM parameterisation).
//! The blackbox mat-mul uses the Kronecker identity — one data-kernel
//! mat-mul per task block instead of an (nq)² matrix — so the whole model
//! is, once again, a ~100-line `KernelOperator`.

use crate::kernels::{Kernel, KernelOperator};
use crate::linalg::kronecker::kron_dense;
use crate::tensor::Mat;

/// Multi-task operator over n points × q tasks (ICM / Kronecker model).
///
/// Vector layout: entry `i*q + t` is point `i`, task `t`.
pub struct MultitaskOp {
    x: Mat,
    kernel: Box<dyn Kernel>,
    /// low-rank task factor W (q×r), raw entries (unconstrained)
    task_w: Mat,
    /// raw log task diagonal v (length q)
    raw_task_diag: Vec<f64>,
    raw_noise: f64,
    q: usize,
}

impl MultitaskOp {
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, q: usize, rank: usize, noise: f64) -> Self {
        assert!(noise > 0.0 && q > 0 && rank > 0);
        // identity-ish init: W = small, diag = 1
        let task_w = Mat::from_fn(q, rank, |i, j| if i % rank == j { 0.5 } else { 0.1 });
        MultitaskOp {
            x,
            kernel,
            task_w,
            raw_task_diag: vec![0.0; q],
            raw_noise: noise.ln(),
            q,
        }
    }

    pub fn q(&self) -> usize {
        self.q
    }

    /// task covariance `B = W Wᵀ + diag(e^{raw_v})`
    pub fn task_cov(&self) -> Mat {
        let mut b = self.task_w.matmul_t(&self.task_w);
        for t in 0..self.q {
            let d = b.get(t, t) + self.raw_task_diag[t].exp();
            b.set(t, t, d);
        }
        b
    }

    /// data kernel matrix K_XX (noiseless)
    fn data_kernel(&self) -> Mat {
        let n = self.x.rows();
        Mat::from_fn(n, n, |i, j| self.kernel.eval(self.x.row(i), self.x.row(j)))
    }

    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.extend_from_slice(self.task_w.data());
        p.extend_from_slice(&self.raw_task_diag);
        p.push(self.raw_noise);
        p
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.kernel.n_params();
        self.kernel.set_params(&raw[..nk]);
        let wn = self.task_w.rows() * self.task_w.cols();
        self.task_w.data_mut().copy_from_slice(&raw[nk..nk + wn]);
        self.raw_task_diag
            .copy_from_slice(&raw[nk + wn..nk + wn + self.q]);
        self.raw_noise = raw[nk + wn + self.q];
    }
}

impl KernelOperator for MultitaskOp {
    fn n(&self) -> usize {
        self.x.rows() * self.q
    }

    fn n_params(&self) -> usize {
        self.kernel.n_params() + self.task_w.rows() * self.task_w.cols() + self.q + 1
    }

    /// `(K_XX ⊗ B) M + σ²M` — layout `i*q + t` makes the Kronecker factor
    /// order (K_data ⊗ B).
    fn matmul(&self, m: &Mat) -> Mat {
        let n = self.x.rows();
        let q = self.q;
        assert_eq!(m.rows(), n * q);
        let b = self.task_cov();
        let k = self.data_kernel();
        let sigma2 = self.noise();
        let t_cols = m.cols();
        let mut out = Mat::zeros(n * q, t_cols);
        // (K ⊗ B) vec-layout: for each RHS column, reshape to n×q,
        // compute K · X · Bᵀ
        for c in 0..t_cols {
            let xcol = Mat::from_vec(n, q, m.col(c));
            let kx = k.matmul(&xcol);
            let res = kx.matmul_t(&b);
            let mut col = res.data().to_vec();
            for (i, v) in col.iter_mut().enumerate() {
                *v += sigma2 * m.get(i, c);
            }
            out.set_col(c, &col);
        }
        out
    }

    /// Gradients by finite structure would be lengthy; for the multi-task
    /// extension we provide the noise derivative analytically and central
    /// differences for the remaining parameters (the blackbox contract
    /// allows any implementation — this is the "rapid prototyping" mode
    /// the paper's programmability section argues for).
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let nk = self.n_params();
        assert!(param < nk);
        if param == nk - 1 {
            let mut out = m.clone();
            out.scale_assign(self.noise());
            return out;
        }
        // central differences through the (cheap) structured matmul
        let mut raw = self.params();
        let h = 1e-6;
        let mut op = MultitaskOp {
            x: self.x.clone(),
            kernel: self.kernel.boxed_clone(),
            task_w: self.task_w.clone(),
            raw_task_diag: self.raw_task_diag.clone(),
            raw_noise: self.raw_noise,
            q: self.q,
        };
        raw[param] += h;
        op.set_params(&raw);
        let plus = op.matmul(m);
        raw[param] -= 2.0 * h;
        op.set_params(&raw);
        let minus = op.matmul(m);
        let mut out = plus.sub(&minus);
        out.scale_assign(1.0 / (2.0 * h));
        // remove the σ² I M term's contribution (it does not depend on
        // non-noise params; finite differences above keep σ fixed, fine)
        out
    }

    fn diag(&self) -> Vec<f64> {
        let b = self.task_cov();
        let n = self.x.rows();
        let mut d = Vec::with_capacity(n * self.q);
        for i in 0..n {
            let kii = self.kernel.eval(self.x.row(i), self.x.row(i));
            for t in 0..self.q {
                d.push(kii * b.get(t, t));
            }
        }
        d
    }

    fn row(&self, idx: usize) -> Vec<f64> {
        let q = self.q;
        let (i, t) = (idx / q, idx % q);
        let b = self.task_cov();
        let n = self.x.rows();
        let xi = self.x.row(i);
        let mut r = Vec::with_capacity(n * q);
        for j in 0..n {
            let kij = self.kernel.eval(xi, self.x.row(j));
            for s in 0..q {
                r.push(kij * b.get(t, s));
            }
        }
        r
    }

    fn noise(&self) -> f64 {
        self.raw_noise.exp()
    }

    fn dense(&self) -> Mat {
        let k = self.data_kernel();
        let b = self.task_cov();
        let mut full = kron_dense(&k, &b);
        full.add_diag(self.noise());
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn setup(n: usize, q: usize, seed: u64) -> MultitaskOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        MultitaskOp::new(x, Box::new(Rbf::new(0.5, 1.0)), q, 2, 0.1)
    }

    #[test]
    fn matmul_matches_dense_kronecker() {
        let op = setup(12, 3, 1);
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(36, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn row_and_diag_consistent_with_dense() {
        let op = setup(8, 2, 3);
        let dense = op.dense();
        let d = op.diag();
        for idx in [0usize, 5, 15] {
            let r = op.row(idx);
            for j in 0..16 {
                let want = dense.get(idx, j) - if idx == j { op.noise() } else { 0.0 };
                assert!((r[j] - want).abs() < 1e-10, "row {idx} col {j}");
            }
            assert!((d[idx] - r[idx]).abs() < 1e-10);
        }
    }

    #[test]
    fn bbmm_multitask_matches_cholesky() {
        let op = setup(15, 2, 4);
        let mut rng = Rng::new(5);
        let y = rng.normal_vec(30);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::new(60, 64, 5, 6);
        let est = bbmm.mll_and_grad(&op, &y);
        assert!(
            (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4,
            "{} vs {}",
            est.datafit,
            exact.datafit
        );
        assert!((est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15);
    }

    #[test]
    fn task_covariance_is_pd() {
        let op = setup(5, 4, 7);
        let b = op.task_cov();
        assert!(crate::linalg::cholesky::Cholesky::new(&b).is_ok());
    }

    #[test]
    fn parameter_roundtrip() {
        let mut op = setup(6, 3, 8);
        let mut p = op.params();
        assert_eq!(p.len(), op.n_params());
        p[2] = 0.777;
        op.set_params(&p);
        assert!((op.params()[2] - 0.777).abs() < 1e-15);
    }
}
