//! Multi-task Gaussian processes (paper §5; Bonilla et al. [5]).
//!
//! `K̂ = (K_XX ⊗ B) + σ²I` with `B = W Wᵀ + diag(v)` a learnable q×q task
//! covariance (low-rank-plus-diagonal, the standard ICM parameterisation)
//! — written as the composition `AddedDiagOp(KroneckerOp(K_XX, B))`. The
//! [`crate::linalg::op::KroneckerOp`] identity makes a mat-mul one
//! data-kernel GEMM per task block instead of an (nq)² matrix, and the
//! factors are cached across mBCG iterations (rebuilt only on
//! hyperparameter updates). The model layer is, once again, a thin
//! named wrapper over the algebra plus its gradient layout.

use crate::kernels::Kernel;
use crate::linalg::op::{AddedDiagOp, KroneckerOp, LinearOp};
use crate::tensor::Mat;

/// Multi-task operator over n points × q tasks (ICM / Kronecker model).
///
/// Vector layout: entry `i*q + t` is point `i`, task `t`.
pub struct MultitaskOp {
    x: Mat,
    kernel: Box<dyn Kernel>,
    /// low-rank task factor W (q×r), raw entries (unconstrained)
    task_w: Mat,
    /// raw log task diagonal v (length q)
    raw_task_diag: Vec<f64>,
    q: usize,
    /// cached composition `(K_XX ⊗ B) + σ²I` for current hyperparameters
    op: AddedDiagOp<KroneckerOp>,
}

impl MultitaskOp {
    /// Build over training inputs, a data kernel, and the task layout.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, q: usize, rank: usize, noise: f64) -> Self {
        assert!(noise > 0.0 && q > 0 && rank > 0);
        // identity-ish init: W = small, diag = 1
        let task_w = Mat::from_fn(q, rank, |i, j| if i % rank == j { 0.5 } else { 0.1 });
        let raw_task_diag = vec![0.0; q];
        let op = AddedDiagOp::new(
            Self::build_kron(&x, kernel.as_ref(), &task_w, &raw_task_diag),
            noise,
        );
        MultitaskOp {
            x,
            kernel,
            task_w,
            raw_task_diag,
            q,
            op,
        }
    }

    fn build_kron(
        x: &Mat,
        kernel: &dyn Kernel,
        task_w: &Mat,
        raw_task_diag: &[f64],
    ) -> KroneckerOp {
        let n = x.rows();
        let k = Mat::from_fn(n, n, |i, j| kernel.eval(x.row(i), x.row(j)));
        let q = task_w.rows();
        let mut b = task_w.matmul_t(task_w);
        for t in 0..q {
            let d = b.get(t, t) + raw_task_diag[t].exp();
            b.set(t, t, d);
        }
        KroneckerOp::new(k, b)
    }

    /// Number of tasks q.
    pub fn q(&self) -> usize {
        self.q
    }

    /// task covariance `B = W Wᵀ + diag(e^{raw_v})`
    pub fn task_cov(&self) -> Mat {
        self.op.inner().b().clone()
    }

    /// Raw parameter vector `[kernel…, W entries…, log v…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.extend_from_slice(self.task_w.data());
        p.extend_from_slice(&self.raw_task_diag);
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite raw parameters (rebuilds the Kronecker factors).
    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.kernel.n_params();
        self.kernel.set_params(&raw[..nk]);
        let wn = self.task_w.rows() * self.task_w.cols();
        self.task_w.data_mut().copy_from_slice(&raw[nk..nk + wn]);
        self.raw_task_diag
            .copy_from_slice(&raw[nk + wn..nk + wn + self.q]);
        self.op = AddedDiagOp::from_raw(
            Self::build_kron(
                &self.x,
                self.kernel.as_ref(),
                &self.task_w,
                &self.raw_task_diag,
            ),
            raw[nk + wn + self.q],
        );
    }
}

impl LinearOp for MultitaskOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.kernel.n_params() + self.task_w.rows() * self.task_w.cols() + self.q + 1
    }

    /// Gradients by finite structure would be lengthy; for the multi-task
    /// extension we provide the noise derivative analytically and central
    /// differences for the remaining parameters (the blackbox contract
    /// allows any implementation — this is the "rapid prototyping" mode
    /// the paper's programmability section argues for).
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let nk = self.n_params();
        assert!(param < nk);
        if param == nk - 1 {
            let mut out = m.clone();
            out.scale_assign(self.noise());
            return out;
        }
        // central differences through the (cheap) structured matmul
        let mut raw = self.params();
        let h = 1e-6;
        let mut probe = MultitaskOp::new(
            self.x.clone(),
            self.kernel.boxed_clone(),
            self.q,
            self.task_w.cols(),
            self.noise(),
        );
        raw[param] += h;
        probe.set_params(&raw);
        let plus = probe.matmul(m);
        raw[param] -= 2.0 * h;
        probe.set_params(&raw);
        let minus = probe.matmul(m);
        let mut out = plus.sub(&minus);
        out.scale_assign(1.0 / (2.0 * h));
        // the σ²I term is parameter-independent here (σ held fixed above),
        // so the difference isolates the structural derivative
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn setup(n: usize, q: usize, seed: u64) -> MultitaskOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        MultitaskOp::new(x, Box::new(Rbf::new(0.5, 1.0)), q, 2, 0.1)
    }

    #[test]
    fn matmul_matches_dense_kronecker() {
        let op = setup(12, 3, 1);
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(36, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn row_and_diag_consistent_with_dense() {
        let op = setup(8, 2, 3);
        let dense = op.dense();
        let d = op.diag();
        for idx in [0usize, 5, 15] {
            let r = op.row(idx);
            for j in 0..16 {
                // full-operator semantics: rows/diag include σ²
                assert!((r[j] - dense.get(idx, j)).abs() < 1e-10, "row {idx} col {j}");
            }
            assert!((d[idx] - r[idx]).abs() < 1e-10);
        }
        let (kron, s2) = op.noise_split().unwrap();
        assert!((kron.diag()[0] + s2 - d[0]).abs() < 1e-12);
    }

    #[test]
    fn bbmm_multitask_matches_cholesky() {
        let op = setup(15, 2, 4);
        let mut rng = Rng::new(5);
        let y = rng.normal_vec(30);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::new(60, 64, 5, 6);
        let est = bbmm.mll_and_grad(&op, &y);
        assert!(
            (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4,
            "{} vs {}",
            est.datafit,
            exact.datafit
        );
        assert!((est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15);
    }

    #[test]
    fn task_covariance_is_pd() {
        let op = setup(5, 4, 7);
        let b = op.task_cov();
        assert!(crate::linalg::cholesky::Cholesky::new(&b).is_ok());
    }

    #[test]
    fn parameter_roundtrip() {
        let mut op = setup(6, 3, 8);
        let mut p = op.params();
        assert_eq!(p.len(), op.n_params());
        p[2] = 0.777;
        op.set_params(&p);
        assert!((op.params()[2] - 0.777).abs() < 1e-15);
    }
}
