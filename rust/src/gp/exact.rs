//! Exact GP regression model — a thin composition over the operator
//! algebra: `K̂ = AddedDiagOp(cov)` where `cov` is **any**
//! [`KernelCov`] backend (the fused monolithic [`KernelCovOp`] or the
//! row-sharded [`ShardedCovOp`]), tied to targets and an inference engine
//! (BBMM or Cholesky). The seed-era `ExactOp` enum is gone: backends plug
//! in through the `KernelCov` trait, and training/prediction run through
//! the generic engine + solve-dispatcher paths. This is the model behind
//! the paper's "Exact" columns in Figures 2 and 3.

use crate::gp::mll::{BatchBbmmEngine, BatchInferenceEngine, BbmmEngine, InferenceEngine, MllGrad};
use crate::gp::posterior::PosteriorCache;
use crate::gp::predict::{predict, predict_with_plan, Prediction};
use crate::kernels::{Kernel, KernelCov, KernelCovOp, ShardedCovOp};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::op::{
    lift_added_diag, AddedDiagOp, BatchOp, LinearOp, SolveOptions, SolvePlanCache,
};
use crate::tensor::Mat;
use crate::train::{SweepReport, SweepTrainer, TrainConfig};
use crate::util::Rng;

/// Which inference engine backs the model.
pub enum Engine {
    /// Blackbox matrix-matrix inference (the paper's method)
    Bbmm(BbmmEngine),
    /// Dense Cholesky baseline
    Cholesky,
}

/// Exact Gaussian-process regression model over a pluggable covariance
/// backend. Holds a [`SolvePlanCache`] handle so repeated predictions
/// against fixed hyperparameters reuse one factorisation/preconditioner;
/// a `set_params` call changes the operator's content fingerprint and the
/// stale plan is rebuilt on the next predict automatically.
pub struct ExactGp {
    op: AddedDiagOp<Box<dyn KernelCov>>,
    y: Vec<f64>,
    engine: Engine,
    plans: SolvePlanCache,
    /// LOVE rank for constant-time variances (`None` = solve per predict)
    love: Option<usize>,
    posterior: PosteriorCache,
}

impl ExactGp {
    /// Monolithic fused-operator model.
    pub fn new(x: Mat, y: Vec<f64>, kernel: Box<dyn Kernel>, noise: f64, engine: Engine) -> Self {
        assert_eq!(x.rows(), y.len());
        Self::over(Box::new(KernelCovOp::new(x, kernel)), y, noise, engine)
    }

    /// Like [`ExactGp::new`], but over a row-sharded covariance backend —
    /// the configuration the serving path uses to size shards to traffic.
    pub fn new_sharded(
        x: Mat,
        y: Vec<f64>,
        kernel: Box<dyn Kernel>,
        noise: f64,
        engine: Engine,
        shards: usize,
    ) -> Self {
        assert_eq!(x.rows(), y.len());
        Self::over(Box::new(ShardedCovOp::new(x, kernel, shards)), y, noise, engine)
    }

    /// The general constructor: any [`KernelCov`] backend composes with
    /// `AddedDiagOp` into the training operator.
    pub fn over(cov: Box<dyn KernelCov>, y: Vec<f64>, noise: f64, engine: Engine) -> Self {
        assert_eq!(cov.n(), y.len());
        ExactGp {
            op: AddedDiagOp::new(cov, noise),
            y,
            engine,
            plans: SolvePlanCache::new(),
            love: None,
            posterior: PosteriorCache::new(),
        }
    }

    /// Enable LOVE: predictions answer variances from a cached rank-`rank`
    /// posterior ([`crate::gp::posterior::LovePosterior`]) instead of
    /// paying a solve per predict call. Higher rank = tighter variances
    /// (exact at `rank = n`); the posterior rebuilds automatically when
    /// `set_params` changes the operator fingerprint.
    pub fn with_love_rank(mut self, rank: usize) -> Self {
        self.set_love_rank(Some(rank));
        self
    }

    /// Switch the LOVE rank (or disable LOVE with `None`) on a live model.
    pub fn set_love_rank(&mut self, rank: Option<usize>) {
        assert!(rank != Some(0), "LOVE rank must be positive");
        self.love = rank;
    }

    /// The model's posterior cache (counters observable for tests and
    /// serving logs).
    pub fn posterior_cache(&self) -> &PosteriorCache {
        &self.posterior
    }

    /// The composed training operator `K̂ = K + σ²I`.
    pub fn op(&self) -> &AddedDiagOp<Box<dyn KernelCov>> {
        &self.op
    }

    /// The noise-free covariance backend.
    pub fn cov(&self) -> &dyn KernelCov {
        self.op.inner().as_ref()
    }

    /// Row-shard count of the backend (1 for the monolithic operator).
    pub fn shard_count(&self) -> usize {
        self.op.inner().shard_count()
    }

    /// Training targets.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// The model's solve-plan cache (hit/miss/invalidation counters are
    /// observable for tests and serving logs).
    pub fn plan_cache(&self) -> &SolvePlanCache {
        &self.plans
    }

    /// Raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.op.inner().kernel().params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite all raw parameters.
    pub fn set_params(&mut self, raw: &[f64]) {
        let nk = self.op.inner().kernel().n_params();
        self.op.inner_mut().set_kernel_params(&raw[..nk]);
        self.op.set_raw_value(raw[nk]);
    }

    /// Total raw parameter count.
    pub fn n_params(&self) -> usize {
        self.op.n_params()
    }

    /// NMLL + gradient under the configured engine.
    pub fn mll_and_grad(&mut self) -> MllGrad {
        match &mut self.engine {
            Engine::Bbmm(e) => e.mll_and_grad(&self.op, &self.y),
            Engine::Cholesky => {
                let mut e = crate::gp::mll::CholeskyEngine;
                e.mll_and_grad(&self.op, &self.y)
            }
        }
    }

    /// **Batched multi-restart training** (the sweep tentpole): optimise
    /// `b = inits.len()` hyperparameter candidates for the same dataset in
    /// lockstep, ONE batched MLL + gradient evaluation — one `mbcg_batch`
    /// call — per Adam step, instead of b scalar engine calls.
    ///
    /// Candidate parameters are `[kernel params…, log σ²]` (the `kernel`
    /// argument is the template each candidate's covariance is cloned
    /// from). Each candidate owns one [`KernelCovOp`]; the candidates are
    /// lifted into the batch with [`lift_added_diag`], and each iteration
    /// the active candidates form a [`BatchOp`]:
    ///
    /// - when every active candidate currently has **identical kernel
    ///   parameters** (a noise sweep — [`crate::train::noise_grid_inits`])
    ///   the batch takes [`BatchOp::shared`], so every mBCG iteration is
    ///   one fused covariance product and the pivoted-Cholesky
    ///   preconditioner is built once for the whole batch (checked per
    ///   step: per-candidate gradients differ, so Adam drifts kernel
    ///   parameters apart after the first step and later steps take the
    ///   general path — a persistent tied-kernel mode is a ROADMAP item);
    /// - otherwise the general elementwise batch still runs one iteration
    ///   loop with per-candidate early stopping.
    ///
    /// Candidates that converge (patience) or diverge (non-finite values)
    /// drop out of the batch exactly like `mbcg_batch`'s frozen systems.
    pub fn fit_sweep(
        x: &Mat,
        y: &[f64],
        kernel: &dyn Kernel,
        inits: &[Vec<f64>],
        engine: &mut BatchBbmmEngine,
        config: TrainConfig,
    ) -> SweepReport {
        assert_eq!(x.rows(), y.len());
        let nk = kernel.n_params();
        assert!(!inits.is_empty(), "fit_sweep: empty candidate set");
        for raw in inits {
            assert_eq!(raw.len(), nk + 1, "fit_sweep: candidate must be [kernel…, log σ²]");
        }
        // one covariance operator per candidate, lifted into `K + σᵢ²I`.
        // All candidates share ONE copy of the training inputs (and the
        // cached Xᵀ/norms/r² panel) through the Arc seam — sweep memory
        // stays flat in the candidate count instead of cloning X b times.
        let x_shared = std::sync::Arc::new(x.clone());
        let mut covs: Vec<KernelCovOp> = Vec::with_capacity(inits.len());
        for raw in inits {
            let mut k = kernel.boxed_clone();
            k.set_params(&raw[..nk]);
            let cov = match covs.first() {
                Some(first) => first.share_cached(k),
                None => KernelCovOp::from_shared(std::sync::Arc::clone(&x_shared), k),
            };
            covs.push(cov);
        }
        let sigma2s: Vec<f64> = inits.iter().map(|raw| raw[nk].exp()).collect();
        let mut ops = lift_added_diag(covs, &sigma2s);
        let mut trainer = SweepTrainer::new(config, inits.to_vec());
        let _best = trainer.run(|active| {
            // push each active candidate's current raw params into its op
            for (i, raw) in active {
                ops[*i].inner_mut().set_kernel_params(&raw[..nk]);
                ops[*i].set_raw_value(raw[nk]);
            }
            // shared-covariance fast path when the active candidates'
            // kernel params coincide (σ² may differ per candidate)
            let kernel_shared = active
                .iter()
                .all(|(_, raw)| raw[..nk] == active[0].1[..nk]);
            let sig: Vec<f64> = active.iter().map(|(i, _)| ops[*i].value()).collect();
            if kernel_shared && sig.iter().all(|&s| s > 0.0 && s.is_finite()) {
                let (i0, _) = active[0];
                let cov: &dyn LinearOp = ops[i0].inner();
                let batch = BatchOp::shared(cov, sig);
                engine.mll_and_grad_batch(&batch, y)
            } else {
                let els: Vec<&dyn LinearOp> =
                    active.iter().map(|(i, _)| &ops[*i] as &dyn LinearOp).collect();
                let batch = BatchOp::new(els);
                engine.mll_and_grad_batch(&batch, y)
            }
        });
        trainer.into_report()
    }

    /// Build the model a finished sweep selected: the winner's raw
    /// parameters over the template kernel (`None` when every candidate
    /// diverged).
    pub fn from_sweep(
        x: Mat,
        y: Vec<f64>,
        kernel: &dyn Kernel,
        report: &SweepReport,
        engine: Engine,
    ) -> Option<Self> {
        let raw = report.best_params()?;
        let nk = kernel.n_params();
        let mut k = kernel.boxed_clone();
        k.set_params(&raw[..nk]);
        let mut gp = ExactGp::new(x, y, k, 1.0, engine);
        // install the exact raw noise (avoids the exp/ln round trip)
        gp.op.set_raw_value(raw[nk]);
        Some(gp)
    }

    /// Solve options matching the configured engine (the options the
    /// LOVE build's mean solve and the per-predict solve path share).
    fn solve_opts(&self) -> SolveOptions {
        match &self.engine {
            Engine::Bbmm(e) => SolveOptions {
                max_iters: e.max_cg_iters.max(50),
                tol: 1e-8,
                precond_rank: e.precond_rank,
            },
            Engine::Cholesky => SolveOptions {
                max_iters: 400,
                tol: 1e-10,
                precond_rank: 5,
            },
        }
    }

    /// Predictive mean+variance at test inputs `xs (n_test × d)`.
    pub fn predict(&mut self, xs: &Mat) -> Prediction {
        let cov = self.op.inner();
        let k_star = cov.cross(xs, cov.x());
        let diag: Vec<f64> = (0..xs.rows())
            .map(|i| cov.kernel().eval(xs.row(i), xs.row(i)))
            .collect();
        if let Some(rank) = self.love {
            // constant-time path: mean + variance from the cached LOVE
            // posterior, rebuilt only when the fingerprint or rank moves
            let opts = self.solve_opts();
            let post = self
                .posterior
                .get_or_build("exact-gp", &self.op, &self.y, rank, &opts);
            return post.predict(&k_star, &diag);
        }
        match &mut self.engine {
            Engine::Cholesky => {
                let ch =
                    Cholesky::new_with_jitter(&self.op.dense()).expect("kernel matrix not PD");
                predict(&k_star, &diag, |m| ch.solve_mat(m), &self.y)
            }
            Engine::Bbmm(e) => {
                let opts = SolveOptions {
                    max_iters: e.max_cg_iters.max(50),
                    tol: 1e-8,
                    precond_rank: e.precond_rank,
                };
                // plan looked up by content fingerprint: first predict
                // builds the preconditioner, later predicts reuse it
                let plan = self.plans.get_or_plan("exact-gp", &self.op, &opts);
                predict_with_plan(&self.op, &k_star, &diag, &self.y, &plan, &opts)
            }
        }
    }

    /// Draw `n_samples` correlated posterior samples at test inputs `xs`
    /// from the cached LOVE root (building it on first use — rank
    /// defaults to `min(n, 64)` when LOVE was not explicitly enabled).
    /// Returns an `n_test × n_samples` matrix whose columns are draws.
    pub fn sample_posterior(&mut self, xs: &Mat, n_samples: usize, seed: u64) -> Mat {
        let rank = self.love.unwrap_or_else(|| self.y.len().min(64));
        let opts = self.solve_opts();
        let cov = self.op.inner();
        let k_star = cov.cross(xs, cov.x());
        let prior = cov.cross(xs, xs);
        let post = self
            .posterior
            .get_or_build("exact-gp", &self.op, &self.y, rank, &opts);
        let mut rng = Rng::new(seed);
        post.sample(&k_star, &prior, n_samples, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::predict::mae;
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let f = |x: &[f64]| (3.0 * x[0]).sin() + 0.5 * (2.0 * x[1]).cos();
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| f(x.row(i)) + 0.05 * rng.normal()).collect();
        let xt = Mat::from_fn(50, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let yt: Vec<f64> = (0..50).map(|i| f(xt.row(i))).collect();
        (x, y, xt, yt)
    }

    #[test]
    fn bbmm_and_cholesky_predictions_agree() {
        let (x, y, xt, _yt) = dataset(120, 1);
        let mut chol = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut bbmm = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 1)),
        );
        let pc = chol.predict(&xt);
        let pb = bbmm.predict(&xt);
        for i in 0..xt.rows() {
            assert!(
                (pc.mean[i] - pb.mean[i]).abs() < 1e-4,
                "mean {i}: {} vs {}",
                pc.mean[i],
                pb.mean[i]
            );
            assert!((pc.var[i] - pb.var[i]).abs() < 1e-3, "var {i}");
        }
    }

    #[test]
    fn exact_gp_fits_smooth_function() {
        let (x, y, xt, yt) = dataset(200, 2);
        let mut gp = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::default()),
        );
        let pred = gp.predict(&xt);
        let err = mae(&pred.mean, &yt);
        assert!(err < 0.1, "mae={err}");
    }

    #[test]
    fn sharded_exact_gp_matches_dense_exact_gp() {
        // same engine seed + numerically identical operators ⇒ the sharded
        // model reproduces the dense model's training terms and posterior
        let (x, y, xt, _yt) = dataset(100, 4);
        let mut dense = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 7)),
        );
        let mut sharded = ExactGp::new_sharded(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 7)),
            6,
        );
        assert_eq!(dense.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 6);
        let a = dense.mll_and_grad();
        let b = sharded.mll_and_grad();
        assert!((a.nmll - b.nmll).abs() < 1e-8, "{} vs {}", a.nmll, b.nmll);
        for p in 0..dense.n_params() {
            assert!((a.grad[p] - b.grad[p]).abs() < 1e-8, "grad {p}");
        }
        let pa = dense.predict(&xt);
        let pb = sharded.predict(&xt);
        for i in 0..xt.rows() {
            assert!((pa.mean[i] - pb.mean[i]).abs() < 1e-8, "mean {i}");
            assert!((pa.var[i] - pb.var[i]).abs() < 1e-8, "var {i}");
        }
    }

    #[test]
    fn predict_reuses_the_cached_plan_until_params_change() {
        let (x, y, xt, _yt) = dataset(80, 5);
        let mut gp = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::default()),
        );
        let p1 = gp.predict(&xt);
        let p2 = gp.predict(&xt);
        assert_eq!(gp.plan_cache().misses(), 1);
        assert_eq!(gp.plan_cache().hits(), 1);
        for i in 0..xt.rows() {
            assert_eq!(p1.mean[i], p2.mean[i], "cached plan must not change results");
        }
        // hyperparameter update → fingerprint changes → plan rebuilt once
        let mut raw = gp.params();
        raw[0] += 0.2;
        gp.set_params(&raw);
        let _ = gp.predict(&xt);
        assert_eq!(gp.plan_cache().invalidations(), 1);
    }

    #[test]
    fn love_predictions_match_solve_path_and_cache_rebuilds_on_set_params() {
        let (x, y, xt, _yt) = dataset(90, 6);
        let mut solve_gp = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(200, 10, 5, 1)),
        );
        let n = y.len();
        let mut love_gp = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(200, 10, 5, 1)),
        )
        .with_love_rank(n); // full rank ⇒ exact
        let ps = solve_gp.predict(&xt);
        let pl = love_gp.predict(&xt);
        for i in 0..xt.rows() {
            assert!((ps.mean[i] - pl.mean[i]).abs() < 1e-5, "mean {i}");
            assert!((ps.var[i] - pl.var[i]).abs() < 1e-5, "var {i}");
        }
        // repeated predicts hit the cached posterior
        let _ = love_gp.predict(&xt);
        assert_eq!(love_gp.posterior_cache().misses(), 1);
        assert_eq!(love_gp.posterior_cache().hits(), 1);
        // hyperparameter change → fingerprint moves → posterior rebuilt
        let mut raw = love_gp.params();
        raw[0] += 0.2;
        love_gp.set_params(&raw);
        let _ = love_gp.predict(&xt);
        assert_eq!(love_gp.posterior_cache().invalidations(), 1);
        // sampling from the cached root has posterior-consistent moments
        let draws = love_gp.sample_posterior(&xt, 800, 7);
        let pred = love_gp.predict(&xt);
        for i in 0..3 {
            let row = draws.row(i);
            let emp = row.iter().sum::<f64>() / 800.0;
            assert!((emp - pred.mean[i]).abs() < 0.1, "sample mean {i}");
        }
    }

    #[test]
    fn mll_decreases_with_better_hyperparameters() {
        // moving lengthscale toward the data-generating scale lowers nmll
        let (x, y, _xt, _yt) = dataset(100, 3);
        let mut bad = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(5.0, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut good = ExactGp::new(x, y, Box::new(Rbf::new(0.5, 1.0)), 0.05, Engine::Cholesky);
        assert!(good.mll_and_grad().nmll < bad.mll_and_grad().nmll);
    }
}
