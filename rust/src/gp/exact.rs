//! Exact GP regression model — ties a [`DenseKernelOp`] to targets and an
//! inference engine (BBMM or Cholesky), exposing train-time NMLL/gradients
//! and test-time predictions. This is the model behind the paper's "Exact"
//! columns in Figures 2 and 3.

use crate::gp::mll::{BbmmEngine, InferenceEngine, MllGrad};
use crate::gp::predict::{predict, Prediction};
use crate::kernels::{DenseKernelOp, Kernel, KernelOperator};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mbcg::{mbcg, MbcgOptions};
use crate::tensor::Mat;

/// Which inference engine backs the model.
pub enum Engine {
    /// Blackbox matrix-matrix inference (the paper's method)
    Bbmm(BbmmEngine),
    /// Dense Cholesky baseline
    Cholesky,
}

/// Exact Gaussian-process regression model.
pub struct ExactGp {
    op: DenseKernelOp,
    y: Vec<f64>,
    engine: Engine,
}

impl ExactGp {
    pub fn new(x: Mat, y: Vec<f64>, kernel: Box<dyn Kernel>, noise: f64, engine: Engine) -> Self {
        assert_eq!(x.rows(), y.len());
        ExactGp {
            op: DenseKernelOp::new(x, kernel, noise),
            y,
            engine,
        }
    }

    pub fn op(&self) -> &DenseKernelOp {
        &self.op
    }

    pub fn y(&self) -> &[f64] {
        &self.y
    }

    pub fn params(&self) -> Vec<f64> {
        self.op.params()
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        self.op.set_params(raw);
    }

    pub fn n_params(&self) -> usize {
        self.op.n_params()
    }

    /// NMLL + gradient under the configured engine.
    pub fn mll_and_grad(&mut self) -> MllGrad {
        match &mut self.engine {
            Engine::Bbmm(e) => e.mll_and_grad(&self.op, &self.y),
            Engine::Cholesky => {
                let mut e = crate::gp::mll::CholeskyEngine;
                e.mll_and_grad(&self.op, &self.y)
            }
        }
    }

    /// Predictive mean+variance at test inputs `xs (n_test × d)`.
    pub fn predict(&mut self, xs: &Mat) -> Prediction {
        let k_star = self.op.cross(xs, self.op.x());
        let diag: Vec<f64> = (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect();
        match &mut self.engine {
            Engine::Cholesky => {
                let ch = Cholesky::new_with_jitter(&self.op.dense())
                    .expect("kernel matrix not PD");
                predict(&k_star, &diag, |m| ch.solve_mat(m), &self.y)
            }
            Engine::Bbmm(e) => {
                let precond = e.build_preconditioner(&self.op);
                let max_iters = e.max_cg_iters.max(50);
                let op = &self.op;
                predict(
                    &k_star,
                    &diag,
                    |m| {
                        let o = MbcgOptions {
                            max_iters,
                            tol: 1e-8,
                            n_solve_only: m.cols(), // tridiags unused at predict time
                        };
                        mbcg(|v| op.matmul(v), m, |r| precond.solve_mat(r), &o).solves
                    },
                    &self.y,
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::predict::mae;
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let f = |x: &[f64]| (3.0 * x[0]).sin() + 0.5 * (2.0 * x[1]).cos();
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| f(x.row(i)) + 0.05 * rng.normal()).collect();
        let xt = Mat::from_fn(50, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let yt: Vec<f64> = (0..50).map(|i| f(xt.row(i))).collect();
        (x, y, xt, yt)
    }

    #[test]
    fn bbmm_and_cholesky_predictions_agree() {
        let (x, y, xt, _yt) = dataset(120, 1);
        let mut chol = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut bbmm = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 1)),
        );
        let pc = chol.predict(&xt);
        let pb = bbmm.predict(&xt);
        for i in 0..xt.rows() {
            assert!(
                (pc.mean[i] - pb.mean[i]).abs() < 1e-4,
                "mean {i}: {} vs {}",
                pc.mean[i],
                pb.mean[i]
            );
            assert!((pc.var[i] - pb.var[i]).abs() < 1e-3, "var {i}");
        }
    }

    #[test]
    fn exact_gp_fits_smooth_function() {
        let (x, y, xt, yt) = dataset(200, 2);
        let mut gp = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::default()),
        );
        let pred = gp.predict(&xt);
        let err = mae(&pred.mean, &yt);
        assert!(err < 0.1, "mae={err}");
    }

    #[test]
    fn mll_decreases_with_better_hyperparameters() {
        // moving lengthscale toward the data-generating scale lowers nmll
        let (x, y, _xt, _yt) = dataset(100, 3);
        let mut bad = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(5.0, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut good = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        assert!(good.mll_and_grad().nmll < bad.mll_and_grad().nmll);
    }
}
