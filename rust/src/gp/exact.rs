//! Exact GP regression model — ties a kernel operator (the monolithic
//! [`DenseKernelOp`] or the row-sharded [`ShardedKernelOp`]) to targets and
//! an inference engine (BBMM or Cholesky), exposing train-time
//! NMLL/gradients and test-time predictions. This is the model behind the
//! paper's "Exact" columns in Figures 2 and 3.

use crate::gp::mll::{BbmmEngine, InferenceEngine, MllGrad};
use crate::gp::predict::{predict, Prediction};
use crate::kernels::{DenseKernelOp, Kernel, KernelOperator, ShardedKernelOp};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mbcg::{mbcg, MbcgOptions};
use crate::tensor::Mat;

/// Which inference engine backs the model.
pub enum Engine {
    /// Blackbox matrix-matrix inference (the paper's method)
    Bbmm(BbmmEngine),
    /// Dense Cholesky baseline
    Cholesky,
}

/// The operator backing an exact GP: the monolithic fused operator or its
/// row-sharded variant. Both expose the same blackbox surface, so every
/// engine works with either — this enum only carries the constructor
/// choice plus the concrete accessors predictions need.
pub enum ExactOp {
    Dense(DenseKernelOp),
    Sharded(ShardedKernelOp),
}

impl ExactOp {
    /// The blackbox view every inference engine consumes.
    pub fn as_operator(&self) -> &dyn KernelOperator {
        match self {
            ExactOp::Dense(op) => op,
            ExactOp::Sharded(op) => op,
        }
    }

    pub fn x(&self) -> &Mat {
        match self {
            ExactOp::Dense(op) => op.x(),
            ExactOp::Sharded(op) => op.x(),
        }
    }

    pub fn kernel(&self) -> &dyn Kernel {
        match self {
            ExactOp::Dense(op) => op.kernel(),
            ExactOp::Sharded(op) => op.kernel(),
        }
    }

    pub fn cross(&self, a: &Mat, b: &Mat) -> Mat {
        match self {
            ExactOp::Dense(op) => op.cross(a, b),
            ExactOp::Sharded(op) => op.cross(a, b),
        }
    }

    pub fn params(&self) -> Vec<f64> {
        match self {
            ExactOp::Dense(op) => op.params(),
            ExactOp::Sharded(op) => op.params(),
        }
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        match self {
            ExactOp::Dense(op) => op.set_params(raw),
            ExactOp::Sharded(op) => op.set_params(raw),
        }
    }

    /// Shard count (1 for the monolithic operator).
    pub fn shard_count(&self) -> usize {
        match self {
            ExactOp::Dense(_) => 1,
            ExactOp::Sharded(op) => op.shard_count(),
        }
    }
}

/// Exact Gaussian-process regression model.
pub struct ExactGp {
    op: ExactOp,
    y: Vec<f64>,
    engine: Engine,
}

impl ExactGp {
    pub fn new(x: Mat, y: Vec<f64>, kernel: Box<dyn Kernel>, noise: f64, engine: Engine) -> Self {
        assert_eq!(x.rows(), y.len());
        ExactGp {
            op: ExactOp::Dense(DenseKernelOp::new(x, kernel, noise)),
            y,
            engine,
        }
    }

    /// Like [`ExactGp::new`], but over a row-sharded operator — the
    /// configuration the serving path uses to size shards to traffic.
    pub fn new_sharded(
        x: Mat,
        y: Vec<f64>,
        kernel: Box<dyn Kernel>,
        noise: f64,
        engine: Engine,
        shards: usize,
    ) -> Self {
        assert_eq!(x.rows(), y.len());
        ExactGp {
            op: ExactOp::Sharded(ShardedKernelOp::new(x, kernel, noise, shards)),
            y,
            engine,
        }
    }

    pub fn op(&self) -> &ExactOp {
        &self.op
    }

    pub fn y(&self) -> &[f64] {
        &self.y
    }

    pub fn params(&self) -> Vec<f64> {
        self.op.params()
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        self.op.set_params(raw);
    }

    pub fn n_params(&self) -> usize {
        self.op.as_operator().n_params()
    }

    /// NMLL + gradient under the configured engine.
    pub fn mll_and_grad(&mut self) -> MllGrad {
        match &mut self.engine {
            Engine::Bbmm(e) => e.mll_and_grad(self.op.as_operator(), &self.y),
            Engine::Cholesky => {
                let mut e = crate::gp::mll::CholeskyEngine;
                e.mll_and_grad(self.op.as_operator(), &self.y)
            }
        }
    }

    /// Predictive mean+variance at test inputs `xs (n_test × d)`.
    pub fn predict(&mut self, xs: &Mat) -> Prediction {
        let k_star = self.op.cross(xs, self.op.x());
        let diag: Vec<f64> = (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect();
        match &mut self.engine {
            Engine::Cholesky => {
                let ch = Cholesky::new_with_jitter(&self.op.as_operator().dense())
                    .expect("kernel matrix not PD");
                predict(&k_star, &diag, |m| ch.solve_mat(m), &self.y)
            }
            Engine::Bbmm(e) => {
                let op = self.op.as_operator();
                let precond = e.build_preconditioner(op);
                let max_iters = e.max_cg_iters.max(50);
                predict(
                    &k_star,
                    &diag,
                    |m| {
                        let o = MbcgOptions {
                            max_iters,
                            tol: 1e-8,
                            n_solve_only: m.cols(), // tridiags unused at predict time
                        };
                        mbcg(|v| op.matmul(v), m, |r| precond.solve_mat(r), &o).solves
                    },
                    &self.y,
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::predict::mae;
    use crate::kernels::Rbf;
    use crate::util::Rng;

    fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let f = |x: &[f64]| (3.0 * x[0]).sin() + 0.5 * (2.0 * x[1]).cos();
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| f(x.row(i)) + 0.05 * rng.normal()).collect();
        let xt = Mat::from_fn(50, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let yt: Vec<f64> = (0..50).map(|i| f(xt.row(i))).collect();
        (x, y, xt, yt)
    }

    #[test]
    fn bbmm_and_cholesky_predictions_agree() {
        let (x, y, xt, _yt) = dataset(120, 1);
        let mut chol = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut bbmm = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 1)),
        );
        let pc = chol.predict(&xt);
        let pb = bbmm.predict(&xt);
        for i in 0..xt.rows() {
            assert!(
                (pc.mean[i] - pb.mean[i]).abs() < 1e-4,
                "mean {i}: {} vs {}",
                pc.mean[i],
                pb.mean[i]
            );
            assert!((pc.var[i] - pb.var[i]).abs() < 1e-3, "var {i}");
        }
    }

    #[test]
    fn exact_gp_fits_smooth_function() {
        let (x, y, xt, yt) = dataset(200, 2);
        let mut gp = ExactGp::new(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::default()),
        );
        let pred = gp.predict(&xt);
        let err = mae(&pred.mean, &yt);
        assert!(err < 0.1, "mae={err}");
    }

    #[test]
    fn sharded_exact_gp_matches_dense_exact_gp() {
        // same engine seed + numerically identical operators ⇒ the sharded
        // model reproduces the dense model's training terms and posterior
        let (x, y, xt, _yt) = dataset(100, 4);
        let mut dense = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 7)),
        );
        let mut sharded = ExactGp::new_sharded(
            x,
            y,
            Box::new(Rbf::new(0.5, 1.0)),
            0.05,
            Engine::Bbmm(BbmmEngine::new(100, 10, 5, 7)),
            6,
        );
        assert_eq!(dense.op().shard_count(), 1);
        assert_eq!(sharded.op().shard_count(), 6);
        let a = dense.mll_and_grad();
        let b = sharded.mll_and_grad();
        assert!((a.nmll - b.nmll).abs() < 1e-8, "{} vs {}", a.nmll, b.nmll);
        for p in 0..dense.n_params() {
            assert!((a.grad[p] - b.grad[p]).abs() < 1e-8, "grad {p}");
        }
        let pa = dense.predict(&xt);
        let pb = sharded.predict(&xt);
        for i in 0..xt.rows() {
            assert!((pa.mean[i] - pb.mean[i]).abs() < 1e-8, "mean {i}");
            assert!((pa.var[i] - pb.var[i]).abs() < 1e-8, "var {i}");
        }
    }

    #[test]
    fn mll_decreases_with_better_hyperparameters() {
        // moving lengthscale toward the data-generating scale lowers nmll
        let (x, y, _xt, _yt) = dataset(100, 3);
        let mut bad = ExactGp::new(
            x.clone(),
            y.clone(),
            Box::new(Rbf::new(5.0, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let mut good = ExactGp::new(x, y, Box::new(Rbf::new(0.5, 1.0)), 0.05, Engine::Cholesky);
        assert!(good.mll_and_grad().nmll < bad.mll_and_grad().nmll);
    }
}
