//! Marginal log-likelihood engines.
//!
//! The negative log marginal likelihood (paper eq. 2) and its gradient:
//!
//! ```text
//! L(θ) = ½ [ yᵀK̂⁻¹y + log|K̂| + n·log 2π ]
//! dL/dθ = ½ [ −(K̂⁻¹y)ᵀ (dK̂/dθ) (K̂⁻¹y) + Tr(K̂⁻¹ dK̂/dθ) ]
//! ```
//!
//! [`BbmmEngine`] derives all three quantities from **one** mBCG call
//! (paper §4); [`CholeskyEngine`] computes them exactly in O(n³).
//!
//! Both consume the composable [`LinearOp`] — any operator composition
//! (exact, SGPR, SKI, sharded, multitask, …) flows through unchanged.
//!
//! The **batch axis** extends the single-call promise across a whole
//! hyperparameter sweep: [`BatchBbmmEngine`] evaluates b candidates'
//! nmll + gradients through **one** [`mbcg_batch_stats`] call per
//! optimisation step ([`BatchInferenceEngine`]); the scalar
//! [`BbmmEngine`] is the b = 1 case of the same core.

use crate::linalg::mbcg::{mbcg_batch_stats, MbcgBatchStats, MbcgOptions};
use crate::linalg::op::{build_preconditioner_batch, BatchOp, LinearOp};
use crate::linalg::preconditioner::Preconditioner;
use crate::linalg::trace::paired_trace;
use crate::linalg::tridiag::SymTridiagEig;
use crate::tensor::Mat;
use crate::util::Rng;

const LN_2PI: f64 = 1.8378770664093453;

/// Negative mll value + gradient wrt raw parameters, with diagnostics.
#[derive(Debug, Clone)]
pub struct MllGrad {
    /// negative log marginal likelihood (lower is better)
    pub nmll: f64,
    /// d nmll / d raw-param
    pub grad: Vec<f64>,
    /// CG / factorization iterations used
    pub iterations: usize,
    /// log|K̂| as estimated/computed (diagnostics; Fig. ablation A2)
    pub logdet: f64,
    /// data-fit term yᵀK̂⁻¹y
    pub datafit: f64,
}

/// An inference engine: computes the nmll and gradient for a blackbox
/// linear operator and training targets.
pub trait InferenceEngine {
    fn mll_and_grad(&mut self, op: &dyn LinearOp, y: &[f64]) -> MllGrad;
    fn name(&self) -> &'static str;
}

/// **BBMM** (paper §4): all inference terms from a single mBCG call.
pub struct BbmmEngine {
    /// maximum CG iterations p (paper default 20)
    pub max_cg_iters: usize,
    /// CG relative-residual tolerance
    pub cg_tol: f64,
    /// number of probe vectors t (paper default 10)
    pub n_probes: usize,
    /// pivoted-Cholesky preconditioner rank k (paper default 5; 0 disables)
    pub precond_rank: usize,
    /// RNG for probe draws (kept so successive calls use fresh probes)
    pub rng: Rng,
}

impl Default for BbmmEngine {
    fn default() -> Self {
        BbmmEngine {
            max_cg_iters: 20,
            cg_tol: 1e-10,
            n_probes: 10,
            precond_rank: 5,
            rng: Rng::new(0x5EED),
        }
    }
}

impl BbmmEngine {
    pub fn new(max_cg_iters: usize, n_probes: usize, precond_rank: usize, seed: u64) -> Self {
        BbmmEngine {
            max_cg_iters,
            cg_tol: 1e-10,
            n_probes,
            precond_rank,
            rng: Rng::new(seed),
        }
    }

    /// Build the §4.1 preconditioner for the operator (rank 0 → identity):
    /// rank-k pivoted Cholesky over the operator's noise-free part, via the
    /// generic [`crate::linalg::op::build_preconditioner`] dispatcher.
    pub fn build_preconditioner(&self, op: &dyn LinearOp) -> Box<dyn Preconditioner> {
        crate::linalg::op::build_preconditioner(op, self.precond_rank)
    }
}

impl InferenceEngine for BbmmEngine {
    /// The scalar engine **is** the b = 1 case of the batched core: one
    /// single-element [`BatchOp`] flows through the same shared core as
    /// [`BatchBbmmEngine`] (numerics identical to a standalone mBCG run —
    /// the single-system batch performs the same products in the same
    /// order, so pre-batch-era results are reproduced). Gradients are
    /// taken on `op` itself, so operators with custom `dmatmul` math
    /// (e.g. SGPR) keep their exact gradient surface.
    fn mll_and_grad(&mut self, op: &dyn LinearOp, y: &[f64]) -> MllGrad {
        let batch = BatchOp::new(vec![op]);
        let (mut out, _stats) = bbmm_mll_and_grad_core(
            &batch,
            Some(&[op]),
            y,
            &mut self.rng,
            self.max_cg_iters,
            self.cg_tol,
            self.n_probes,
            self.precond_rank,
        );
        out.pop().expect("b = 1 core returns one result")
    }

    fn name(&self) -> &'static str {
        "bbmm"
    }
}

/// A **batched** inference engine: negative mll + gradient for every
/// element of a [`BatchOp`] against shared training targets — the
/// evaluation unit of a hyperparameter sweep's lockstep optimisation step
/// ([`crate::train::SweepTrainer`]).
pub trait BatchInferenceEngine {
    /// One nmll + gradient per batch element, in element order.
    fn mll_and_grad_batch(&mut self, batch: &BatchOp<'_>, y: &[f64]) -> Vec<MllGrad>;
    /// Engine name for logs.
    fn name(&self) -> &'static str;
}

/// **Batched BBMM** (paper §4, extended across operators): all training
/// terms for b hyperparameter candidates from **one**
/// [`mbcg_batch_stats`] call per step.
///
/// Per-element probes are drawn element-by-element from ONE shared RNG
/// stream, so element i of a batch call reproduces — to the bit — the
/// i-th sequential [`BbmmEngine::mll_and_grad`] call on an engine seeded
/// identically (the parity contract the sweep tests pin down).
///
/// On the shared-covariance fast path (`K + σᵢ²I` over one covariance:
/// [`BatchOp::shared`] or a noise sweep built with
/// [`crate::linalg::op::lift_added_diag`] over one inner), three costs
/// amortise across the batch:
/// - the rank-k pivoted-Cholesky preconditioner factor is built **once**
///   ([`build_preconditioner_batch`]),
/// - every mBCG iteration is one fused `K·[D₁ … D_b]` product,
/// - each kernel-parameter gradient pass is one fused
///   `dK·[u₀⁽¹⁾ W⁽¹⁾ … u₀⁽ᵇ⁾ W⁽ᵇ⁾]` product.
///
/// General batches (per-candidate kernel hyperparameters, so b distinct
/// covariances) still run one iteration loop with per-system early
/// stopping; gradients go through each element's own `dmatmul`.
pub struct BatchBbmmEngine {
    /// maximum CG iterations p (paper default 20)
    pub max_cg_iters: usize,
    /// CG relative-residual tolerance
    pub cg_tol: f64,
    /// number of probe vectors t per element (paper default 10)
    pub n_probes: usize,
    /// pivoted-Cholesky preconditioner rank k (paper default 5; 0 disables)
    pub precond_rank: usize,
    /// shared probe RNG (advances across calls: fresh probes per step)
    pub rng: Rng,
    /// operator-product accounting from the most recent batch call
    pub last_stats: MbcgBatchStats,
}

impl Default for BatchBbmmEngine {
    fn default() -> Self {
        BatchBbmmEngine::new(20, 10, 5, 0x5EED)
    }
}

impl BatchBbmmEngine {
    /// Engine with the paper-style knobs (mirrors [`BbmmEngine::new`]).
    pub fn new(max_cg_iters: usize, n_probes: usize, precond_rank: usize, seed: u64) -> Self {
        BatchBbmmEngine {
            max_cg_iters,
            cg_tol: 1e-10,
            n_probes,
            precond_rank,
            rng: Rng::new(seed),
            last_stats: MbcgBatchStats::default(),
        }
    }

    /// [`BatchInferenceEngine::mll_and_grad_batch`] with explicit
    /// per-element **gradient operators**: solves run through `batch`,
    /// but element i's `n_params`/`dmatmul` come from `grad_ops[i]`. Use
    /// this when elements are named wrappers with custom gradient math
    /// (SGPR) — the batch's structural representation (in particular the
    /// shared-covariance collapse of a single-element batch) must not
    /// replace their derivative surface.
    pub fn mll_and_grad_batch_on(
        &mut self,
        batch: &BatchOp<'_>,
        grad_ops: &[&dyn LinearOp],
        y: &[f64],
    ) -> Vec<MllGrad> {
        let (out, stats) = bbmm_mll_and_grad_core(
            batch,
            Some(grad_ops),
            y,
            &mut self.rng,
            self.max_cg_iters,
            self.cg_tol,
            self.n_probes,
            self.precond_rank,
        );
        self.last_stats = stats;
        out
    }
}

impl BatchInferenceEngine for BatchBbmmEngine {
    fn mll_and_grad_batch(&mut self, batch: &BatchOp<'_>, y: &[f64]) -> Vec<MllGrad> {
        let (out, stats) = bbmm_mll_and_grad_core(
            batch,
            None,
            y,
            &mut self.rng,
            self.max_cg_iters,
            self.cg_tol,
            self.n_probes,
            self.precond_rank,
        );
        self.last_stats = stats;
        out
    }

    fn name(&self) -> &'static str {
        "bbmm-batch"
    }
}

/// Sequential fallback: evaluate every batch element through a scalar
/// [`InferenceEngine`] — the baseline the batched engine is benchmarked
/// (and parity-tested) against, and the path non-BBMM engines (Cholesky,
/// Dong) take in a sweep.
pub fn mll_and_grad_batch_with(
    engine: &mut dyn InferenceEngine,
    batch: &BatchOp<'_>,
    y: &[f64],
) -> Vec<MllGrad> {
    (0..batch.len())
        .map(|i| batch.with_element(i, |op| engine.mll_and_grad(op, y)))
        .collect()
}

/// The shared BBMM core (scalar engine = b = 1): preconditioners via
/// [`build_preconditioner_batch`] (one pivoted-Cholesky factor on the
/// shared-covariance path), per-element probe draws from one RNG stream,
/// ONE batched mBCG call, then per-element SLQ log-det + paired-trace
/// gradients.
///
/// `grad_ops`, when given, supplies the operator each element's gradient
/// is taken on (`n_params`/`dmatmul`) — the scalar engine passes the
/// original operator so named wrappers with custom gradient math (SGPR)
/// bypass the batch's structural view. When `None`, gradients run on the
/// batch's own elements; on the shared-covariance representation those
/// are `cov + σᵢ²I` views, which makes the fused kernel-gradient pass
/// exact by construction.
#[allow(clippy::too_many_arguments)]
fn bbmm_mll_and_grad_core(
    batch: &BatchOp<'_>,
    grad_ops: Option<&[&dyn LinearOp]>,
    y: &[f64],
    rng: &mut Rng,
    max_cg_iters: usize,
    cg_tol: f64,
    n_probes: usize,
    precond_rank: usize,
) -> (Vec<MllGrad>, MbcgBatchStats) {
    let b = batch.len();
    let n = batch.n();
    assert_eq!(y.len(), n);
    if let Some(ops) = grad_ops {
        assert_eq!(ops.len(), b, "grad_ops must match the batch length");
    }
    let t = n_probes;

    // §4.1 preconditioners: ONE pivoted-Cholesky factor serves the whole
    // batch on the shared-covariance path (per-element σ² capacitance).
    let preconds = build_preconditioner_batch(batch, precond_rank);

    // Per-element RHS [y  z₁ … z_t]; probes ~ N(0, P̂ᵢ) when preconditioned
    // (Rademacher when not), drawn element-by-element from the one shared
    // RNG stream — the sequential-parity contract.
    let mut zs: Vec<Mat> = Vec::with_capacity(b);
    let mut bs: Vec<Mat> = Vec::with_capacity(b);
    for pre in &preconds {
        let z = pre.sample_probes(n, t, rng);
        let mut rhs = Mat::zeros(n, 1 + t);
        rhs.set_col(0, y);
        for c in 0..t {
            rhs.set_col(1 + c, &z.col(c));
        }
        zs.push(z);
        bs.push(rhs);
    }
    let b_refs: Vec<&Mat> = bs.iter().collect();
    fn upcast(p: &(dyn Preconditioner + Send)) -> &dyn Preconditioner {
        p
    }
    let pre_refs: Vec<&dyn Preconditioner> = preconds.iter().map(|p| upcast(p.as_ref())).collect();

    // THE single batched mBCG call (paper §4 across the whole sweep):
    // per-element solves + probe solves + tridiagonals together.
    let (results, stats) = mbcg_batch_stats(
        batch,
        &b_refs,
        &pre_refs,
        &MbcgOptions {
            max_iters: max_cg_iters,
            tol: cg_tol,
            n_solve_only: 1,
        },
    );

    // Per-element value terms: SLQ log-det (eq. 6) + preconditioner
    // correction (§4.1), deterministic data fit.
    let mut out: Vec<MllGrad> = Vec::with_capacity(b);
    let mut u0s: Vec<Vec<f64>> = Vec::with_capacity(b);
    let mut solves_zs: Vec<Mat> = Vec::with_capacity(b);
    let mut ws: Vec<Mat> = Vec::with_capacity(b);
    for (i, res) in results.iter().enumerate() {
        let u0 = res.solves.col(0); // K̂ᵢ⁻¹ y
        let solves_z = res.solves.cols_range(1, 1 + t); // K̂ᵢ⁻¹ Zᵢ
        let w = preconds[i].solve_mat(&zs[i]); // P̂ᵢ⁻¹ Zᵢ (identity → Zᵢ)
        let mut logdet_quad = 0.0;
        for (c, tri) in res.tridiags.iter().enumerate() {
            if tri.n() == 0 {
                continue;
            }
            let scale = col_dot(&zs[i], &w, c);
            let eig = SymTridiagEig::new(&tri.diag, &tri.offdiag);
            logdet_quad += scale * eig.log_quadrature();
        }
        let logdet = logdet_quad / t as f64 + preconds[i].logdet();
        let datafit: f64 = y.iter().zip(u0.iter()).map(|(a, b)| a * b).sum();
        let nmll = 0.5 * (datafit + logdet + n as f64 * LN_2PI);
        out.push(MllGrad {
            nmll,
            grad: Vec::new(),
            iterations: res.iterations,
            logdet,
            datafit,
        });
        u0s.push(u0);
        solves_zs.push(solves_z);
        ws.push(w);
    }

    // Gradients: dL/dθ = ½[ −u₀ᵀ dK̂ u₀ + Tr(K̂⁻¹ dK̂) ], trace via paired
    // probes (eq. 4): mean_c (K̂⁻¹z_c)ᵀ dK̂ (P̂⁻¹z_c).
    match (grad_ops, batch.shared_parts()) {
        (None, Some((cov, sigma2s))) => {
            // Shared covariance ⇒ dK̂ᵢ/dθ_kernel ≡ dK/dθ for every element:
            // ONE fused dK·[u₀⁽¹⁾ W⁽¹⁾ … u₀⁽ᵇ⁾ W⁽ᵇ⁾] pass per kernel
            // parameter (column-for-column identical to the elementwise
            // products), then the σᵢ²-diagonal gradient elementwise.
            let nk = cov.n_params();
            let width = 1 + t;
            let mut block = Mat::zeros(n, b * width);
            for i in 0..b {
                let c0 = i * width;
                block.set_col(c0, &u0s[i]);
                for c in 0..t {
                    block.set_col(c0 + 1 + c, &ws[i].col(c));
                }
            }
            for p in 0..nk {
                let dk = cov.dmatmul(p, &block);
                for i in 0..b {
                    let c0 = i * width;
                    let quad: f64 = (0..n).map(|r| u0s[i][r] * dk.get(r, c0)).sum();
                    let dk_w = dk.cols_range(c0 + 1, c0 + width);
                    let tr = paired_trace(&solves_zs[i], &dk_w);
                    out[i].grad.push(0.5 * (-quad + tr));
                }
            }
            // noise parameter (last, crate-wide convention):
            // dK̂ᵢ/d(log σᵢ²) = σᵢ²·I
            for i in 0..b {
                let s2 = sigma2s[i];
                let quad: f64 = u0s[i].iter().map(|v| (s2 * v) * v).sum();
                let mut tr = 0.0;
                for c in 0..t {
                    for r in 0..n {
                        tr += solves_zs[i].get(r, c) * (s2 * ws[i].get(r, c));
                    }
                }
                out[i].grad.push(0.5 * (-quad + tr / t as f64));
            }
        }
        _ => {
            // General path: each element's own gradient surface.
            for i in 0..b {
                out[i].grad = match grad_ops {
                    Some(ops) => element_grad(ops[i], &u0s[i], &ws[i], &solves_zs[i]),
                    None => batch
                        .with_element(i, |op| element_grad(op, &u0s[i], &ws[i], &solves_zs[i])),
                };
            }
        }
    }

    (out, stats)
}

/// One element's gradient: per-parameter `dK̂·u₀` quadratic plus the
/// paired-trace term against that element's probe solves.
fn element_grad(op: &dyn LinearOp, u0: &[f64], w: &Mat, solves_z: &Mat) -> Vec<f64> {
    let n = u0.len();
    let u0_mat = Mat::col_from_slice(u0);
    let n_params = op.n_params();
    let mut grad = Vec::with_capacity(n_params);
    for p in 0..n_params {
        let dk_u0 = op.dmatmul(p, &u0_mat);
        let quad: f64 = (0..n).map(|r| u0[r] * dk_u0.get(r, 0)).sum();
        let dk_w = op.dmatmul(p, w);
        let tr = paired_trace(solves_z, &dk_w);
        grad.push(0.5 * (-quad + tr));
    }
    grad
}

/// Exact Cholesky engine — the paper's baseline (O(n³) factor, exact trace).
pub struct CholeskyEngine;

impl InferenceEngine for CholeskyEngine {
    fn mll_and_grad(&mut self, op: &dyn LinearOp, y: &[f64]) -> MllGrad {
        let n = op.n();
        let k_hat = op.dense();
        let ch = crate::linalg::cholesky::Cholesky::new_with_jitter(&k_hat)
            .expect("kernel matrix not PD even with jitter");
        let alpha = ch.solve_vec(y);
        let datafit: f64 = y.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        let logdet = ch.logdet();
        let nmll = 0.5 * (datafit + logdet + n as f64 * LN_2PI);

        // exact gradients: dL/dθ = ½[ −αᵀ dK̂ α + Tr(K̂⁻¹ dK̂) ].
        // One explicit inverse (a single O(n³) triangular solve-matrix)
        // amortises across all parameters; each trace is then an O(n²)
        // elementwise contraction — the strongest form of this baseline.
        let eye = Mat::eye(n);
        let kinv = ch.solve_mat(&eye);
        let n_params = op.n_params();
        let mut grad = Vec::with_capacity(n_params);
        for p in 0..n_params {
            let dk = op.dmatmul(p, &eye); // dense dK̂ (baseline-only cost)
            let dk_alpha = dk.matvec(&alpha);
            let quad: f64 = alpha.iter().zip(dk_alpha.iter()).map(|(a, b)| a * b).sum();
            // Tr(K̂⁻¹dK̂) = Σᵢⱼ (K̂⁻¹)ᵢⱼ (dK̂)ⱼᵢ, and both are symmetric
            let tr: f64 = kinv
                .data()
                .iter()
                .zip(dk.data().iter())
                .map(|(a, b)| a * b)
                .sum();
            grad.push(0.5 * (-quad + tr));
        }

        MllGrad {
            nmll,
            grad,
            iterations: 1,
            logdet,
            datafit,
        }
    }

    fn name(&self) -> &'static str {
        "cholesky"
    }
}

fn col_dot(a: &Mat, b: &Mat, c: usize) -> f64 {
    (0..a.rows()).map(|i| a.get(i, c) * b.get(i, c)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::util::Rng;

    fn toy_problem(n: usize, seed: u64) -> (DenseKernelOp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (r[0] * 3.0).sin() + 0.5 * r[1] + 0.05 * rng.normal()
            })
            .collect();
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        (op, y)
    }

    #[test]
    fn cholesky_engine_matches_direct_formula() {
        let (op, y) = toy_problem(30, 1);
        let mut eng = CholeskyEngine;
        let res = eng.mll_and_grad(&op, &y);
        // recompute from scratch
        let k = op.dense();
        let ch = crate::linalg::cholesky::Cholesky::new(&k).unwrap();
        let alpha = ch.solve_vec(&y);
        let df: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let want = 0.5 * (df + ch.logdet() + 30.0 * LN_2PI);
        assert!((res.nmll - want).abs() < 1e-10);
    }

    #[test]
    fn cholesky_gradients_match_finite_differences() {
        let (mut op, y) = toy_problem(25, 2);
        let mut eng = CholeskyEngine;
        let res = eng.mll_and_grad(&op, &y);
        let raw = op.params();
        let h = 1e-5;
        for p in 0..op.n_params() {
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = eng.mll_and_grad(&op, &y).nmll;
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = eng.mll_and_grad(&op, &y).nmll;
            op.set_params(&raw);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (fd - res.grad[p]).abs() < 1e-4 * (1.0 + fd.abs()),
                "param {p}: fd={fd} analytic={}",
                res.grad[p]
            );
        }
    }

    #[test]
    fn bbmm_converges_to_cholesky_with_enough_iterations_and_probes() {
        // with p = n iterations and many probes the stochastic estimates
        // concentrate on the exact values
        let n = 60;
        let (op, y) = toy_problem(n, 3);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::new(n, 200, 5, 42);
        let est = bbmm.mll_and_grad(&op, &y);
        // datafit is deterministic; logdet is MC — compare each against its
        // own scale (nmll itself can be near zero, so its relative error is
        // not meaningful)
        assert!(
            (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-6,
            "datafit {} vs {}",
            est.datafit,
            exact.datafit
        );
        assert!(
            (est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.10,
            "logdet {} vs {}",
            est.logdet,
            exact.logdet
        );
        for p in 0..op.n_params() {
            let denom = exact.grad[p].abs().max(1.0);
            assert!(
                (est.grad[p] - exact.grad[p]).abs() / denom < 0.15,
                "grad {p}: {} vs {}",
                est.grad[p],
                exact.grad[p]
            );
        }
    }

    #[test]
    fn bbmm_datafit_term_is_accurate_at_paper_defaults() {
        // the solve K̂⁻¹y is deterministic — paper defaults (p=20, k=5)
        // should already nail the data-fit term on a well-conditioned system
        let (op, y) = toy_problem(80, 4);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::default();
        let est = bbmm.mll_and_grad(&op, &y);
        assert!(
            (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-3,
            "{} vs {}",
            est.datafit,
            exact.datafit
        );
    }

    #[test]
    fn engines_accept_sharded_operators_through_the_trait() {
        // both engines consume &dyn LinearOp, so the sharded operator
        // drops in with no engine changes and reproduces the dense numbers
        use crate::kernels::ShardedKernelOp;
        let n = 60;
        let mut rng = Rng::new(31);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| (x.get(i, 0) * 3.0).sin() + 0.05 * rng.normal())
            .collect();
        let sharded = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, 5);
        let dense = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let cd = CholeskyEngine.mll_and_grad(&dense, &y);
        let cs = CholeskyEngine.mll_and_grad(&sharded, &y);
        assert!((cd.nmll - cs.nmll).abs() < 1e-9, "{} vs {}", cd.nmll, cs.nmll);
        let mut bd = BbmmEngine::new(n, 32, 5, 8);
        let mut bs = BbmmEngine::new(n, 32, 5, 8);
        let rd = bd.mll_and_grad(&dense, &y);
        let rs = bs.mll_and_grad(&sharded, &y);
        assert!((rd.nmll - rs.nmll).abs() < 1e-8, "{} vs {}", rd.nmll, rs.nmll);
        for p in 0..dense.n_params() {
            assert!((rd.grad[p] - rs.grad[p]).abs() < 1e-8, "grad {p}");
        }
    }

    #[test]
    fn preconditioning_reduces_iterations() {
        // narrow lengthscale + small noise ⇒ ill-conditioned K̂
        let n = 150;
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform());
        let y: Vec<f64> = (0..n).map(|i| (x.get(i, 0) * 6.0).sin()).collect();
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.3, 1.0)), 1e-4);
        let mut plain = BbmmEngine::new(400, 4, 0, 7);
        plain.cg_tol = 1e-9;
        let r_plain = plain.mll_and_grad(&op, &y);
        let mut pre = BbmmEngine::new(400, 4, 9, 7);
        pre.cg_tol = 1e-9;
        let r_pre = pre.mll_and_grad(&op, &y);
        assert!(
            r_pre.iterations < r_plain.iterations,
            "precond {} !< plain {}",
            r_pre.iterations,
            r_plain.iterations
        );
    }

    #[test]
    fn preconditioned_logdet_estimate_is_consistent() {
        let n = 100;
        let (op, y) = toy_problem(n, 6);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        // average over several probe draws to beat the MC noise
        let mut est_sum = 0.0;
        let reps = 5;
        for rep in 0..reps {
            let mut eng = BbmmEngine::new(n, 60, 5, 100 + rep);
            est_sum += eng.mll_and_grad(&op, &y).logdet;
        }
        let est = est_sum / reps as f64;
        assert!(
            (est - exact.logdet).abs() / exact.logdet.abs() < 0.05,
            "logdet est {est} vs exact {}",
            exact.logdet
        );
    }
}
