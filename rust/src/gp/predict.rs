//! Predictive distribution (paper eq. 1):
//!
//! ```text
//! μ(x*)          = k_{Xx*}ᵀ K̂⁻¹ y
//! k(x*, x*′)     = k_{x*x*′} − k_{Xx*}ᵀ K̂⁻¹ k_{Xx*′}
//! ```
//!
//! Generic over the engine: the caller supplies a batched solve
//! `K̂⁻¹ · M` closure — or passes the training operator itself to
//! [`predict_op`], which dispatches the solve on the operator's structure
//! (direct Woodbury for SGPR-shaped compositions, dense Cholesky for
//! explicit matrices, preconditioned mBCG otherwise).

use crate::linalg::mbcg::{MbcgBatchStats, MbcgWorkspace};
use crate::linalg::op::{
    plan, solve_batch_hetero_ws, solve_batch_ws, solve_with, BatchOp, LinearOp, SolveOptions,
    SolvePlan,
};
use crate::tensor::Mat;

/// Posterior mean and (marginal) variance at test points.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub mean: Vec<f64>,
    /// predictive variance of the latent f (add σ² for observation noise)
    pub var: Vec<f64>,
}

/// The shared RHS block `[y  K_X*ᵀ]`: one batched solve yields mean and
/// variance together.
fn posterior_rhs(k_star: &Mat, y: &[f64]) -> Mat {
    let n_test = k_star.rows();
    let n = k_star.cols();
    assert_eq!(y.len(), n);
    let mut rhs = Mat::zeros(n, 1 + n_test);
    rhs.set_col(0, y);
    for j in 0..n_test {
        for i in 0..n {
            rhs.set(i, 1 + j, k_star.get(j, i));
        }
    }
    rhs
}

/// Assemble mean/variance from the solved `K̂⁻¹·[y K_X*ᵀ]` block.
fn posterior_from_solves(k_star: &Mat, k_star_diag: &[f64], solved: &Mat) -> Prediction {
    let n_test = k_star.rows();
    let n = k_star.cols();
    assert_eq!(k_star_diag.len(), n_test);
    let mut mean = vec![0.0; n_test];
    let mut var = vec![0.0; n_test];
    for j in 0..n_test {
        let krow = k_star.row(j);
        let mut mu = 0.0;
        let mut quad = 0.0;
        for i in 0..n {
            mu += krow[i] * solved.get(i, 0);
            quad += krow[i] * solved.get(i, 1 + j);
        }
        mean[j] = mu;
        var[j] = (k_star_diag[j] - quad).max(0.0);
    }
    Prediction { mean, var }
}

/// Compute the predictive distribution.
///
/// * `k_star` — `n_test × n` cross-covariance `K(X*, X)`
/// * `k_star_diag` — prior variances `k(x*, x*)` per test point
/// * `solve` — applies `K̂⁻¹` to an `n×t` matrix
/// * `y` — training targets
pub fn predict(
    k_star: &Mat,
    k_star_diag: &[f64],
    solve: impl Fn(&Mat) -> Mat,
    y: &[f64],
) -> Prediction {
    let rhs = posterior_rhs(k_star, y);
    let solved = solve(&rhs);
    posterior_from_solves(k_star, k_star_diag, &solved)
}

/// Predictive distribution through the **generic solve path**: the
/// training operator is any [`LinearOp`] composition, and the batched
/// `K̂⁻¹·[y K_X*ᵀ]` solve is dispatched on its structure by
/// [`crate::linalg::op::solve()`]. This is the single path exact, SGPR,
/// SKI, and sharded models all predict through. Callers answering
/// repeated queries against a fixed posterior should hold a plan
/// ([`predict_with_plan`]) or a [`crate::linalg::op::SolvePlanCache`]
/// instead of paying the factorisation per call.
pub fn predict_op(
    op: &dyn LinearOp,
    k_star: &Mat,
    k_star_diag: &[f64],
    y: &[f64],
    opts: &SolveOptions,
) -> Prediction {
    predict_with_plan(op, k_star, k_star_diag, y, &plan(op, opts), opts)
}

/// [`predict_op`] against a **prepared** [`SolvePlan`] — the per-request
/// path of a serving loop: no factorisation, no preconditioner build, one
/// dispatched solve.
pub fn predict_with_plan(
    op: &dyn LinearOp,
    k_star: &Mat,
    k_star_diag: &[f64],
    y: &[f64],
    plan: &SolvePlan,
    opts: &SolveOptions,
) -> Prediction {
    predict(k_star, k_star_diag, |m| solve_with(plan, op, m, opts), y)
}

/// [`predict_with_plan`]'s constant-time sibling: answer a test block
/// from a **frozen** [`crate::gp::posterior::LovePosterior`] — two skinny
/// GEMMs against the cached mean solve and LOVE variance factor, O(n·r)
/// per test point with no solve at all. This is the serve-path fast lane;
/// accuracy is governed by the posterior's LOVE rank (exact at r=n).
pub fn predict_with_posterior(
    post: &crate::gp::posterior::LovePosterior,
    k_star: &Mat,
    k_star_diag: &[f64],
) -> Prediction {
    post.predict(k_star, k_star_diag)
}

/// One posterior query against one batch element: the cross-covariance
/// block, prior variances, and targets of the posterior it addresses.
pub struct PosteriorQuery<'a> {
    /// `n_q × n` cross-covariance `K(X*, X)` for this element's posterior
    pub k_star: &'a Mat,
    /// prior variances `k(x*, x*)` per query point
    pub k_star_diag: &'a [f64],
    /// this element's training targets
    pub y: &'a [f64],
}

/// **Batched posterior answering** — many test blocks against many
/// posteriors in one dispatcher call: query `i` is answered by batch
/// element `i` under its prepared plan. Direct-structure posteriors solve
/// immediately; all iterative ones share a single `mbcg_batch` loop (per-
/// system early stopping included), which is what lets a multi-tenant
/// serving tick answer every tenant with one solve call.
pub fn predict_batch_op(
    batch: &BatchOp<'_>,
    queries: &[PosteriorQuery<'_>],
    plans: &[&SolvePlan],
    opts: &SolveOptions,
) -> Vec<Prediction> {
    let mut ws = MbcgWorkspace::new();
    predict_batch_op_ws(batch, queries, plans, opts, &mut ws)
}

/// [`predict_batch_op`] against a caller-held [`MbcgWorkspace`]: a serving
/// loop answering the same tenant group every tick holds one workspace per
/// group, so the iterative sub-batch's solver buffers stay warm across
/// ticks instead of being rebuilt per call.
pub fn predict_batch_op_ws(
    batch: &BatchOp<'_>,
    queries: &[PosteriorQuery<'_>],
    plans: &[&SolvePlan],
    opts: &SolveOptions,
    ws: &mut MbcgWorkspace,
) -> Vec<Prediction> {
    assert_eq!(queries.len(), batch.len(), "predict_batch_op: query count mismatch");
    let rhs: Vec<Mat> = queries.iter().map(|q| posterior_rhs(q.k_star, q.y)).collect();
    let rhs_refs: Vec<&Mat> = rhs.iter().collect();
    let solved = solve_batch_ws(batch, plans, &rhs_refs, opts, ws);
    queries
        .iter()
        .zip(solved)
        .map(|(q, s)| posterior_from_solves(q.k_star, q.k_star_diag, &s))
        .collect()
}

/// **Heterogeneous batched posterior answering** — the fused serving
/// tick: query `i` is answered by posterior operator `els[i]`, with
/// tenants of **any mix of training sizes and model families** sharing
/// exactly ONE iterative loop through
/// [`crate::linalg::op::solve_batch_hetero_ws`] (direct-planned tenants
/// converge at the first α-step via
/// [`crate::linalg::op::PlanPrecond`]; iterative tenants run to their own
/// per-tenant tolerance `opts[i]`). Returns the per-tenant predictions
/// plus the fused loop's stats — the serving metrics' fused-tick
/// occupancy counters.
pub fn predict_batch_hetero_ws(
    els: &[&dyn LinearOp],
    queries: &[PosteriorQuery<'_>],
    plans: &[&SolvePlan],
    opts: &[SolveOptions],
    ws: &mut MbcgWorkspace,
) -> (Vec<Prediction>, MbcgBatchStats) {
    assert_eq!(queries.len(), els.len(), "predict_batch_hetero: query count mismatch");
    let rhs: Vec<Mat> = queries.iter().map(|q| posterior_rhs(q.k_star, q.y)).collect();
    let rhs_refs: Vec<&Mat> = rhs.iter().collect();
    let (solved, stats) = solve_batch_hetero_ws(els, plans, &rhs_refs, opts, ws);
    let preds = queries
        .iter()
        .zip(solved)
        .map(|(q, s)| posterior_from_solves(q.k_star, q.k_star_diag, &s))
        .collect();
    (preds, stats)
}

/// Mean-only prediction (one solve total, reused across all test points).
pub fn predict_mean(k_star: &Mat, solve: impl Fn(&Mat) -> Mat, y: &[f64]) -> Vec<f64> {
    let n = k_star.cols();
    assert_eq!(y.len(), n);
    let rhs = Mat::col_from_slice(y);
    let alpha = solve(&rhs); // K̂⁻¹y, n×1
    let mut mean = vec![0.0; k_star.rows()];
    for j in 0..k_star.rows() {
        let krow = k_star.row(j);
        mean[j] = (0..n).map(|i| krow[i] * alpha.get(i, 0)).sum();
    }
    mean
}

/// Mean absolute error — the paper's Figure-3 metric.
pub fn mae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    pred.iter()
        .zip(truth.iter())
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / pred.len() as f64
}

/// Root mean squared error (supplementary metric).
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    (pred
        .iter()
        .zip(truth.iter())
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::linalg::cholesky::Cholesky;
    use crate::util::Rng;

    #[test]
    fn noiseless_gp_interpolates_training_data() {
        // tiny noise ⇒ posterior mean ≈ y at training inputs
        let n = 20;
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| (3.0 * x.get(i, 0)).sin()).collect();
        let op = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 1e-8);
        let ch = Cholesky::new_with_jitter(&op.dense()).unwrap();
        let k_star = op.cross(&x, op.x());
        let diag: Vec<f64> = (0..n).map(|i| op.kernel().eval(x.row(i), x.row(i))).collect();
        let pred = predict(&k_star, &diag, |m| ch.solve_mat(m), &y);
        for i in 0..n {
            assert!((pred.mean[i] - y[i]).abs() < 1e-4, "i={i}");
            assert!(pred.var[i] < 1e-4);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let n = 15;
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform_in(0.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| x.get(i, 0)).collect();
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.2, 1.0)), 1e-4);
        let ch = Cholesky::new_with_jitter(&op.dense()).unwrap();
        let xs = Mat::from_vec(2, 1, vec![0.5, 5.0]); // in-range vs far away
        let k_star = op.cross(&xs, op.x());
        let diag = vec![
            op.kernel().eval(&[0.5], &[0.5]),
            op.kernel().eval(&[5.0], &[5.0]),
        ];
        let pred = predict(&k_star, &diag, |m| ch.solve_mat(m), &y);
        assert!(pred.var[1] > pred.var[0] * 10.0);
        // far-away mean reverts to prior (0)
        assert!(pred.mean[1].abs() < 0.05);
    }

    #[test]
    fn predict_mean_matches_full_predict() {
        let n = 25;
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(n, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|i| x.get(i, 0) - x.get(i, 1)).collect();
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(1.0, 1.0)), 0.1);
        let ch = Cholesky::new(&op.dense()).unwrap();
        let xs = Mat::from_fn(7, 2, |_, _| rng.normal());
        let k_star = op.cross(&xs, op.x());
        let diag: Vec<f64> = (0..7).map(|i| op.kernel().eval(xs.row(i), xs.row(i))).collect();
        let full = predict(&k_star, &diag, |m| ch.solve_mat(m), &y);
        let mean_only = predict_mean(&k_star, |m| ch.solve_mat(m), &y);
        for i in 0..7 {
            assert!((full.mean[i] - mean_only[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn metrics() {
        assert_eq!(mae(&[1.0, 2.0], &[2.0, 0.0]), 1.5);
        assert!((rmse(&[1.0, 2.0], &[2.0, 0.0]) - (2.5f64).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn predict_batch_op_matches_per_posterior_predict_op() {
        use crate::linalg::op::{plan_batch, BatchOp, LinearOp, SolveOptions};
        let n = 40;
        let mut rng = Rng::new(9);
        let mut ops = Vec::new();
        let mut ys = Vec::new();
        for seed in 0..3u64 {
            let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
            let y: Vec<f64> = (0..n).map(|i| (2.0 * x.get(i, 0)).sin()).collect();
            ops.push(DenseKernelOp::new(
                x,
                Box::new(Rbf::new(0.4 + 0.1 * seed as f64, 1.0)),
                0.05 + 0.02 * seed as f64,
            ));
            ys.push(y);
        }
        let xs = Mat::from_fn(7, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let kstars: Vec<Mat> = ops.iter().map(|op| op.cross(&xs, op.x())).collect();
        let diags: Vec<Vec<f64>> = ops
            .iter()
            .map(|op| (0..7).map(|i| op.kernel().eval(xs.row(i), xs.row(i))).collect())
            .collect();
        let opts = SolveOptions {
            max_iters: 200,
            tol: 1e-12,
            precond_rank: 5,
        };
        let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o as &dyn LinearOp).collect();
        let batch = BatchOp::new(els);
        let plans = plan_batch(&batch, &opts);
        let plan_refs: Vec<&crate::linalg::op::SolvePlan> = plans.iter().collect();
        let queries: Vec<PosteriorQuery> = (0..3)
            .map(|k| PosteriorQuery {
                k_star: &kstars[k],
                k_star_diag: &diags[k],
                y: &ys[k],
            })
            .collect();
        let batched = predict_batch_op(&batch, &queries, &plan_refs, &opts);
        for k in 0..3 {
            let single = predict_op(&ops[k], &kstars[k], &diags[k], &ys[k], &opts);
            for j in 0..7 {
                assert!(
                    (batched[k].mean[j] - single.mean[j]).abs() < 1e-8,
                    "posterior {k} mean {j}"
                );
                assert!(
                    (batched[k].var[j] - single.var[j]).abs() < 1e-8,
                    "posterior {k} var {j}"
                );
            }
        }
    }
}
