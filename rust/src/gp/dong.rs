//! The Dong et al. [13] inference engine — the paper's SKI baseline
//! (Figure 2, right).
//!
//! Computes the same three inference terms as BBMM, but the way the prior
//! work does: **in series** — one standard CG solve for `K̂⁻¹y`, then `t`
//! *separate* CG solves for the probe vectors, then `t` explicit Lanczos
//! tridiagonalizations (with their O(np) storage and reorthogonalization
//! cost) for the log-det — and with **no preconditioner**. The asymptotic
//! complexity matches BBMM; the constant-factor and parallelism differences
//! are exactly what Figure 2 (right) measures.

use crate::gp::mll::{InferenceEngine, MllGrad};
use crate::linalg::cg::pcg;
use crate::linalg::lanczos::lanczos_tridiag;
use crate::linalg::op::LinearOp;
use crate::linalg::tridiag::SymTridiagEig;
use crate::tensor::Mat;
use crate::util::Rng;

const LN_2PI: f64 = 1.8378770664093453;

/// Sequential MVM engine of Dong et al. [13].
pub struct DongEngine {
    pub max_cg_iters: usize,
    pub cg_tol: f64,
    pub n_probes: usize,
    pub rng: Rng,
}

impl Default for DongEngine {
    fn default() -> Self {
        DongEngine {
            max_cg_iters: 20,
            cg_tol: 1e-10,
            n_probes: 10,
            rng: Rng::new(0xD04C),
        }
    }
}

impl DongEngine {
    pub fn new(max_cg_iters: usize, n_probes: usize, seed: u64) -> Self {
        DongEngine {
            max_cg_iters,
            cg_tol: 1e-10,
            n_probes,
            rng: Rng::new(seed),
        }
    }
}

impl InferenceEngine for DongEngine {
    fn mll_and_grad(&mut self, op: &dyn LinearOp, y: &[f64]) -> MllGrad {
        let n = op.n();
        let t = self.n_probes;
        // mat-vec through the blackbox operator, one column at a time —
        // the sequential access pattern of the prior work
        let matvec = |v: &[f64]| -> Vec<f64> {
            let m = Mat::col_from_slice(v);
            op.matmul(&m).col(0)
        };

        // 1) K̂⁻¹y by standard CG
        let solve_y = pcg(matvec, y, |r| r.to_vec(), self.max_cg_iters, self.cg_tol);
        let u0 = solve_y.x;
        let mut iters = solve_y.iterations;
        let datafit: f64 = y.iter().zip(u0.iter()).map(|(a, b)| a * b).sum();

        // 2) t probe solves, one CG each (sequential)
        let mut probes = Vec::with_capacity(t);
        let mut probe_solves = Vec::with_capacity(t);
        for _ in 0..t {
            let mut z = vec![0.0; n];
            self.rng.fill_rademacher(&mut z);
            let s = pcg(matvec, &z, |r| r.to_vec(), self.max_cg_iters, self.cg_tol);
            iters += s.iterations;
            probes.push(z);
            probe_solves.push(s.x);
        }

        // 3) log-det via t explicit Lanczos runs (O(np) storage each)
        let mut logdet = 0.0;
        for z in &probes {
            let (tri, _q) = lanczos_tridiag(matvec, z, self.max_cg_iters);
            let eig = SymTridiagEig::new(&tri.diag, &tri.offdiag);
            let znorm2: f64 = z.iter().map(|v| v * v).sum();
            logdet += znorm2 * eig.log_quadrature();
        }
        logdet /= t as f64;

        let nmll = 0.5 * (datafit + logdet + n as f64 * LN_2PI);

        // 4) gradients: quad term + Hutchinson trace, probe by probe
        let n_params = op.n_params();
        let mut grad = Vec::with_capacity(n_params);
        let u0_mat = Mat::col_from_slice(&u0);
        for p in 0..n_params {
            let dk_u0 = op.dmatmul(p, &u0_mat).col(0);
            let quad: f64 = u0.iter().zip(dk_u0.iter()).map(|(a, b)| a * b).sum();
            let mut tr = 0.0;
            for (z, sz) in probes.iter().zip(probe_solves.iter()) {
                let dk_z = op.dmatmul(p, &Mat::col_from_slice(z)).col(0);
                tr += sz.iter().zip(dk_z.iter()).map(|(a, b)| a * b).sum::<f64>();
            }
            tr /= t as f64;
            grad.push(0.5 * (-quad + tr));
        }

        MllGrad {
            nmll,
            grad,
            iterations: iters,
            logdet,
            datafit,
        }
    }

    fn name(&self) -> &'static str {
        "dong"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::mll::{BbmmEngine, CholeskyEngine};
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::util::Rng;

    fn toy(n: usize, seed: u64) -> (DenseKernelOp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| (x.get(i, 0) * 3.0).sin() + 0.05 * rng.normal()).collect();
        (DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05), y)
    }

    #[test]
    fn dong_engine_agrees_with_cholesky_when_converged() {
        let n = 50;
        let (op, y) = toy(n, 1);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut dong = DongEngine::new(n, 150, 11);
        let est = dong.mll_and_grad(&op, &y);
        // deterministic datafit must match tightly; the log-det is a
        // Monte-Carlo estimate — compare against its own magnitude
        assert!(
            (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-6,
            "datafit {} vs {}",
            est.datafit,
            exact.datafit
        );
        assert!(
            (est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.10,
            "logdet {} vs {}",
            est.logdet,
            exact.logdet
        );
    }

    #[test]
    fn dong_and_bbmm_produce_consistent_estimates() {
        // the two MVM engines must estimate the same quantities
        // (paper footnote 3: identical outputs up to MC noise)
        let n = 60;
        let (op, y) = toy(n, 2);
        let mut dong = DongEngine::new(n, 100, 3);
        let mut bbmm = BbmmEngine::new(n, 100, 0, 3);
        let a = dong.mll_and_grad(&op, &y);
        let b = bbmm.mll_and_grad(&op, &y);
        assert!((a.datafit - b.datafit).abs() / a.datafit.abs() < 1e-4);
        assert!((a.logdet - b.logdet).abs() / a.logdet.abs() < 0.05);
    }

    #[test]
    fn dong_uses_more_operator_calls_than_bbmm() {
        // serial CG: iterations counted across t+1 separate solves
        let (op, y) = toy(40, 4);
        let mut dong = DongEngine::new(15, 10, 5);
        let mut bbmm = BbmmEngine::new(15, 10, 0, 5);
        let a = dong.mll_and_grad(&op, &y);
        let b = bbmm.mll_and_grad(&op, &y);
        assert!(
            a.iterations > 5 * b.iterations,
            "dong {} vs bbmm {}",
            a.iterations,
            b.iterations
        );
    }
}
