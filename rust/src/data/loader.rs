//! CSV loader for real UCI files (optional — the harness falls back to
//! [`super::synthetic`] when no file is present).
//!
//! Format: numeric CSV, optional header row, last column is the target.

use crate::data::synthetic::Dataset;
use crate::tensor::Mat;
use crate::util::Rng;
use std::path::Path;

/// Parse a numeric CSV into (X, y). Rows with non-numeric fields (e.g. a
/// header) are skipped; the last column is the target.
pub fn parse_csv(text: &str) -> Result<(Mat, Vec<f64>), String> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Result<Vec<f64>, _> = line
            .split(&[',', ';', '\t'][..])
            .map(|f| f.trim().parse::<f64>())
            .collect();
        match fields {
            Ok(vals) => {
                if vals.len() < 2 {
                    return Err(format!("line {}: need ≥2 columns", lineno + 1));
                }
                match width {
                    None => width = Some(vals.len()),
                    Some(w) if w != vals.len() => {
                        return Err(format!(
                            "line {}: {} columns, expected {}",
                            lineno + 1,
                            vals.len(),
                            w
                        ));
                    }
                    _ => {}
                }
                rows.push(vals);
            }
            Err(_) if lineno == 0 => continue, // header
            Err(e) => return Err(format!("line {}: {}", lineno + 1, e)),
        }
    }
    if rows.is_empty() {
        return Err("no data rows".to_string());
    }
    let d = rows[0].len() - 1;
    let n = rows.len();
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for (r, vals) in rows.iter().enumerate() {
        x.row_mut(r).copy_from_slice(&vals[..d]);
        y.push(vals[d]);
    }
    Ok((x, y))
}

/// Load a dataset from a CSV file, standardise, and split train/test.
pub fn load_csv(path: &Path, name: &str, seed: u64) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    let (mut x, mut y) = parse_csv(&text)?;
    standardize(&mut x, &mut y);
    let n = x.rows();
    let mut rng = Rng::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = (n / 10).max(1);
    let n_train = n - n_test;
    let take = |ids: &[usize]| {
        let mut xm = Mat::zeros(ids.len(), x.cols());
        let mut ym = Vec::with_capacity(ids.len());
        for (r, &i) in ids.iter().enumerate() {
            xm.row_mut(r).copy_from_slice(x.row(i));
            ym.push(y[i]);
        }
        (xm, ym)
    };
    let (x_train, y_train) = take(&idx[..n_train]);
    let (x_test, y_test) = take(&idx[n_train..]);
    Ok(Dataset {
        name: name.to_string(),
        x_train,
        y_train,
        x_test,
        y_test,
    })
}

/// Column-standardise X and standardise y in place.
pub fn standardize(x: &mut Mat, y: &mut [f64]) {
    let n = x.rows();
    for c in 0..x.cols() {
        let mean: f64 = (0..n).map(|r| x.get(r, c)).sum::<f64>() / n as f64;
        let var: f64 = (0..n).map(|r| (x.get(r, c) - mean).powi(2)).sum::<f64>() / n as f64;
        let sd = var.sqrt().max(1e-12);
        for r in 0..n {
            x.set(r, c, (x.get(r, c) - mean) / sd);
        }
    }
    let mean = y.iter().sum::<f64>() / n as f64;
    let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let sd = var.sqrt().max(1e-12);
    for v in y.iter_mut() {
        *v = (*v - mean) / sd;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_csv() {
        let (x, y) = parse_csv("1,2,3\n4,5,6\n7,8,9\n").unwrap();
        assert_eq!(x.shape(), (3, 2));
        assert_eq!(y, vec![3.0, 6.0, 9.0]);
    }

    #[test]
    fn skips_header() {
        let (x, y) = parse_csv("a,b,target\n1,2,3\n").unwrap();
        assert_eq!(x.shape(), (1, 2));
        assert_eq!(y, vec![3.0]);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2,3\n4,5\n").is_err());
    }

    #[test]
    fn rejects_non_numeric_data_row() {
        assert!(parse_csv("1,2,3\nx,y,z\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("").is_err());
        assert!(parse_csv("header,line\n").is_err());
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut x = Mat::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let mut y = vec![10.0, 20.0, 30.0, 40.0];
        standardize(&mut x, &mut y);
        let xm: f64 = (0..4).map(|r| x.get(r, 0)).sum::<f64>() / 4.0;
        assert!(xm.abs() < 1e-12);
        let ym: f64 = y.iter().sum::<f64>() / 4.0;
        assert!(ym.abs() < 1e-12);
        let yv: f64 = y.iter().map(|v| v * v).sum::<f64>() / 4.0;
        assert!((yv - 1.0).abs() < 1e-12);
    }

    #[test]
    fn load_csv_roundtrip() {
        let dir = std::env::temp_dir().join("bbmm_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.csv");
        let mut content = String::from("f1,f2,y\n");
        for i in 0..50 {
            content.push_str(&format!("{},{},{}\n", i, i * 2, i * 3));
        }
        std::fs::write(&p, content).unwrap();
        let ds = load_csv(&p, "toy", 1).unwrap();
        assert_eq!(ds.x_train.rows() + ds.x_test.rows(), 50);
        assert_eq!(ds.dim(), 2);
        std::fs::remove_file(&p).ok();
    }
}
