//! Synthetic UCI-equivalent regression datasets.
//!
//! Each dataset named in the paper's §6 is mirrored with the same (n, d).
//! Targets are drawn from a random-Fourier-feature function (an approximate
//! sample from an RBF-kernel GP) plus i.i.d. Gaussian noise, then
//! standardised — giving the same SNR character as standardised UCI data.

use crate::tensor::Mat;
use crate::util::Rng;

/// A regression dataset with a train/test split.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    pub x_train: Mat,
    pub y_train: Vec<f64>,
    pub x_test: Mat,
    pub y_test: Vec<f64>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.x_train.rows()
    }

    pub fn dim(&self) -> usize {
        self.x_train.cols()
    }
}

/// (name, n_total, d) for a paper dataset.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
}

/// §6 "Exact" datasets (n ≤ 3500).
pub const UCI_EXACT: &[DatasetSpec] = &[
    DatasetSpec {
        name: "autompg",
        n: 392,
        d: 7,
    },
    DatasetSpec {
        name: "airfoil",
        n: 1503,
        d: 5,
    },
    DatasetSpec {
        name: "wine",
        n: 1599,
        d: 11,
    },
    DatasetSpec {
        name: "gas",
        n: 2565,
        d: 128,
    },
    DatasetSpec {
        name: "skillcraft",
        n: 3338,
        d: 19,
    },
];

/// §6 SGPR datasets (n up to 50k).
pub const UCI_SGPR: &[DatasetSpec] = &[
    DatasetSpec {
        name: "poletele",
        n: 15000,
        d: 26,
    },
    DatasetSpec {
        name: "elevators",
        n: 16599,
        d: 18,
    },
    DatasetSpec {
        name: "kin40k",
        n: 40000,
        d: 8,
    },
    DatasetSpec {
        name: "protein",
        n: 45730,
        d: 9,
    },
    DatasetSpec {
        name: "kegg",
        n: 48827,
        d: 20,
    },
];

/// §6 SKI datasets (n up to 515k).
pub const UCI_SKI: &[DatasetSpec] = &[
    DatasetSpec {
        name: "kin40k",
        n: 40000,
        d: 8,
    },
    DatasetSpec {
        name: "protein",
        n: 45730,
        d: 9,
    },
    DatasetSpec {
        name: "kegg",
        n: 48827,
        d: 20,
    },
    DatasetSpec {
        name: "song",
        n: 515345,
        d: 90,
    },
    DatasetSpec {
        name: "buzz",
        n: 583250,
        d: 77,
    },
];

/// Look up a spec by name across all three suites.
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    UCI_EXACT
        .iter()
        .chain(UCI_SGPR)
        .chain(UCI_SKI)
        .find(|s| s.name == name)
        .copied()
}

/// Generate the synthetic stand-in for a paper dataset (deterministic in
/// the seed). 90/10 train/test split, standardised features and targets.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Dataset {
    generate_sized(spec.name, spec.n, spec.d, seed)
}

/// Generate with explicit size (used by scaling benchmarks).
pub fn generate_sized(name: &str, n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ hash_name(name));
    // random Fourier features: f(x) = √(2/D) Σ_j a_j cos(w_jᵀx + b_j)
    let n_feat = 64usize;
    let ls = 0.4 * (d as f64).sqrt(); // keeps function smooth in high d
    let w = Mat::from_fn(n_feat, d, |_, _| rng.normal() / ls);
    let b: Vec<f64> = (0..n_feat)
        .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI))
        .collect();
    let a: Vec<f64> = (0..n_feat).map(|_| rng.normal()).collect();
    let noise = 0.1;

    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    let scale = (2.0 / n_feat as f64).sqrt();
    for i in 0..n {
        for c in 0..d {
            x.set(i, c, rng.uniform_in(-1.0, 1.0));
        }
        let xi = x.row(i);
        let mut f = 0.0;
        for j in 0..n_feat {
            let wj = w.row(j);
            let dot: f64 = wj.iter().zip(xi.iter()).map(|(p, q)| p * q).sum();
            f += a[j] * (dot + b[j]).cos();
        }
        y[i] = scale * f + noise * rng.normal();
    }

    // standardise targets
    let mean = y.iter().sum::<f64>() / n as f64;
    let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let sd = var.sqrt().max(1e-12);
    for v in y.iter_mut() {
        *v = (*v - mean) / sd;
    }

    // split: shuffle indices, 90/10
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = (n / 10).max(1).min(2000); // cap test size for big sets
    let n_train = n - n_test;
    let take = |ids: &[usize]| -> (Mat, Vec<f64>) {
        let mut xm = Mat::zeros(ids.len(), d);
        let mut ym = Vec::with_capacity(ids.len());
        for (r, &i) in ids.iter().enumerate() {
            xm.row_mut(r).copy_from_slice(x.row(i));
            ym.push(y[i]);
        }
        (xm, ym)
    };
    let (x_train, y_train) = take(&idx[..n_train]);
    let (x_test, y_test) = take(&idx[n_train..]);

    Dataset {
        name: name.to_string(),
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

fn hash_name(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_respects_spec() {
        let ds = generate(&UCI_EXACT[0], 1); // autompg: 392×7
        assert_eq!(ds.n_train() + ds.x_test.rows(), 392);
        assert_eq!(ds.dim(), 7);
        assert_eq!(ds.y_train.len(), ds.n_train());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&UCI_EXACT[0], 7);
        let b = generate(&UCI_EXACT[0], 7);
        assert_eq!(a.y_train, b.y_train);
        let c = generate(&UCI_EXACT[0], 8);
        assert_ne!(a.y_train, c.y_train);
    }

    #[test]
    fn targets_standardised() {
        let ds = generate_sized("test", 2000, 4, 3);
        let all: Vec<f64> = ds.y_train.iter().chain(ds.y_test.iter()).copied().collect();
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        let var = all.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / all.len() as f64;
        assert!(mean.abs() < 1e-10);
        assert!((var - 1.0).abs() < 1e-10);
    }

    #[test]
    fn signal_is_learnable() {
        // a GP with decent hyperparameters must beat the mean predictor
        use crate::gp::exact::{Engine, ExactGp};
        use crate::gp::predict::mae;
        use crate::kernels::Rbf;
        let ds = generate_sized("learnable", 400, 3, 4);
        let mut gp = ExactGp::new(
            ds.x_train.clone(),
            ds.y_train.clone(),
            Box::new(Rbf::new(0.7, 1.0)),
            0.05,
            Engine::Cholesky,
        );
        let pred = gp.predict(&ds.x_test);
        let gp_mae = mae(&pred.mean, &ds.y_test);
        let mean_mae = mae(&vec![0.0; ds.y_test.len()], &ds.y_test);
        assert!(gp_mae < 0.7 * mean_mae, "gp {gp_mae} vs mean {mean_mae}");
    }

    #[test]
    fn all_specs_resolvable() {
        for s in UCI_EXACT.iter().chain(UCI_SGPR).chain(UCI_SKI) {
            assert!(spec_by_name(s.name).is_some());
        }
        assert!(spec_by_name("nonexistent").is_none());
    }
}
