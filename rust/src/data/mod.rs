//! Datasets.
//!
//! The paper evaluates on UCI regression sets. Those files are not
//! available in this environment, so [`synthetic`] generates
//! dimension-matched synthetic equivalents (same n, d; smooth nonlinear
//! target + observation noise — drawn via random Fourier features, i.e. an
//! approximate GP sample, so the learning problem has the same character).
//! [`loader`] reads real UCI CSVs when present, keeping the harness able to
//! run on the true data. See DESIGN.md §5 for the substitution argument.

pub mod loader;
pub mod synthetic;

pub use synthetic::{Dataset, DatasetSpec, UCI_EXACT, UCI_SGPR, UCI_SKI};
