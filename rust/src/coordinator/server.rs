//! TCP prediction server (std::net; the offline crate set has no tokio).
//!
//! Line protocol, one request per line:
//!
//! ```text
//! → 0.12,3.4,-1.0\n          (comma-separated features → tenant 0)
//! ← 0.873,0.0021\n           (mean, variance)
//! → wine:0.12,3.4,-1.0\n     (routed to the tenant named `wine`)
//! ← 0.873,0.0021\n
//! → TENANTS\n
//! ← wine:11 airfoil:5\n      (name:dim per hosted tenant)
//! → STATS\n
//! ← requests=… batches=…\n
//! ```
//!
//! Each connection gets a handler thread; all handlers feed the shared
//! [`DynamicBatcher`], so concurrent clients are served out of coalesced
//! batched GP solves — and in a multi-tenant deployment
//! ([`multi_served_predictor`]), every tick answers all tenants through
//! **one** `BatchOp` dispatch with per-tenant solve plans cached across
//! predict calls.

use crate::coordinator::batcher::{DynamicBatcher, MultiPredictFn, PredictFn, TenantBatch};
use crate::gp::predict::{predict_batch_op, predict_with_plan, PosteriorQuery, Prediction};
use crate::linalg::op::{
    solve_strategy, BatchOp, LinearOp, SolveOptions, SolvePlan, SolvePlanCache,
};
use crate::tensor::Mat;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    /// Human-readable description of the hosted operator composition
    /// (e.g. `AddedDiag(ShardedCov(rbf) × 8)`), echoed at startup so the
    /// deployment log records what algebra is serving traffic.
    pub operator: String,
    /// Row-shard count of the serving model's covariance backend (1 =
    /// monolithic dense operator), recorded here so the deployment config
    /// carries how the operator was sized to traffic. The server itself
    /// does not build the model — the launcher (`bbmm serve --shards N`)
    /// constructs the sharded operator, fills this in, and echoes it at
    /// startup.
    pub shard_count: usize,
    /// stop flag the caller can flip to shut the accept loop down
    pub stop: Arc<AtomicBool>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7777".to_string(),
            operator: String::new(),
            shard_count: 1,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// A servable GP posterior: **any** [`LinearOp`] composition plus the
/// model-side pieces a posterior needs (cross-covariance, prior variances,
/// targets). This is the seam `bbmm serve` threads the operator algebra
/// through — exact, sharded, SGPR, and SKI models all implement it with a
/// few lines, and the server solves every prediction through the generic
/// dispatcher ([`crate::linalg::op::solve()`]).
pub trait ServableModel: Send + Sync {
    /// The training operator `K̂` (noise included in the composition).
    fn op(&self) -> &dyn LinearOp;
    /// Cross-covariance `K(X*, X)` rows for a batch of query points.
    fn cross(&self, xs: &Mat) -> Mat;
    /// Prior variances `k(x*, x*)` per query point.
    fn prior_diag(&self, xs: &Mat) -> Vec<f64>;
    /// Training targets.
    fn y(&self) -> &[f64];
    /// One-line operator description for the startup log.
    fn describe(&self) -> String {
        format!(
            "LinearOp n={} strategy={:?}",
            self.op().n(),
            solve_strategy(self.op())
        )
    }
}

/// Wrap a servable model into the batcher's [`PredictFn`]: each coalesced
/// batch becomes one cross-covariance build plus one dispatched solve —
/// no model lock, since [`LinearOp`] solves are `&self`. The solve plan
/// (Woodbury capacitance factor / pivoted-Cholesky preconditioner) lives
/// in a [`SolvePlanCache`]: prepared once, reused every batch, rebuilt
/// only if the operator's content changes.
pub fn served_predictor(model: Box<dyn ServableModel>, opts: SolveOptions) -> PredictFn {
    served_predictor_cached(model, opts, Arc::new(SolvePlanCache::new()))
}

/// [`served_predictor`] with a caller-held plan cache (observable
/// hit/miss/invalidation counters — the deployment's factorisation log).
pub fn served_predictor_cached(
    model: Box<dyn ServableModel>,
    opts: SolveOptions,
    cache: Arc<SolvePlanCache>,
) -> PredictFn {
    // the served model is moved into the closure with no mutation path,
    // so its content fingerprint is computed once, not per tick
    let fp = model.op().fingerprint();
    Box::new(move |xs: &Mat| -> Prediction {
        let k_star = model.cross(xs);
        let diag = model.prior_diag(xs);
        let plan = cache.get_or_plan_with_fingerprint("default", fp, model.op(), &opts);
        predict_with_plan(model.op(), &k_star, &diag, model.y(), &plan, &opts)
    })
}

/// Host **many** tenants behind one predictor: each batching tick carries
/// every tenant's coalesced RHS block, and this closure answers them all
/// through a single [`predict_batch_op`] dispatch — same-shape tenants
/// stack into one [`BatchOp`] (iterative ones then share one `mbcg_batch`
/// iteration loop), per-tenant [`SolvePlan`]s come from `cache` keyed by
/// tenant name, so factorisations/preconditioners persist across predict
/// calls and rebuild only on hyperparameter change.
pub fn multi_served_predictor(
    models: Vec<(String, Box<dyn ServableModel>)>,
    opts: SolveOptions,
    cache: Arc<SolvePlanCache>,
) -> MultiPredictFn {
    // served models are moved into the closure with no mutation path, so
    // per-tenant fingerprints are computed once, not per tick
    let fps: Vec<u64> = models.iter().map(|(_, m)| m.op().fingerprint()).collect();
    Box::new(move |blocks: &[TenantBatch]| -> Vec<Prediction> {
        // per-block posterior pieces + cached plans
        let mut kstars = Vec::with_capacity(blocks.len());
        let mut diags = Vec::with_capacity(blocks.len());
        let mut plans: Vec<Arc<SolvePlan>> = Vec::with_capacity(blocks.len());
        for tb in blocks {
            let (name, model) = &models[tb.tenant];
            kstars.push(model.cross(&tb.xs));
            diags.push(model.prior_diag(&tb.xs));
            plans.push(cache.get_or_plan_with_fingerprint(
                name,
                fps[tb.tenant],
                model.op(),
                &opts,
            ));
        }
        // same-n tenants batch into one BatchOp dispatch; distinct sizes
        // run as their own (possibly singleton) batches
        let mut by_n: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (g, tb) in blocks.iter().enumerate() {
            by_n.entry(models[tb.tenant].1.op().n()).or_default().push(g);
        }
        let mut out: Vec<Option<Prediction>> = (0..blocks.len()).map(|_| None).collect();
        for idxs in by_n.values() {
            let ops: Vec<&dyn LinearOp> =
                idxs.iter().map(|&g| models[blocks[g].tenant].1.op()).collect();
            let batch = BatchOp::new(ops);
            let queries: Vec<PosteriorQuery<'_>> = idxs
                .iter()
                .map(|&g| PosteriorQuery {
                    k_star: &kstars[g],
                    k_star_diag: &diags[g],
                    y: models[blocks[g].tenant].1.y(),
                })
                .collect();
            let plan_refs: Vec<&SolvePlan> = idxs.iter().map(|&g| plans[g].as_ref()).collect();
            let preds = predict_batch_op(&batch, &queries, &plan_refs, &opts);
            for (&g, p) in idxs.iter().zip(preds) {
                out[g] = Some(p);
            }
        }
        out.into_iter()
            .map(|p| p.expect("every block answered"))
            .collect()
    })
}

/// Run the accept loop (blocking). Returns the bound address via the
/// `on_ready` callback (useful when binding port 0 in tests).
pub fn serve(
    config: ServerConfig,
    batcher: Arc<DynamicBatcher>,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(&config.addr)?;
    listener.set_nonblocking(true)?;
    if !config.operator.is_empty() {
        // the deployment log records which operator composition is serving
        println!("hosting operator: {} ({} shards)", config.operator, config.shard_count);
    }
    on_ready(listener.local_addr()?);
    let mut handles = Vec::new();
    while !config.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let b = Arc::clone(&batcher);
                handles.push(std::thread::spawn(move || handle_conn(stream, b)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, batcher: Arc<DynamicBatcher>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let response = handle_line(&line, &batcher);
        if writer.write_all(response.as_bytes()).is_err() {
            break;
        }
        if writer.write_all(b"\n").is_err() {
            break;
        }
        if line.trim() == "QUIT" {
            break;
        }
    }
}

/// Pure request handler (unit-testable without sockets). A `name:` prefix
/// routes the request to that tenant; bare feature lines go to tenant 0.
pub fn handle_line(line: &str, batcher: &DynamicBatcher) -> String {
    let line = line.trim();
    if line.is_empty() {
        return "ERR empty request".to_string();
    }
    if line == "STATS" {
        return batcher.metrics.summary();
    }
    if line == "TENANTS" {
        return batcher
            .tenants()
            .iter()
            .map(|t| format!("{}:{}", t.name, t.dim))
            .collect::<Vec<_>>()
            .join(" ");
    }
    if line == "QUIT" {
        return "BYE".to_string();
    }
    let (tenant, payload) = match line.split_once(':') {
        Some((name, rest)) => match batcher.tenant_index(name.trim()) {
            Some(t) => (t, rest),
            None => {
                batcher.metrics.record_error();
                return format!("ERR unknown tenant {:?}", name.trim());
            }
        },
        None => (0, line),
    };
    let parsed: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    match parsed {
        Err(e) => {
            batcher.metrics.record_error();
            format!("ERR parse: {e}")
        }
        Ok(x) => match batcher.predict_for(tenant, x) {
            Ok((mean, var)) => format!("{mean:.9},{var:.9}"),
            Err(e) => {
                batcher.metrics.record_error();
                format!("ERR {e}")
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{BatchPolicy, PredictFn};
    use crate::gp::predict::Prediction;
    use crate::tensor::Mat;
    use std::io::{BufRead, BufReader, Write};

    fn echo_batcher(dim: usize) -> Arc<DynamicBatcher> {
        let f: PredictFn = Box::new(|xs: &Mat| Prediction {
            mean: (0..xs.rows()).map(|i| xs.row(i).iter().sum()).collect(),
            var: vec![0.5; xs.rows()],
        });
        Arc::new(DynamicBatcher::new(dim, BatchPolicy::default(), f))
    }

    #[test]
    fn handle_line_predict() {
        let b = echo_batcher(2);
        let resp = handle_line("1.0, 2.0", &b);
        assert!(resp.starts_with("3.0"), "{resp}");
    }

    #[test]
    fn handle_line_errors() {
        let b = echo_batcher(2);
        assert!(handle_line("", &b).starts_with("ERR"));
        assert!(handle_line("a,b", &b).starts_with("ERR"));
        assert!(handle_line("1.0", &b).starts_with("ERR")); // wrong dim
        assert!(handle_line("STATS", &b).contains("requests="));
    }

    #[test]
    fn tenant_prefixed_lines_route_and_list() {
        use crate::coordinator::batcher::{MultiPredictFn, TenantBatch, TenantSpec};
        let multi: MultiPredictFn = Box::new(|blocks: &[TenantBatch]| {
            blocks
                .iter()
                .map(|tb| Prediction {
                    mean: (0..tb.xs.rows())
                        .map(|i| 100.0 * tb.tenant as f64 + tb.xs.row(i).iter().sum::<f64>())
                        .collect(),
                    var: vec![0.5; tb.xs.rows()],
                })
                .collect()
        });
        let b = DynamicBatcher::new_multi(
            vec![
                TenantSpec {
                    name: "a".into(),
                    dim: 1,
                },
                TenantSpec {
                    name: "b".into(),
                    dim: 2,
                },
            ],
            BatchPolicy::default(),
            multi,
        );
        assert!(handle_line("a: 2.0", &b).starts_with("2.0"));
        assert!(handle_line("b: 1.0, 2.0", &b).starts_with("103.0"));
        // bare lines route to tenant 0
        assert!(handle_line("3.0", &b).starts_with("3.0"));
        assert!(handle_line("zzz:1.0", &b).starts_with("ERR unknown tenant"));
        assert_eq!(handle_line("TENANTS", &b), "a:1 b:2");
    }

    #[test]
    fn served_predictor_hosts_any_operator_composition() {
        // a low-rank-plus-diagonal posterior served through the generic
        // dispatcher (Woodbury direct path) — no model-specific glue
        use crate::linalg::cholesky::Cholesky;
        use crate::linalg::op::{AddedDiagOp, LowRankOp};
        use crate::util::Rng;

        struct LowRankModel {
            op: AddedDiagOp<LowRankOp>,
            x: Mat,
            y: Vec<f64>,
        }
        impl ServableModel for LowRankModel {
            fn op(&self) -> &dyn LinearOp {
                &self.op
            }
            fn cross(&self, xs: &Mat) -> Mat {
                // linear-kernel cross-covariance X*·Xᵀ (factor is X itself)
                xs.matmul_t(&self.x)
            }
            fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
                (0..xs.rows())
                    .map(|i| xs.row(i).iter().map(|v| v * v).sum())
                    .collect()
            }
            fn y(&self) -> &[f64] {
                &self.y
            }
        }

        let mut rng = Rng::new(42);
        let x = Mat::from_fn(30, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..30)
            .map(|i| x.get(i, 0) - 0.5 * x.get(i, 1) + 0.01 * rng.normal())
            .collect();
        let model = LowRankModel {
            op: AddedDiagOp::new(LowRankOp::new(x.clone()), 0.01),
            x: x.clone(),
            y: y.clone(),
        };
        assert!(model.describe().contains("Woodbury"));
        let predictor = served_predictor(Box::new(model), SolveOptions::default());
        let b = Arc::new(DynamicBatcher::new(2, BatchPolicy::default(), predictor));
        let resp = handle_line("0.5, -0.25", &b);
        assert!(!resp.starts_with("ERR"), "{resp}");
        // reference: dense posterior mean through an explicit Cholesky
        let mut k = x.matmul_t(&x);
        k.add_diag(0.01);
        let alpha = Cholesky::new_with_jitter(&k).unwrap().solve_vec(&y);
        let kstar: Vec<f64> = (0..30)
            .map(|i| 0.5 * x.get(i, 0) - 0.25 * x.get(i, 1))
            .collect();
        let want: f64 = kstar.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        let got: f64 = resp.split(',').next().unwrap().parse().unwrap();
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn end_to_end_tcp_roundtrip() {
        let b = echo_batcher(2);
        let stop = Arc::new(AtomicBool::new(false));
        let config = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            operator: "echo".to_string(),
            shard_count: 1,
            stop: Arc::clone(&stop),
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let srv = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                serve(config, b, move |addr| {
                    addr_tx.send(addr).unwrap();
                })
                .unwrap();
            })
        };
        let addr = addr_rx.recv().unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"2.0,3.0\nSTATS\nQUIT\n").unwrap();
        let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
        let first = lines.next().unwrap().unwrap();
        assert!(first.starts_with("5.0"), "{first}");
        let stats = lines.next().unwrap().unwrap();
        assert!(stats.contains("requests=1"), "{stats}");
        let bye = lines.next().unwrap().unwrap();
        assert_eq!(bye, "BYE");
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
