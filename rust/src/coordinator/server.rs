//! TCP prediction server (std::net; the offline crate set has no tokio).
//!
//! Line protocol, one request per line:
//!
//! ```text
//! → 0.12,3.4,-1.0\n          (comma-separated features → tenant 0)
//! ← 0.873,0.0021\n           (mean, variance)
//! → wine:0.12,3.4,-1.0\n     (routed to the tenant named `wine`)
//! ← 0.873,0.0021\n
//! → VAR wine:0.12,3.4,-1.0\n (LOVE constant-time variance)
//! ← 0.0021\n
//! → SAMPLE 3 wine:0.12,3.4,-1.0\n
//! ← 0.871,0.902,0.845\n      (posterior draws from the cached root)
//! → TENANTS\n
//! ← wine:11 airfoil:5\n      (name:dim per hosted tenant)
//! → STATS\n
//! ← requests=… batches=…\n
//! ```
//!
//! Each connection gets a handler thread; all handlers feed the shared
//! [`DynamicBatcher`], so concurrent clients are served out of coalesced
//! batched GP solves — and in a multi-tenant deployment
//! ([`multi_served_predictor`]), every tick answers all tenants through
//! **one** `BatchOp` dispatch with per-tenant solve plans cached across
//! predict calls.
//!
//! With LOVE enabled ([`serve_with_love`] + a [`LoveServeCtx`]) the
//! `VAR`/`SAMPLE` verbs bypass the batcher entirely: each is answered in
//! O(n·r) from the tenant's cached [`LovePosterior`] — the point of the
//! posterior cache is that these queries need no coalescing because they
//! no longer pay a solve.

use crate::coordinator::batcher::{DynamicBatcher, MultiPredictFn, PredictFn, TenantBatch};
use crate::coordinator::metrics::Metrics;
use crate::gp::posterior::{LovePosterior, PosteriorCache};
use crate::gp::predict::{
    predict_batch_hetero_ws, predict_batch_op_ws, PosteriorQuery, Prediction,
};
use crate::linalg::mbcg::MbcgWorkspace;
use crate::linalg::op::{
    solve_strategy, BatchOp, LinearOp, SolveOptions, SolvePlan, SolvePlanCache,
};
use crate::tensor::Mat;
use crate::util::Rng;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    /// Human-readable description of the hosted operator composition
    /// (e.g. `AddedDiag(ShardedCov(rbf) × 8)`), echoed at startup so the
    /// deployment log records what algebra is serving traffic.
    pub operator: String,
    /// Row-shard count of the serving model's covariance backend (1 =
    /// monolithic dense operator), recorded here so the deployment config
    /// carries how the operator was sized to traffic. The server itself
    /// does not build the model — the launcher (`bbmm serve --shards N`)
    /// constructs the sharded operator, fills this in, and echoes it at
    /// startup.
    pub shard_count: usize,
    /// stop flag the caller can flip to shut the accept loop down
    pub stop: Arc<AtomicBool>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7777".to_string(),
            operator: String::new(),
            shard_count: 1,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// A servable GP posterior: **any** [`LinearOp`] composition plus the
/// model-side pieces a posterior needs (cross-covariance, prior variances,
/// targets). This is the seam `bbmm serve` threads the operator algebra
/// through — exact, sharded, SGPR, and SKI models all implement it with a
/// few lines, and the server solves every prediction through the generic
/// dispatcher ([`crate::linalg::op::solve()`]).
pub trait ServableModel: Send + Sync {
    /// The training operator `K̂` (noise included in the composition).
    fn op(&self) -> &dyn LinearOp;
    /// Cross-covariance `K(X*, X)` rows for a batch of query points.
    fn cross(&self, xs: &Mat) -> Mat;
    /// Prior variances `k(x*, x*)` per query point.
    fn prior_diag(&self, xs: &Mat) -> Vec<f64>;
    /// Training targets.
    fn y(&self) -> &[f64];
    /// One-line operator description for the startup log.
    fn describe(&self) -> String {
        format!(
            "LinearOp n={} strategy={:?}",
            self.op().n(),
            solve_strategy(self.op())
        )
    }
}

/// Shared LOVE serving state: the hosted models plus a per-tenant
/// [`PosteriorCache`] keyed by tenant name. Connection handlers answer
/// `VAR`/`SAMPLE` through it directly, and the LOVE tick predictors
/// ([`served_predictor_love`] / [`multi_served_predictor_love`]) answer
/// ordinary mean,variance lines from the same cached posteriors — one
/// posterior build per tenant per hyperparameter setting, shared by every
/// path.
pub struct LoveServeCtx {
    models: Vec<(String, Arc<dyn ServableModel>)>,
    /// per-tenant operator fingerprints, computed once (served models are
    /// immutable behind the Arc)
    fps: Vec<u64>,
    rank: usize,
    opts: SolveOptions,
    posteriors: Arc<PosteriorCache>,
    /// sampler state shared across connection handlers
    rng: Mutex<Rng>,
}

impl LoveServeCtx {
    /// Bundle the hosted `models` (tenant order must match the batcher's
    /// [`TenantSpec`](crate::coordinator::batcher::TenantSpec) order) with
    /// a posterior cache at LOVE rank `rank`.
    pub fn new(
        models: Vec<(String, Arc<dyn ServableModel>)>,
        rank: usize,
        opts: SolveOptions,
        posteriors: Arc<PosteriorCache>,
        seed: u64,
    ) -> Self {
        assert!(rank > 0, "LOVE rank must be positive");
        assert!(!models.is_empty(), "LoveServeCtx needs at least one model");
        let fps = models.iter().map(|(_, m)| m.op().fingerprint()).collect();
        LoveServeCtx {
            models,
            fps,
            rank,
            opts,
            posteriors,
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    /// Hosted tenant count.
    pub fn tenant_count(&self) -> usize {
        self.models.len()
    }

    /// Configured LOVE rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The tenant's cached posterior (built on first use, O(1) after).
    fn posterior_for(&self, tenant: usize) -> Arc<LovePosterior> {
        let (name, m) = &self.models[tenant];
        self.posteriors.get_or_build_with_fingerprint(
            name,
            self.fps[tenant],
            m.op(),
            m.y(),
            self.rank,
            &self.opts,
        )
    }

    /// Mean + variance for a tenant's query block from the cached
    /// posterior — two skinny GEMMs, no solve.
    pub fn predict(&self, tenant: usize, xs: &Mat) -> Prediction {
        let (_, m) = &self.models[tenant];
        let k_star = m.cross(xs);
        let diag = m.prior_diag(xs);
        self.posterior_for(tenant).predict(&k_star, &diag)
    }

    /// Constant-time predictive variance at one point (the `VAR` verb).
    pub fn variance(&self, tenant: usize, x: Vec<f64>) -> f64 {
        let xs = Mat::from_vec(1, x.len(), x);
        self.predict(tenant, &xs).var[0]
    }

    /// `k` posterior draws at one point from the cached root (the
    /// `SAMPLE` verb).
    pub fn sample(&self, tenant: usize, x: Vec<f64>, k: usize) -> Vec<f64> {
        let (_, m) = &self.models[tenant];
        let xs = Mat::from_vec(1, x.len(), x);
        let k_star = m.cross(&xs);
        let prior = Mat::from_vec(1, 1, vec![m.prior_diag(&xs)[0]]);
        let post = self.posterior_for(tenant);
        let mut rng = self.rng.lock().unwrap();
        let draws = post.sample(&k_star, &prior, k, &mut rng);
        draws.row(0).to_vec()
    }

    /// Posterior-cache counter summary (appended to `STATS`).
    pub fn stats(&self) -> String {
        self.posteriors.stats()
    }

    /// Build every tenant's posterior now instead of on first use, so the
    /// first request after startup pays two skinny GEMMs — not a LOVE
    /// factorisation. `bbmm serve` calls this before binding the socket.
    pub fn prime(&self) {
        for t in 0..self.models.len() {
            let _ = self.posterior_for(t);
        }
    }
}

/// Single-model LOVE tick predictor: ordinary mean,variance lines are
/// answered from the tenant-0 cached posterior instead of a per-batch
/// solve.
pub fn served_predictor_love(ctx: Arc<LoveServeCtx>) -> PredictFn {
    Box::new(move |xs: &Mat| ctx.predict(0, xs))
}

/// Multi-tenant LOVE tick predictor: every tenant block in the tick is
/// answered from that tenant's cached posterior — the batcher still
/// coalesces, but a tick is b skinny GEMMs instead of a `BatchOp` solve.
pub fn multi_served_predictor_love(ctx: Arc<LoveServeCtx>) -> MultiPredictFn {
    Box::new(move |blocks: &[TenantBatch]| {
        blocks.iter().map(|tb| ctx.predict(tb.tenant, &tb.xs)).collect()
    })
}

/// Wrap a servable model into the batcher's [`PredictFn`]: each coalesced
/// batch becomes one cross-covariance build plus one dispatched solve —
/// no model lock, since [`LinearOp`] solves are `&self`. The solve plan
/// (Woodbury capacitance factor / pivoted-Cholesky preconditioner) lives
/// in a [`SolvePlanCache`]: prepared once, reused every batch, rebuilt
/// only if the operator's content changes.
pub fn served_predictor(model: Box<dyn ServableModel>, opts: SolveOptions) -> PredictFn {
    served_predictor_cached(model, opts, Arc::new(SolvePlanCache::new()))
}

/// [`served_predictor`] with a caller-held plan cache (observable
/// hit/miss/invalidation counters — the deployment's factorisation log).
pub fn served_predictor_cached(
    model: Box<dyn ServableModel>,
    opts: SolveOptions,
    cache: Arc<SolvePlanCache>,
) -> PredictFn {
    // the served model is moved into the closure with no mutation path,
    // so its content fingerprint is computed once, not per tick —
    // and the plan (factorisation / preconditioner) is primed here so
    // the first request after startup pays a solve, not a plan build
    let fp = model.op().fingerprint();
    let _ = cache.get_or_plan_with_fingerprint("default", fp, model.op(), &opts);
    // one warm solver workspace held across ticks: without it every
    // predict call rebuilt the mBCG arena from cold
    let ws: Mutex<MbcgWorkspace> = Mutex::new(MbcgWorkspace::new());
    Box::new(move |xs: &Mat| -> Prediction {
        let k_star = model.cross(xs);
        let diag = model.prior_diag(xs);
        let plan = cache.get_or_plan_with_fingerprint("default", fp, model.op(), &opts);
        let batch = BatchOp::new(vec![model.op()]);
        let queries = [PosteriorQuery {
            k_star: &k_star,
            k_star_diag: &diag,
            y: model.y(),
        }];
        let mut guard = ws.lock().unwrap();
        let mut preds = predict_batch_op_ws(&batch, &queries, &[plan.as_ref()], &opts, &mut guard);
        preds.pop().expect("one query answered")
    })
}

/// Host **many** tenants behind one predictor: each batching tick carries
/// every tenant's coalesced RHS block, and this closure answers them all
/// through a single [`predict_batch_op_ws`] dispatch — same-shape tenants
/// stack into one [`BatchOp`] (iterative ones then share one `mbcg_batch`
/// iteration loop), per-tenant [`SolvePlan`]s come from `cache` keyed by
/// tenant name, so factorisations/preconditioners persist across predict
/// calls and rebuild only on hyperparameter change. The solver's
/// [`MbcgWorkspace`] persists the same way — one warm arena per tenant
/// group size, held across ticks, instead of a rebuild per call.
pub fn multi_served_predictor(
    models: Vec<(String, Box<dyn ServableModel>)>,
    opts: SolveOptions,
    cache: Arc<SolvePlanCache>,
) -> MultiPredictFn {
    // served models are moved into the closure with no mutation path, so
    // per-tenant fingerprints are computed once, not per tick — and every
    // tenant's plan is primed now so no request pays a factorisation
    let fps: Vec<u64> = models.iter().map(|(_, m)| m.op().fingerprint()).collect();
    for ((name, m), &fp) in models.iter().zip(&fps) {
        let _ = cache.get_or_plan_with_fingerprint(name, fp, m.op(), &opts);
    }
    // group-size n → warm solver workspace, reused every tick (the
    // predictor must be Sync, so ticks take the workspace through a lock;
    // same-n groups from concurrent ticks serialise on it, which is the
    // batcher's cadence anyway)
    let workspaces: Mutex<BTreeMap<usize, MbcgWorkspace>> = Mutex::new(BTreeMap::new());
    Box::new(move |blocks: &[TenantBatch]| -> Vec<Prediction> {
        // per-block posterior pieces + cached plans
        let mut kstars = Vec::with_capacity(blocks.len());
        let mut diags = Vec::with_capacity(blocks.len());
        let mut plans: Vec<Arc<SolvePlan>> = Vec::with_capacity(blocks.len());
        for tb in blocks {
            let (name, model) = &models[tb.tenant];
            kstars.push(model.cross(&tb.xs));
            diags.push(model.prior_diag(&tb.xs));
            plans.push(cache.get_or_plan_with_fingerprint(
                name,
                fps[tb.tenant],
                model.op(),
                &opts,
            ));
        }
        // same-n tenants batch into one BatchOp dispatch; distinct sizes
        // run as their own (possibly singleton) batches
        let mut by_n: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (g, tb) in blocks.iter().enumerate() {
            by_n.entry(models[tb.tenant].1.op().n()).or_default().push(g);
        }
        let mut out: Vec<Option<Prediction>> = (0..blocks.len()).map(|_| None).collect();
        for (&gn, idxs) in by_n.iter() {
            let ops: Vec<&dyn LinearOp> =
                idxs.iter().map(|&g| models[blocks[g].tenant].1.op()).collect();
            let batch = BatchOp::new(ops);
            let queries: Vec<PosteriorQuery<'_>> = idxs
                .iter()
                .map(|&g| PosteriorQuery {
                    k_star: &kstars[g],
                    k_star_diag: &diags[g],
                    y: models[blocks[g].tenant].1.y(),
                })
                .collect();
            let plan_refs: Vec<&SolvePlan> = idxs.iter().map(|&g| plans[g].as_ref()).collect();
            let mut wss = workspaces.lock().unwrap();
            let ws = wss.entry(gn).or_default();
            let preds = predict_batch_op_ws(&batch, &queries, &plan_refs, &opts, ws);
            for (&g, p) in idxs.iter().zip(preds) {
                out[g] = Some(p);
            }
        }
        out.into_iter()
            .map(|p| p.expect("every block answered"))
            .collect()
    })
}

/// The heterogeneous serving hot path: every tenant block of a tick —
/// regardless of training-set size `n` or model family — is answered
/// through **one** fused iterative solve per tick
/// ([`predict_batch_hetero_ws`]). Direct-planned tenants (Cholesky /
/// Woodbury / circulant) ride the same loop as preconditioners and
/// converge in one iteration; per-block early stopping drops each block
/// as its own tolerance is met. Compare [`multi_served_predictor`], which
/// pays one solve *per distinct n* per tick.
///
/// Every fused tick is counted on `metrics`
/// ([`Metrics::record_fused`]: one solve + its block occupancy), so
/// `STATS` exposes `fused=`/`fused_blocks=` — share the same `Arc` with
/// the batcher via
/// [`DynamicBatcher::new_multi_with_metrics`](crate::coordinator::batcher::DynamicBatcher::new_multi_with_metrics).
/// Plans are primed at construction; the solver workspace is keyed by the
/// tick's total stacked size and kept warm across ticks.
pub fn multi_served_predictor_fused(
    models: Vec<(String, Box<dyn ServableModel>)>,
    opts: SolveOptions,
    cache: Arc<SolvePlanCache>,
    metrics: Arc<Metrics>,
) -> MultiPredictFn {
    let fps: Vec<u64> = models.iter().map(|(_, m)| m.op().fingerprint()).collect();
    for ((name, m), &fp) in models.iter().zip(&fps) {
        let _ = cache.get_or_plan_with_fingerprint(name, fp, m.op(), &opts);
    }
    // total stacked size Σnᵢ → warm solver workspace, reused every tick
    let workspaces: Mutex<BTreeMap<usize, MbcgWorkspace>> = Mutex::new(BTreeMap::new());
    Box::new(move |blocks: &[TenantBatch]| -> Vec<Prediction> {
        let mut kstars = Vec::with_capacity(blocks.len());
        let mut diags = Vec::with_capacity(blocks.len());
        let mut plans: Vec<Arc<SolvePlan>> = Vec::with_capacity(blocks.len());
        let mut stacked = 0usize;
        for tb in blocks {
            let (name, model) = &models[tb.tenant];
            kstars.push(model.cross(&tb.xs));
            diags.push(model.prior_diag(&tb.xs));
            plans.push(cache.get_or_plan_with_fingerprint(
                name,
                fps[tb.tenant],
                model.op(),
                &opts,
            ));
            stacked += model.op().n();
        }
        let els: Vec<&dyn LinearOp> =
            blocks.iter().map(|tb| models[tb.tenant].1.op()).collect();
        let queries: Vec<PosteriorQuery<'_>> = blocks
            .iter()
            .enumerate()
            .map(|(g, tb)| PosteriorQuery {
                k_star: &kstars[g],
                k_star_diag: &diags[g],
                y: models[tb.tenant].1.y(),
            })
            .collect();
        let plan_refs: Vec<&SolvePlan> = plans.iter().map(|p| p.as_ref()).collect();
        let per_opts = vec![opts; blocks.len()];
        let mut wss = workspaces.lock().unwrap();
        let ws = wss.entry(stacked).or_default();
        let (preds, _stats) = predict_batch_hetero_ws(&els, &queries, &plan_refs, &per_opts, ws);
        metrics.record_fused(blocks.len() as u64);
        preds
    })
}

/// Run the accept loop (blocking). Returns the bound address via the
/// `on_ready` callback (useful when binding port 0 in tests).
pub fn serve(
    config: ServerConfig,
    batcher: Arc<DynamicBatcher>,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    serve_with_love(config, batcher, None, on_ready)
}

/// [`serve`] with an optional LOVE context: when present, the `VAR` and
/// `SAMPLE` verbs are live and answered constant-time from the per-tenant
/// posterior cache; when `None` they return `ERR LOVE disabled`.
pub fn serve_with_love(
    config: ServerConfig,
    batcher: Arc<DynamicBatcher>,
    love: Option<Arc<LoveServeCtx>>,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(&config.addr)?;
    listener.set_nonblocking(true)?;
    if !config.operator.is_empty() {
        // the deployment log records which operator composition is serving
        println!("hosting operator: {} ({} shards)", config.operator, config.shard_count);
    }
    on_ready(listener.local_addr()?);
    let mut handles = Vec::new();
    while !config.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let b = Arc::clone(&batcher);
                let l = love.clone();
                handles.push(std::thread::spawn(move || handle_conn(stream, b, l)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, batcher: Arc<DynamicBatcher>, love: Option<Arc<LoveServeCtx>>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let response = handle_request(&line, &batcher, love.as_deref());
        if writer.write_all(response.as_bytes()).is_err() {
            break;
        }
        if writer.write_all(b"\n").is_err() {
            break;
        }
        if line.trim() == "QUIT" {
            break;
        }
    }
}

/// Pure request handler (unit-testable without sockets). A `name:` prefix
/// routes the request to that tenant; bare feature lines go to tenant 0.
/// Equivalent to [`handle_request`] with no LOVE context.
pub fn handle_line(line: &str, batcher: &DynamicBatcher) -> String {
    handle_request(line, batcher, None)
}

/// Route a `[name:]features` payload to a tenant and parse + dimension-
/// check the feature vector (the shared front half of the `VAR`/`SAMPLE`
/// paths). Errors come back as ready-to-send `ERR …` lines.
fn parse_routed(payload: &str, batcher: &DynamicBatcher) -> Result<(usize, Vec<f64>), String> {
    let (tenant, rest) = match payload.split_once(':') {
        Some((name, rest)) => match batcher.tenant_index(name.trim()) {
            Some(t) => (t, rest),
            None => return Err(format!("ERR unknown tenant {:?}", name.trim())),
        },
        None => (0, payload),
    };
    let x: Vec<f64> = rest
        .split(',')
        .map(|f| f.trim().parse::<f64>())
        .collect::<Result<_, _>>()
        .map_err(|e| format!("ERR parse: {e}"))?;
    if let Some(spec) = batcher.tenants().get(tenant) {
        if x.len() != spec.dim {
            return Err(format!("ERR dim: expected {} features, got {}", spec.dim, x.len()));
        }
    }
    Ok((tenant, x))
}

/// [`handle_line`] with an optional LOVE context enabling the `VAR` and
/// `SAMPLE` verbs (constant-time, answered outside the batcher — they pay
/// no solve, so there is nothing to coalesce).
pub fn handle_request(line: &str, batcher: &DynamicBatcher, love: Option<&LoveServeCtx>) -> String {
    let line = line.trim();
    if line.is_empty() {
        return "ERR empty request".to_string();
    }
    if line == "STATS" {
        let mut s = batcher.metrics.summary();
        if let Some(ctx) = love {
            s.push(' ');
            s.push_str(&ctx.stats());
        }
        return s;
    }
    if line == "TENANTS" {
        return batcher
            .tenants()
            .iter()
            .map(|t| format!("{}:{}", t.name, t.dim))
            .collect::<Vec<_>>()
            .join(" ");
    }
    if line == "QUIT" {
        return "BYE".to_string();
    }
    if let Some(rest) = line.strip_prefix("VAR ") {
        let Some(ctx) = love else {
            batcher.metrics.record_error();
            return "ERR LOVE disabled".to_string();
        };
        return match parse_routed(rest, batcher) {
            Err(e) => {
                batcher.metrics.record_error();
                e
            }
            Ok((tenant, x)) => {
                let t0 = Instant::now();
                let var = ctx.variance(tenant, x);
                batcher.metrics.record_request(t0.elapsed().as_micros() as u64);
                format!("{var:.9}")
            }
        };
    }
    if let Some(rest) = line.strip_prefix("SAMPLE ") {
        let Some(ctx) = love else {
            batcher.metrics.record_error();
            return "ERR LOVE disabled".to_string();
        };
        let Some((k_str, payload)) = rest.trim().split_once(' ') else {
            batcher.metrics.record_error();
            return "ERR usage: SAMPLE <k> [tenant:]<features>".to_string();
        };
        let k: usize = match k_str.trim().parse() {
            Ok(k) if k > 0 => k,
            _ => {
                batcher.metrics.record_error();
                return format!("ERR sample count {:?} must be a positive integer", k_str.trim());
            }
        };
        return match parse_routed(payload, batcher) {
            Err(e) => {
                batcher.metrics.record_error();
                e
            }
            Ok((tenant, x)) => {
                let t0 = Instant::now();
                let draws = ctx.sample(tenant, x, k);
                batcher.metrics.record_request(t0.elapsed().as_micros() as u64);
                draws
                    .iter()
                    .map(|d| format!("{d:.9}"))
                    .collect::<Vec<_>>()
                    .join(",")
            }
        };
    }
    let (tenant, payload) = match line.split_once(':') {
        Some((name, rest)) => match batcher.tenant_index(name.trim()) {
            Some(t) => (t, rest),
            None => {
                batcher.metrics.record_error();
                return format!("ERR unknown tenant {:?}", name.trim());
            }
        },
        None => (0, line),
    };
    let parsed: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    match parsed {
        Err(e) => {
            batcher.metrics.record_error();
            format!("ERR parse: {e}")
        }
        Ok(x) => match batcher.predict_for(tenant, x) {
            Ok((mean, var)) => format!("{mean:.9},{var:.9}"),
            Err(e) => {
                batcher.metrics.record_error();
                format!("ERR {e}")
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{BatchPolicy, PredictFn};
    use crate::gp::predict::Prediction;
    use crate::tensor::Mat;
    use std::io::{BufRead, BufReader, Write};

    fn echo_batcher(dim: usize) -> Arc<DynamicBatcher> {
        let f: PredictFn = Box::new(|xs: &Mat| Prediction {
            mean: (0..xs.rows()).map(|i| xs.row(i).iter().sum()).collect(),
            var: vec![0.5; xs.rows()],
        });
        Arc::new(DynamicBatcher::new(dim, BatchPolicy::default(), f))
    }

    #[test]
    fn handle_line_predict() {
        let b = echo_batcher(2);
        let resp = handle_line("1.0, 2.0", &b);
        assert!(resp.starts_with("3.0"), "{resp}");
    }

    #[test]
    fn handle_line_errors() {
        let b = echo_batcher(2);
        assert!(handle_line("", &b).starts_with("ERR"));
        assert!(handle_line("a,b", &b).starts_with("ERR"));
        assert!(handle_line("1.0", &b).starts_with("ERR")); // wrong dim
        assert!(handle_line("STATS", &b).contains("requests="));
    }

    #[test]
    fn tenant_prefixed_lines_route_and_list() {
        use crate::coordinator::batcher::{MultiPredictFn, TenantBatch, TenantSpec};
        let multi: MultiPredictFn = Box::new(|blocks: &[TenantBatch]| {
            blocks
                .iter()
                .map(|tb| Prediction {
                    mean: (0..tb.xs.rows())
                        .map(|i| 100.0 * tb.tenant as f64 + tb.xs.row(i).iter().sum::<f64>())
                        .collect(),
                    var: vec![0.5; tb.xs.rows()],
                })
                .collect()
        });
        let b = DynamicBatcher::new_multi(
            vec![TenantSpec::new("a", 1), TenantSpec::new("b", 2)],
            BatchPolicy::default(),
            multi,
        );
        assert!(handle_line("a: 2.0", &b).starts_with("2.0"));
        assert!(handle_line("b: 1.0, 2.0", &b).starts_with("103.0"));
        // bare lines route to tenant 0
        assert!(handle_line("3.0", &b).starts_with("3.0"));
        assert!(handle_line("zzz:1.0", &b).starts_with("ERR unknown tenant"));
        assert_eq!(handle_line("TENANTS", &b), "a:1 b:2");
    }

    #[test]
    fn served_predictor_hosts_any_operator_composition() {
        // a low-rank-plus-diagonal posterior served through the generic
        // dispatcher (Woodbury direct path) — no model-specific glue
        use crate::linalg::cholesky::Cholesky;
        use crate::linalg::op::{AddedDiagOp, LowRankOp};
        use crate::util::Rng;

        struct LowRankModel {
            op: AddedDiagOp<LowRankOp>,
            x: Mat,
            y: Vec<f64>,
        }
        impl ServableModel for LowRankModel {
            fn op(&self) -> &dyn LinearOp {
                &self.op
            }
            fn cross(&self, xs: &Mat) -> Mat {
                // linear-kernel cross-covariance X*·Xᵀ (factor is X itself)
                xs.matmul_t(&self.x)
            }
            fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
                (0..xs.rows())
                    .map(|i| xs.row(i).iter().map(|v| v * v).sum())
                    .collect()
            }
            fn y(&self) -> &[f64] {
                &self.y
            }
        }

        let mut rng = Rng::new(42);
        let x = Mat::from_fn(30, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..30)
            .map(|i| x.get(i, 0) - 0.5 * x.get(i, 1) + 0.01 * rng.normal())
            .collect();
        let model = LowRankModel {
            op: AddedDiagOp::new(LowRankOp::new(x.clone()), 0.01),
            x: x.clone(),
            y: y.clone(),
        };
        assert!(model.describe().contains("Woodbury"));
        let predictor = served_predictor(Box::new(model), SolveOptions::default());
        let b = Arc::new(DynamicBatcher::new(2, BatchPolicy::default(), predictor));
        let resp = handle_line("0.5, -0.25", &b);
        assert!(!resp.starts_with("ERR"), "{resp}");
        // reference: dense posterior mean through an explicit Cholesky
        let mut k = x.matmul_t(&x);
        k.add_diag(0.01);
        let alpha = Cholesky::new_with_jitter(&k).unwrap().solve_vec(&y);
        let kstar: Vec<f64> = (0..30)
            .map(|i| 0.5 * x.get(i, 0) - 0.25 * x.get(i, 1))
            .collect();
        let want: f64 = kstar.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        let got: f64 = resp.split(',').next().unwrap().parse().unwrap();
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn var_and_sample_verbs_answer_from_the_posterior_cache() {
        use crate::kernels::{DenseKernelOp, Rbf};
        use crate::util::Rng;

        struct ExactModel {
            op: DenseKernelOp,
            y: Vec<f64>,
        }
        impl ServableModel for ExactModel {
            fn op(&self) -> &dyn LinearOp {
                &self.op
            }
            fn cross(&self, xs: &Mat) -> Mat {
                self.op.cross(xs, self.op.x())
            }
            fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
                (0..xs.rows())
                    .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
                    .collect()
            }
            fn y(&self) -> &[f64] {
                &self.y
            }
        }

        let n = 50;
        let mut rng = Rng::new(11);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| (3.0 * x.get(i, 0)).sin()).collect();
        let model = ExactModel {
            op: DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1),
            y,
        };
        // dense reference variance at the probe point
        let kd = model.op.dense();
        let xs = Mat::from_vec(1, 2, vec![0.3, -0.2]);
        let k_star = model.cross(&xs);
        let kss = model.prior_diag(&xs)[0];
        let ch = crate::linalg::cholesky::Cholesky::new_with_jitter(&kd).unwrap();
        let solved = ch.solve_mat(&k_star.transpose());
        let quad: f64 = (0..n).map(|i| k_star.get(0, i) * solved.get(i, 0)).sum();
        let want_var = kss - quad;

        let opts = SolveOptions {
            max_iters: 400,
            tol: 1e-10,
            precond_rank: 5,
        };
        let posteriors = Arc::new(PosteriorCache::new());
        let ctx = Arc::new(LoveServeCtx::new(
            vec![("default".to_string(), Arc::new(model) as Arc<dyn ServableModel>)],
            n, // full rank ⇒ exact
            opts,
            Arc::clone(&posteriors),
            1,
        ));
        let b = DynamicBatcher::new(
            2,
            BatchPolicy::default(),
            served_predictor_love(Arc::clone(&ctx)),
        );

        // VAR answers the dense-reference variance constant-time
        let resp = handle_request("VAR 0.3,-0.2", &b, Some(&ctx));
        let got: f64 = resp.parse().expect(&resp);
        assert!((got - want_var).abs() < 1e-6, "{got} vs {want_var}");
        // the ordinary mean,var line agrees with VAR through the LOVE
        // tick predictor
        let line = handle_request("0.3,-0.2", &b, Some(&ctx));
        let var_part: f64 = line.split(',').nth(1).unwrap().parse().unwrap();
        assert!((var_part - got).abs() < 1e-9, "{line}");
        // SAMPLE returns k finite draws
        let resp = handle_request("SAMPLE 5 default:0.3,-0.2", &b, Some(&ctx));
        let draws: Vec<f64> = resp.split(',').map(|d| d.parse().unwrap()).collect();
        assert_eq!(draws.len(), 5);
        assert!(draws.iter().all(|d| d.is_finite()));
        // one posterior build served every verb
        assert_eq!(posteriors.misses(), 1, "{}", posteriors.stats());
        assert!(posteriors.hits() >= 2);
        // protocol errors
        assert!(handle_request("VAR 0.3,-0.2", &b, None).starts_with("ERR LOVE disabled"));
        assert!(handle_request("SAMPLE 0 default:0.3,-0.2", &b, Some(&ctx)).starts_with("ERR"));
        assert!(handle_request("SAMPLE x", &b, Some(&ctx)).starts_with("ERR"));
        assert!(handle_request("VAR ghost:0.3,-0.2", &b, Some(&ctx)).starts_with("ERR unknown"));
        assert!(handle_request("VAR 0.3", &b, Some(&ctx)).starts_with("ERR dim"));
        // STATS carries the posterior-cache counters when LOVE is live
        let stats = handle_request("STATS", &b, Some(&ctx));
        assert!(stats.contains("posteriors=1"), "{stats}");
    }

    #[test]
    fn end_to_end_tcp_roundtrip() {
        let b = echo_batcher(2);
        let stop = Arc::new(AtomicBool::new(false));
        let config = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            operator: "echo".to_string(),
            shard_count: 1,
            stop: Arc::clone(&stop),
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let srv = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                serve(config, b, move |addr| {
                    addr_tx.send(addr).unwrap();
                })
                .unwrap();
            })
        };
        let addr = addr_rx.recv().unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"2.0,3.0\nSTATS\nQUIT\n").unwrap();
        let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
        let first = lines.next().unwrap().unwrap();
        assert!(first.starts_with("5.0"), "{first}");
        let stats = lines.next().unwrap().unwrap();
        assert!(stats.contains("requests=1"), "{stats}");
        let bye = lines.next().unwrap().unwrap();
        assert_eq!(bye, "BYE");
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
