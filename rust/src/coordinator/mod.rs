//! L3 prediction coordinator: a request router with **dynamic batching**.
//!
//! After training, a GP model serves predictions. Each incoming request is
//! one test point; the batcher coalesces concurrent requests into a single
//! batched predictive solve (one mBCG call for the whole batch — exactly
//! the regime BBMM is built for), trading a small queueing delay for much
//! higher throughput. A plain TCP front-end (std::net; tokio is not
//! available offline) exposes the batcher over a line-oriented protocol.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{
    BatchPolicy, DynamicBatcher, MultiPredictFn, PredictFn, TenantBatch, TenantSpec,
};
pub use metrics::Metrics;
pub use server::{
    handle_line, handle_request, multi_served_predictor, multi_served_predictor_fused,
    multi_served_predictor_love, serve, serve_with_love, served_predictor,
    served_predictor_cached, served_predictor_love, LoveServeCtx, ServableModel, ServerConfig,
};
