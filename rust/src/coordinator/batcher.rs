//! Dynamic batcher: coalesces concurrent single-point prediction requests
//! into one batched GP predictive solve.
//!
//! Policy: a worker thread drains the queue; a batch closes when it reaches
//! `max_batch` points or `max_wait` has elapsed since the first queued
//! request (vLLM-style continuous batching, specialised to stateless
//! predictions). The GP side benefits directly: one mBCG call with an
//! `n×(1+B)` RHS block replaces B separate solves — the same
//! batching-beats-sequential argument as the paper's Figure 2.

use crate::coordinator::metrics::Metrics;
use crate::gp::predict::Prediction;
use crate::tensor::Mat;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A batched predictor: takes a `B×d` matrix of query points, returns
/// means/variances.
pub type PredictFn = Box<dyn Fn(&Mat) -> Prediction + Send + Sync>;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
        }
    }
}

struct Request {
    x: Vec<f64>,
    reply: Sender<(f64, f64)>,
    enqueued: Instant,
}

/// Dynamic batcher handle. Cloneable; submit from any thread.
pub struct DynamicBatcher {
    tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    dim: usize,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl DynamicBatcher {
    /// Spawn the batching worker around a batched predictor.
    pub fn new(dim: usize, policy: BatchPolicy, predict: PredictFn) -> Self {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || {
            Self::worker_loop(rx, policy, predict, m2, dim);
        });
        DynamicBatcher {
            tx,
            metrics,
            dim,
            worker: Some(worker),
        }
    }

    fn worker_loop(
        rx: Receiver<Request>,
        policy: BatchPolicy,
        predict: PredictFn,
        metrics: Arc<Metrics>,
        dim: usize,
    ) {
        loop {
            // block for the first request of a batch
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders dropped — shut down
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + policy.max_wait;
            while batch.len() < policy.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            // form the batch matrix and run one batched predict
            let b = batch.len();
            let mut xs = Mat::zeros(b, dim);
            for (i, req) in batch.iter().enumerate() {
                xs.row_mut(i).copy_from_slice(&req.x);
            }
            let pred = predict(&xs);
            metrics.record_batch();
            let now = Instant::now();
            for (i, req) in batch.into_iter().enumerate() {
                let latency = now.duration_since(req.enqueued).as_micros() as u64;
                metrics.record_request(latency);
                // receiver may have gone away; that's fine
                let _ = req.reply.send((pred.mean[i], pred.var[i]));
            }
        }
    }

    /// Submit one query point; returns a receiver for (mean, variance).
    pub fn submit(&self, x: Vec<f64>) -> Result<Receiver<(f64, f64)>, String> {
        if x.len() != self.dim {
            return Err(format!("expected {} features, got {}", self.dim, x.len()));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request {
                x,
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .map_err(|_| "batcher shut down".to_string())?;
        Ok(reply_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn predict_one(&self, x: Vec<f64>) -> Result<(f64, f64), String> {
        let rx = self.submit(x)?;
        rx.recv().map_err(|_| "worker dropped reply".to_string())
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        // closing the channel stops the worker
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Shared handle for multi-threaded front-ends.
pub type SharedBatcher = Arc<Mutex<()>>;

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_predictor() -> PredictFn {
        // mean = sum of features, var = 1
        Box::new(|xs: &Mat| {
            let mean: Vec<f64> = (0..xs.rows()).map(|i| xs.row(i).iter().sum()).collect();
            let var = vec![1.0; xs.rows()];
            Prediction { mean, var }
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let b = DynamicBatcher::new(2, BatchPolicy::default(), echo_predictor());
        let (mean, var) = b.predict_one(vec![1.5, 2.5]).unwrap();
        assert!((mean - 4.0).abs() < 1e-12);
        assert_eq!(var, 1.0);
    }

    #[test]
    fn wrong_dimension_rejected() {
        let b = DynamicBatcher::new(3, BatchPolicy::default(), echo_predictor());
        assert!(b.submit(vec![1.0]).is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_millis(20),
            },
            echo_predictor(),
        ));
        let mut handles = Vec::new();
        for i in 0..20 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                b.predict_one(vec![i as f64]).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (mean, _var) = h.join().unwrap();
            assert!((mean - i as f64).abs() < 1e-12);
        }
        // 20 requests should have been served in far fewer than 20 batches
        let batches = b.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches < 20, "batches={batches}");
        assert!(b.metrics.mean_batch_size() > 1.0);
    }

    #[test]
    fn max_batch_respected() {
        // slow predictor lets the queue build up; max_batch caps each batch
        let slow: PredictFn = Box::new(|xs: &Mat| {
            std::thread::sleep(Duration::from_millis(5));
            Prediction {
                mean: vec![0.0; xs.rows()],
                var: vec![0.0; xs.rows()],
            }
        });
        let b = Arc::new(DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            slow,
        ));
        let mut rxs = Vec::new();
        for i in 0..16 {
            rxs.push(b.submit(vec![i as f64]).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = b.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches >= 4, "batches={batches}");
    }
}
