//! Dynamic batcher: coalesces concurrent prediction requests into batched
//! GP predictive solves, with a **tenant routing layer** for multi-model
//! deployments.
//!
//! Policy: a worker thread drains the queue; a batch ("tick") closes when
//! it reaches `max_batch` points or `max_wait` has elapsed since the first
//! queued request (vLLM-style continuous batching, specialised to
//! stateless predictions). Within a tick, same-tenant requests are
//! coalesced into one RHS block, and the per-tick predictor receives
//! **all** tenants' blocks in one call — the multi-tenant server turns
//! that into a single `BatchOp` solve
//! ([`crate::coordinator::multi_served_predictor`]), so cross-tenant
//! traffic shares one mBCG iteration loop exactly as the paper's Figure 2
//! argues batched RHSs should.
//!
//! The submit path is bounded: `max_queue` pending requests, beyond which
//! `submit` fails fast instead of building an unbounded backlog.
//!
//! **Deadlines.** A tenant (or the policy) may carry a deadline class.
//! Admission control sheds a request up front — error line `ERR deadline …`
//! — when the smoothed tick latency times the queue backlog says the
//! deadline cannot be met; a request that expires while queued is
//! fast-failed by the worker instead of being solved past its deadline.
//! Without a deadline class the batcher behaves exactly as before.

use crate::coordinator::metrics::Metrics;
use crate::gp::predict::Prediction;
use crate::tensor::Mat;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A batched single-model predictor: takes a `B×d` matrix of query
/// points, returns means/variances.
pub type PredictFn = Box<dyn Fn(&Mat) -> Prediction + Send + Sync>;

/// One tenant's coalesced slice of a tick: which tenant, and its query
/// points stacked into a `B_t×d_t` block.
pub struct TenantBatch {
    /// tenant index (into the batcher's [`TenantSpec`] table)
    pub tenant: usize,
    /// this tenant's query points for the tick
    pub xs: Mat,
}

/// A batched multi-tenant predictor: answers every tenant's block of a
/// tick in one call; `out[k]` must hold predictions for `batches[k].xs`
/// row-for-row.
pub type MultiPredictFn = Box<dyn Fn(&[TenantBatch]) -> Vec<Prediction> + Send + Sync>;

/// A served tenant: routing name, feature dimension, and optional
/// deadline class.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// routing key (the `name:` prefix of the line protocol)
    pub name: String,
    /// expected feature count per request
    pub dim: usize,
    /// deadline class: requests for this tenant must be answered within
    /// this budget or they are shed/fast-failed. `None` falls back to the
    /// policy's [`BatchPolicy::default_deadline`].
    pub deadline: Option<Duration>,
}

impl TenantSpec {
    /// A tenant with no deadline class of its own.
    pub fn new(name: impl Into<String>, dim: usize) -> Self {
        TenantSpec {
            name: name.into(),
            dim,
            deadline: None,
        }
    }

    /// Attach a deadline class.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// pending-request bound: `submit` fails fast beyond this
    pub max_queue: usize,
    /// deadline applied to tenants without their own class; `None`
    /// disables deadline handling entirely (legacy behaviour)
    pub default_deadline: Option<Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            default_deadline: None,
        }
    }
}

struct Request {
    tenant: usize,
    x: Vec<f64>,
    reply: Sender<Result<(f64, f64), String>>,
    enqueued: Instant,
    /// absolute expiry computed at submit (tenant class, else policy default)
    deadline: Option<Instant>,
}

/// Earliest-deadline-first order for a drained tick: deadlined requests
/// ascending by absolute expiry, then the deadline-free tail; the stable
/// sort keeps arrival order inside every tie class.
fn edf_sort(batch: &mut [Request]) {
    batch.sort_by_key(|r| (r.deadline.is_none(), r.deadline));
}

/// Dynamic batcher handle. Submit from any thread.
pub struct DynamicBatcher {
    tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    tenants: Vec<TenantSpec>,
    pending: Arc<AtomicUsize>,
    max_queue: usize,
    max_batch: usize,
    default_deadline: Option<Duration>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl DynamicBatcher {
    /// Spawn the batching worker around a single-model predictor (tenant 0,
    /// routing name `"default"`).
    pub fn new(dim: usize, policy: BatchPolicy, predict: PredictFn) -> Self {
        let multi: MultiPredictFn = Box::new(move |batches: &[TenantBatch]| {
            batches.iter().map(|tb| predict(&tb.xs)).collect()
        });
        Self::new_multi(vec![TenantSpec::new("default", dim)], policy, multi)
    }

    /// Spawn the batching worker around a multi-tenant predictor.
    pub fn new_multi(
        tenants: Vec<TenantSpec>,
        policy: BatchPolicy,
        predict: MultiPredictFn,
    ) -> Self {
        Self::new_multi_with_metrics(tenants, policy, predict, Arc::new(Metrics::new()))
    }

    /// Like [`DynamicBatcher::new_multi`], but shares an existing metrics
    /// sink — the fused serving path uses this so the predictor can count
    /// fused solves on the same `Metrics` the batcher reports through.
    pub fn new_multi_with_metrics(
        tenants: Vec<TenantSpec>,
        policy: BatchPolicy,
        predict: MultiPredictFn,
        metrics: Arc<Metrics>,
    ) -> Self {
        assert!(!tenants.is_empty(), "batcher needs at least one tenant");
        let (tx, rx) = channel::<Request>();
        let pending = Arc::new(AtomicUsize::new(0));
        let m2 = Arc::clone(&metrics);
        let p2 = Arc::clone(&pending);
        let dims: Vec<usize> = tenants.iter().map(|t| t.dim).collect();
        let worker = std::thread::spawn(move || {
            Self::worker_loop(rx, policy, predict, m2, p2, dims);
        });
        DynamicBatcher {
            tx,
            metrics,
            tenants,
            pending,
            max_queue: policy.max_queue.max(1),
            max_batch: policy.max_batch.max(1),
            default_deadline: policy.default_deadline,
            worker: Some(worker),
        }
    }

    fn worker_loop(
        rx: Receiver<Request>,
        policy: BatchPolicy,
        predict: MultiPredictFn,
        metrics: Arc<Metrics>,
        pending: Arc<AtomicUsize>,
        dims: Vec<usize>,
    ) {
        loop {
            // block for the first request of a tick
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders dropped — shut down
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + policy.max_wait;
            while batch.len() < policy.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            let left = pending.fetch_sub(batch.len(), Ordering::Relaxed) - batch.len();
            metrics.set_queue_depth(left as u64);
            // fast-fail requests whose deadline already passed while they
            // sat in the queue — solving them would waste the tick on
            // answers nobody can use
            let now = Instant::now();
            let mut live = Vec::with_capacity(batch.len());
            for req in batch {
                match req.deadline {
                    Some(d) if now > d => {
                        metrics.record_expired();
                        let waited = now.duration_since(req.enqueued).as_micros();
                        let _ = req.reply.send(Err(format!(
                            "deadline expired: waited {waited}us in queue"
                        )));
                    }
                    _ => live.push(req),
                }
            }
            if live.is_empty() {
                continue;
            }
            // earliest-deadline-first drain: the tick solves every drained
            // request regardless, but EDF ordering puts the most urgent
            // rows (and below, the most urgent tenant blocks) first, so
            // replies stream back in deadline order once the solve lands
            let mut batch = live;
            edf_sort(&mut batch);
            // route: coalesce same-tenant requests into one RHS block,
            // preserving EDF order within each tenant
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); dims.len()];
            for (j, req) in batch.iter().enumerate() {
                groups[req.tenant].push(j);
            }
            // tenant blocks assemble in order of each tenant's most urgent
            // request (batch is EDF-sorted, so that is its first index)
            let mut tenant_order: Vec<usize> =
                (0..dims.len()).filter(|&tn| !groups[tn].is_empty()).collect();
            tenant_order.sort_by_key(|&tn| groups[tn][0]);
            let mut blocks: Vec<TenantBatch> = Vec::new();
            let mut slot = vec![(0usize, 0usize); batch.len()];
            for &tenant in &tenant_order {
                let idxs = &groups[tenant];
                let mut xs = Mat::zeros(idxs.len(), dims[tenant]);
                for (row, &j) in idxs.iter().enumerate() {
                    xs.row_mut(row).copy_from_slice(&batch[j].x);
                    slot[j] = (blocks.len(), row);
                }
                blocks.push(TenantBatch { tenant, xs });
            }
            // one predictor call per tick: every tenant's block at once
            let tick_start = Instant::now();
            let preds = predict(&blocks);
            metrics.record_tick(tick_start.elapsed().as_micros() as u64);
            debug_assert_eq!(preds.len(), blocks.len());
            metrics.record_batch();
            let now = Instant::now();
            for (j, req) in batch.into_iter().enumerate() {
                let latency = now.duration_since(req.enqueued).as_micros() as u64;
                metrics.record_request(latency);
                let (g, row) = slot[j];
                // receiver may have gone away; that's fine
                let _ = req.reply.send(Ok((preds[g].mean[row], preds[g].var[row])));
            }
        }
    }

    /// Submit one query point for a specific tenant; returns a receiver
    /// for `Ok((mean, variance))` or a deadline fast-fail. Fails fast on
    /// unknown tenant, feature-count mismatch, a full queue, or — when the
    /// tenant carries a deadline class — an unmeetable deadline at the
    /// current queue depth (admission control).
    pub fn submit_to(
        &self,
        tenant: usize,
        x: Vec<f64>,
    ) -> Result<Receiver<Result<(f64, f64), String>>, String> {
        let spec = self
            .tenants
            .get(tenant)
            .ok_or_else(|| format!("unknown tenant index {tenant}"))?;
        if x.len() != spec.dim {
            return Err(format!(
                "tenant {}: expected {} features, got {}",
                spec.name,
                spec.dim,
                x.len()
            ));
        }
        let deadline = spec.deadline.or(self.default_deadline);
        if let Some(d) = deadline {
            // admission control: estimate the wait this request faces from
            // the smoothed tick latency and the ticks already queued ahead
            // of it; shed now rather than queue work that must expire
            let ewma = self.metrics.ewma_tick_us();
            if ewma > 0 {
                let depth = self.pending.load(Ordering::Relaxed);
                let ticks_ahead = 1 + depth / self.max_batch;
                let est_wait_us = ewma.saturating_mul(ticks_ahead as u64);
                if est_wait_us > d.as_micros() as u64 {
                    self.metrics.record_shed();
                    return Err(format!(
                        "deadline {}ms unmeetable: estimated wait {est_wait_us}us \
                         at queue depth {depth}",
                        d.as_millis()
                    ));
                }
            }
        }
        let was = self.pending.fetch_add(1, Ordering::Relaxed);
        if was >= self.max_queue {
            self.pending.fetch_sub(1, Ordering::Relaxed);
            return Err(format!(
                "queue full: {was} requests pending (max {})",
                self.max_queue
            ));
        }
        self.metrics.set_queue_depth((was + 1) as u64);
        let (reply_tx, reply_rx) = channel();
        let enqueued = Instant::now();
        match self.tx.send(Request {
            tenant,
            x,
            reply: reply_tx,
            enqueued,
            deadline: deadline.map(|d| enqueued + d),
        }) {
            Ok(()) => Ok(reply_rx),
            Err(_) => {
                self.pending.fetch_sub(1, Ordering::Relaxed);
                Err("batcher shut down".to_string())
            }
        }
    }

    /// Submit one query point to tenant 0 (single-model deployments).
    pub fn submit(&self, x: Vec<f64>) -> Result<Receiver<Result<(f64, f64), String>>, String> {
        self.submit_to(0, x)
    }

    /// Blocking convenience: submit to a tenant and wait.
    pub fn predict_for(&self, tenant: usize, x: Vec<f64>) -> Result<(f64, f64), String> {
        let rx = self.submit_to(tenant, x)?;
        rx.recv().map_err(|_| "worker dropped reply".to_string())?
    }

    /// Blocking convenience: submit to tenant 0 and wait.
    pub fn predict_one(&self, x: Vec<f64>) -> Result<(f64, f64), String> {
        self.predict_for(0, x)
    }

    /// Tenant index for a routing name.
    pub fn tenant_index(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// The tenant table.
    pub fn tenants(&self) -> &[TenantSpec] {
        &self.tenants
    }

    /// Feature dimension of tenant 0 (single-model deployments).
    pub fn dim(&self) -> usize {
        self.tenants[0].dim
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        // closing the channel stops the worker
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Shared handle for multi-threaded front-ends.
pub type SharedBatcher = Arc<Mutex<()>>;

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_predictor() -> PredictFn {
        // mean = sum of features, var = 1
        Box::new(|xs: &Mat| {
            let mean: Vec<f64> = (0..xs.rows()).map(|i| xs.row(i).iter().sum()).collect();
            let var = vec![1.0; xs.rows()];
            Prediction { mean, var }
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let b = DynamicBatcher::new(2, BatchPolicy::default(), echo_predictor());
        let (mean, var) = b.predict_one(vec![1.5, 2.5]).unwrap();
        assert!((mean - 4.0).abs() < 1e-12);
        assert_eq!(var, 1.0);
    }

    #[test]
    fn wrong_dimension_rejected() {
        let b = DynamicBatcher::new(3, BatchPolicy::default(), echo_predictor());
        let err = b.submit(vec![1.0]).unwrap_err();
        assert!(err.contains("expected 3 features, got 1"), "{err}");
    }

    #[test]
    fn unknown_tenant_rejected() {
        let b = DynamicBatcher::new(2, BatchPolicy::default(), echo_predictor());
        let err = b.submit_to(5, vec![1.0, 2.0]).unwrap_err();
        assert!(err.contains("unknown tenant index 5"), "{err}");
        assert_eq!(b.tenant_index("default"), Some(0));
        assert_eq!(b.tenant_index("nope"), None);
    }

    #[test]
    fn queue_full_fails_fast_and_recovers() {
        // a predictor that signals entry and then blocks on a gate makes
        // the fill genuinely deterministic: once `entered` fires, the
        // first request has been drained (pending decremented) and the
        // worker is parked inside predict
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let gate = Mutex::new((entered_tx, gate_rx));
        let blocked: PredictFn = Box::new(move |xs: &Mat| {
            let guard = gate.lock().unwrap();
            let _ = guard.0.send(());
            let _ = guard.1.recv();
            Prediction {
                mean: vec![0.0; xs.rows()],
                var: vec![0.0; xs.rows()],
            }
        });
        let b = DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                max_queue: 3,
                ..BatchPolicy::default()
            },
            blocked,
        );
        // first request is drained into a tick and blocks the worker on
        // the gate; then fill the queue to its bound
        let mut rxs = vec![b.submit(vec![0.0]).unwrap()];
        entered_rx.recv().unwrap();
        for i in 0..3 {
            rxs.push(b.submit(vec![i as f64]).unwrap());
        }
        let err = b.submit(vec![9.0]).unwrap_err();
        assert!(err.contains("queue full"), "{err}");
        // release the worker: every accepted request completes, and the
        // queue accepts again
        for _ in 0..rxs.len() + 1 {
            let _ = gate_tx.send(());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let _ = gate_tx.send(());
        assert!(b.predict_one(vec![1.0]).is_ok());
        // drain the entry signals so the channel closing is clean
        while entered_rx.try_recv().is_ok() {}
    }

    #[test]
    fn edf_sort_orders_deadlines_ascending_then_deadline_free_arrivals() {
        let now = Instant::now();
        let mk = |tenant: usize, deadline: Option<Duration>| {
            let (reply, _rx) = channel();
            Request {
                tenant,
                x: Vec::new(),
                reply,
                enqueued: now,
                deadline: deadline.map(|d| now + d),
            }
        };
        let mut batch = vec![
            mk(0, None),
            mk(1, Some(Duration::from_millis(30))),
            mk(2, Some(Duration::from_millis(10))),
            mk(3, None),
            mk(4, Some(Duration::from_millis(20))),
        ];
        edf_sort(&mut batch);
        let order: Vec<usize> = batch.iter().map(|r| r.tenant).collect();
        // deadlines ascending first; the deadline-free pair keeps arrival order
        assert_eq!(order, vec![2, 4, 1, 0, 3]);
    }

    #[test]
    fn ticks_assemble_tenant_blocks_in_deadline_order() {
        // park the worker inside a first tick, queue a slow-deadline and
        // then a fast-deadline request, and check the second tick's block
        // order put the fast tenant first even though it arrived last
        let calls: Arc<Mutex<Vec<Vec<usize>>>> = Arc::new(Mutex::new(Vec::new()));
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let gate = Mutex::new((entered_tx, gate_rx));
        let c2 = Arc::clone(&calls);
        let predict: MultiPredictFn = Box::new(move |blocks: &[TenantBatch]| {
            c2.lock()
                .unwrap()
                .push(blocks.iter().map(|tb| tb.tenant).collect());
            let guard = gate.lock().unwrap();
            let _ = guard.0.send(());
            let _ = guard.1.recv();
            blocks
                .iter()
                .map(|tb| Prediction {
                    mean: vec![0.0; tb.xs.rows()],
                    var: vec![0.0; tb.xs.rows()],
                })
                .collect()
        });
        let b = DynamicBatcher::new_multi(
            vec![
                TenantSpec::new("slow", 1).with_deadline(Duration::from_secs(60)),
                TenantSpec::new("fast", 1).with_deadline(Duration::from_secs(2)),
            ],
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(30),
                ..BatchPolicy::default()
            },
            predict,
        );
        let first = b.submit_to(0, vec![0.0]).unwrap();
        entered_rx.recv().unwrap(); // tick 1 is parked on the gate
        let slow = b.submit_to(0, vec![1.0]).unwrap();
        let fast = b.submit_to(1, vec![2.0]).unwrap();
        gate_tx.send(()).unwrap(); // release tick 1; tick 2 drains both
        entered_rx.recv().unwrap();
        gate_tx.send(()).unwrap();
        first.recv().unwrap().unwrap();
        slow.recv().unwrap().unwrap();
        fast.recv().unwrap().unwrap();
        let calls = calls.lock().unwrap();
        assert_eq!(calls.len(), 2, "expected exactly two ticks");
        assert_eq!(calls[0], vec![0]);
        // EDF: tenant 1's absolute deadline (2 s out) beats tenant 0's
        // (60 s out), so its block assembles first in the shared tick
        assert_eq!(calls[1], vec![1, 0]);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_millis(20),
                ..BatchPolicy::default()
            },
            echo_predictor(),
        ));
        let mut handles = Vec::new();
        for i in 0..20 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                b.predict_one(vec![i as f64]).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (mean, _var) = h.join().unwrap();
            assert!((mean - i as f64).abs() < 1e-12);
        }
        // 20 requests should have been served in far fewer than 20 batches
        let batches = b.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches < 20, "batches={batches}");
        assert!(b.metrics.mean_batch_size() > 1.0);
    }

    #[test]
    fn max_batch_respected() {
        // slow predictor lets the queue build up; max_batch caps each batch
        let slow: PredictFn = Box::new(|xs: &Mat| {
            std::thread::sleep(Duration::from_millis(5));
            Prediction {
                mean: vec![0.0; xs.rows()],
                var: vec![0.0; xs.rows()],
            }
        });
        let b = Arc::new(DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            slow,
        ));
        let mut rxs = Vec::new();
        for i in 0..16 {
            rxs.push(b.submit(vec![i as f64]).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = b.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches >= 4, "batches={batches}");
    }

    #[test]
    fn tenants_route_to_their_own_blocks() {
        // two tenants with different dims; the multi predictor tags means
        // by tenant so cross-routing would be visible
        let multi: MultiPredictFn = Box::new(|blocks: &[TenantBatch]| {
            blocks
                .iter()
                .map(|tb| Prediction {
                    mean: (0..tb.xs.rows())
                        .map(|i| 1000.0 * tb.tenant as f64 + tb.xs.row(i).iter().sum::<f64>())
                        .collect(),
                    var: vec![tb.tenant as f64; tb.xs.rows()],
                })
                .collect()
        });
        let b = Arc::new(DynamicBatcher::new_multi(
            vec![TenantSpec::new("a", 1), TenantSpec::new("b", 2)],
            BatchPolicy {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
                ..BatchPolicy::default()
            },
            multi,
        ));
        assert_eq!(b.tenant_index("b"), Some(1));
        let mut handles = Vec::new();
        for i in 0..6 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                if i % 2 == 0 {
                    (i, b.predict_for(0, vec![i as f64]).unwrap())
                } else {
                    (i, b.predict_for(1, vec![i as f64, 1.0]).unwrap())
                }
            }));
        }
        for h in handles {
            let (i, (mean, var)) = h.join().unwrap();
            if i % 2 == 0 {
                assert!((mean - i as f64).abs() < 1e-12, "tenant a req {i}");
                assert_eq!(var, 0.0);
            } else {
                assert!((mean - (1000.0 + i as f64 + 1.0)).abs() < 1e-12, "tenant b req {i}");
                assert_eq!(var, 1.0);
            }
        }
        // interleaved tenants were still coalesced into shared ticks
        assert!(b.metrics.mean_batch_size() > 1.0);
    }

    #[test]
    fn deadline_admission_sheds_unmeetable_requests() {
        let b = DynamicBatcher::new_multi(
            vec![TenantSpec::new("fast", 1).with_deadline(Duration::from_millis(1))],
            BatchPolicy::default(),
            Box::new(|blocks: &[TenantBatch]| {
                blocks
                    .iter()
                    .map(|tb| Prediction {
                        mean: vec![0.0; tb.xs.rows()],
                        var: vec![1.0; tb.xs.rows()],
                    })
                    .collect()
            }),
        );
        // no tick history yet → no estimate → admitted and answered
        assert!(b.predict_for(0, vec![1.0]).is_ok());
        // fake a pathological tick history: every tick takes ~10s, so a
        // 1ms deadline is provably unmeetable and admission must shed
        b.metrics.record_tick(10_000_000);
        let err = b.predict_for(0, vec![1.0]).unwrap_err();
        assert!(err.starts_with("deadline"), "{err}");
        assert!(err.contains("unmeetable"), "{err}");
        assert_eq!(b.metrics.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn expired_requests_are_fast_failed_by_the_worker() {
        // block the worker inside a tick, queue a short-deadline request,
        // and let it expire before the gate opens: the worker must reply
        // with the documented deadline error instead of solving it
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let gate = Mutex::new((entered_tx, gate_rx));
        let blocked: PredictFn = Box::new(move |xs: &Mat| {
            let guard = gate.lock().unwrap();
            let _ = guard.0.send(());
            let _ = guard.1.recv();
            Prediction {
                mean: vec![7.0; xs.rows()],
                var: vec![1.0; xs.rows()],
            }
        });
        let b = DynamicBatcher::new(
            1,
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                default_deadline: Some(Duration::from_millis(5)),
                ..BatchPolicy::default()
            },
            blocked,
        );
        // first request enters a tick and parks the worker on the gate
        let rx0 = b.submit(vec![0.0]).unwrap();
        entered_rx.recv().unwrap();
        // second request waits in the queue past its 5ms deadline
        let rx1 = b.submit(vec![1.0]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let _ = gate_tx.send(());
        assert!(rx0.recv().unwrap().is_ok());
        let err = rx1.recv().unwrap().unwrap_err();
        assert!(err.starts_with("deadline expired"), "{err}");
        assert_eq!(b.metrics.expired.load(Ordering::Relaxed), 1);
        // gate stays open for any stray tick
        let _ = gate_tx.send(());
        while entered_rx.try_recv().is_ok() {}
    }
}
