//! Serving metrics: counters plus simple latency histograms.
//!
//! Two histograms share one log₂-bucket layout: per-request latency
//! (enqueue → reply) and per-tick latency (one batched predictor call).
//! The tick EWMA feeds the batcher's deadline admission control — the
//! estimated wait a new request faces is a small multiple of it.

use std::sync::atomic::{AtomicU64, Ordering};

/// log₂-bucketed latency histogram: bucket i counts latencies in
/// [2^i, 2^{i+1}) microseconds.
#[derive(Default)]
struct LatencyHist {
    buckets: [AtomicU64; 24],
}

impl LatencyHist {
    fn record(&self, us: u64) {
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(23);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// approximate p-quantile from the histogram (µs)
    fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << i) as f64 * 1.5; // bucket midpoint
            }
        }
        (1u64 << 23) as f64
    }
}

/// Lock-free serving metrics (shared across worker threads).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// requests refused at admission: the deadline could not be met at the
    /// current queue depth (backpressure, counted before any queueing)
    pub shed: AtomicU64,
    /// requests that expired while queued and were fast-failed by the
    /// worker instead of being solved past their deadline
    pub expired: AtomicU64,
    /// fused heterogeneous ticks (one per mixed-tenant batched solve)
    pub fused_solves: AtomicU64,
    /// tenant blocks answered across all fused ticks (occupancy numerator;
    /// divide by `fused_solves` for mean fused-block occupancy)
    pub fused_blocks: AtomicU64,
    /// queue-depth gauge: pending requests at the last submit/drain
    queue_depth: AtomicU64,
    /// total latency in microseconds (for mean)
    total_latency_us: AtomicU64,
    /// per-request latency histogram (enqueue → reply)
    lat: LatencyHist,
    /// per-tick latency histogram (one batched predictor call)
    tick: LatencyHist,
    /// EWMA of tick latency in µs (admission control's wait estimate)
    ewma_tick_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(latency_us, Ordering::Relaxed);
        self.lat.record(latency_us);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was refused at admission (deadline unmeetable).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued request expired before its tick and was fast-failed.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// One fused heterogeneous tick answered `blocks` tenant blocks.
    pub fn record_fused(&self, blocks: u64) {
        self.fused_solves.fetch_add(1, Ordering::Relaxed);
        self.fused_blocks.fetch_add(blocks, Ordering::Relaxed);
    }

    /// Publish the current pending-queue depth (a gauge, not a counter).
    pub fn set_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Last published queue depth.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// One batched predictor call took `us` µs: histogram + EWMA update.
    /// (Single writer — the batching worker — so the read-modify-write
    /// EWMA needs no CAS loop.)
    pub fn record_tick(&self, us: u64) {
        self.tick.record(us);
        let old = self.ewma_tick_us.load(Ordering::Relaxed);
        let new = if old == 0 { us } else { (3 * old + us) / 4 };
        self.ewma_tick_us.store(new, Ordering::Relaxed);
    }

    /// Smoothed tick latency in µs (0 until the first tick completes) —
    /// what admission control multiplies by the queue backlog.
    pub fn ewma_tick_us(&self) -> u64 {
        self.ewma_tick_us.load(Ordering::Relaxed)
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// approximate p-quantile request latency from the histogram (µs)
    pub fn quantile_latency_us(&self, q: f64) -> f64 {
        self.lat.quantile(q)
    }

    /// approximate p-quantile tick latency (µs)
    pub fn quantile_tick_us(&self, q: f64) -> f64 {
        self.tick.quantile(q)
    }

    /// requests per batch (batching efficiency)
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} errors={} shed={} expired={} queue={} \
             fused={} fused_blocks={} mean_batch={:.2} mean_lat={:.0}us \
             p50={:.0}us p99={:.0}us tick_p50={:.0}us tick_p95={:.0}us tick_p99={:.0}us",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.queue_depth(),
            self.fused_solves.load(Ordering::Relaxed),
            self.fused_blocks.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.quantile_latency_us(0.5),
            self.quantile_latency_us(0.99),
            self.quantile_tick_us(0.5),
            self.quantile_tick_us(0.95),
            self.quantile_tick_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100);
        m.record_request(300);
        m.record_batch();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert!((m.mean_latency_us() - 200.0).abs() < 1e-9);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_ordered() {
        let m = Metrics::new();
        for i in 1..1000u64 {
            m.record_request(i * 10);
        }
        let p50 = m.quantile_latency_us(0.5);
        let p99 = m.quantile_latency_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.quantile_latency_us(0.9), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.quantile_tick_us(0.9), 0.0);
        assert_eq!(m.ewma_tick_us(), 0);
        assert_eq!(m.queue_depth(), 0);
    }

    #[test]
    fn backpressure_counters_round_trip_through_summary() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_expired();
        m.record_fused(3);
        m.record_fused(2);
        m.set_queue_depth(7);
        m.record_tick(1000);
        m.record_tick(3000);
        let s = m.summary();
        assert!(s.contains("shed=2"), "{s}");
        assert!(s.contains("expired=1"), "{s}");
        assert!(s.contains("queue=7"), "{s}");
        assert!(s.contains("fused=2"), "{s}");
        assert!(s.contains("fused_blocks=5"), "{s}");
        assert!(s.contains("tick_p50="), "{s}");
        // EWMA moved toward the latest tick but remembers the first
        let e = m.ewma_tick_us();
        assert!(e > 1000 && e < 3000, "ewma {e}");
        assert!(m.quantile_tick_us(0.5) > 0.0);
    }
}
