//! Serving metrics: counters plus a simple latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free serving metrics (shared across worker threads).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// total latency in microseconds (for mean)
    total_latency_us: AtomicU64,
    /// log₂-bucketed latency histogram: bucket i counts latencies in
    /// [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 24],
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(latency_us, Ordering::Relaxed);
        let bucket = (64 - latency_us.max(1).leading_zeros() as usize - 1).min(23);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// approximate p-quantile latency from the histogram (µs)
    pub fn quantile_latency_us(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << i) as f64 * 1.5; // bucket midpoint
            }
        }
        (1u64 << 23) as f64
    }

    /// requests per batch (batching efficiency)
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} errors={} mean_batch={:.2} mean_lat={:.0}us p50={:.0}us p99={:.0}us",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.quantile_latency_us(0.5),
            self.quantile_latency_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100);
        m.record_request(300);
        m.record_batch();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert!((m.mean_latency_us() - 200.0).abs() < 1e-9);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_ordered() {
        let m = Metrics::new();
        for i in 1..1000u64 {
            m.record_request(i * 10);
        }
        let p50 = m.quantile_latency_us(0.5);
        let p99 = m.quantile_latency_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.quantile_latency_us(0.9), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
    }
}
