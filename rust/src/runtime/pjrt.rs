//! PJRT-backed artifact runtime (`--features pjrt`).
//!
//! Compiles HLO-text artifacts once on the PJRT CPU client, caches the
//! executables, and runs them from the Rust hot path. Requires the
//! vendored `xla` crate in [dependencies]; the offline default build uses
//! [`super::stub`] instead.

use super::{scan_artifacts, Result, RuntimeError, TensorF32};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A named, compiled artifact registry over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU-backed runtime rooted at `artifact_dir`.
    pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError::new(format!("pjrt cpu client: {e:?}")))?;
        Ok(Runtime {
            client,
            executables: HashMap::new(),
            artifact_dir: artifact_dir.into(),
        })
    }

    /// Whether compiled-artifact execution is possible in this build.
    pub fn backend_available(&self) -> bool {
        true
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt` under key `name`
    /// (no-op if already loaded).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| RuntimeError::new(format!("parse {path:?}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError::new(format!("compile {name}: {e:?}")))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.executables.keys().cloned().collect();
        v.sort();
        v
    }

    /// List artifacts available on disk (without loading them).
    pub fn available(&self) -> Vec<String> {
        scan_artifacts(&self.artifact_dir)
    }

    /// Execute artifact `name` with f32 inputs, returning all f32 outputs
    /// (the jax lowering uses `return_tuple=True`, so the single result is
    /// a tuple we decompose).
    pub fn execute_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| RuntimeError::new(format!("artifact {name} not loaded")))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = xla::Literal::vec1(inp.data)
                .reshape(&inp.dims)
                .map_err(|e| RuntimeError::new(format!("reshape input: {e:?}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| RuntimeError::new(format!("execute {name}: {e:?}")))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::new(format!("fetch output: {e:?}")))?;
        let parts = out_lit
            .to_tuple()
            .map_err(|e| RuntimeError::new(format!("decompose tuple: {e:?}")))?;
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(
                p.to_vec::<f32>()
                    .map_err(|e| RuntimeError::new(format!("output to_vec: {e:?}")))?,
            );
        }
        Ok(outputs)
    }

    /// Check an artifact exists on disk.
    pub fn artifact_exists(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }
}
