//! Shard scheduler: static striping + work stealing over row shards.
//!
//! The sharded kernel operator ([`crate::kernels::ShardedKernelOp`])
//! partitions training rows into `S` contiguous shards, each owning the
//! work queue for its row-block of `(K + σ²I)·M` (the Wang et al. 2019
//! partitioned-kernel design, 1903.08114). This module is the runtime half:
//!
//! - [`partition_rows`] plans balanced contiguous row ranges,
//! - [`ShardQueue`] hands out disjoint row *tiles* of one shard,
//! - [`run`] drives a worker pool that stripes workers across shards
//!   (worker `w` starts on shard `w mod S`) and steals tiles from
//!   subsequent shards once its home queue drains,
//! - [`run_rows_mut`] is the typed variant that hands each tile its
//!   disjoint mutable row-block of a flat row-major output buffer.
//!
//! Shards are the unit that later maps 1:1 onto devices/processes; tiles
//! are the unit of load balancing within one host.

use crate::util::par;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Partition `0..n` into at most `shards` contiguous, balanced row ranges
/// (sizes differ by at most one row; never returns an empty slice).
pub fn partition_rows(n: usize, shards: usize) -> Vec<Range<usize>> {
    let s = shards.max(1).min(n.max(1));
    let base = n / s;
    let extra = n % s;
    let mut out = Vec::with_capacity(s);
    let mut lo = 0;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    debug_assert_eq!(lo, n);
    out
}

/// One shard's tile queue: pops disjoint row sub-ranges of the shard.
/// Lock-free (a single fetch-add per tile); a queue is drained once and
/// rebuilt per operator call.
pub struct ShardQueue {
    rows: Range<usize>,
    tile: usize,
    next: AtomicUsize,
}

impl ShardQueue {
    pub fn new(rows: Range<usize>, tile: usize) -> Self {
        ShardQueue {
            rows,
            tile: tile.max(1),
            next: AtomicUsize::new(0),
        }
    }

    /// The full row range this shard owns.
    pub fn rows(&self) -> Range<usize> {
        self.rows.clone()
    }

    /// Number of tiles this queue will serve in total.
    pub fn n_tiles(&self) -> usize {
        (self.rows.end - self.rows.start).div_ceil(self.tile)
    }

    /// Pop the next tile (a row range), or `None` once the shard is drained.
    pub fn pop(&self) -> Option<Range<usize>> {
        let len = self.rows.end - self.rows.start;
        let off = self.next.fetch_add(self.tile, Ordering::Relaxed);
        if off >= len {
            return None;
        }
        let lo = self.rows.start + off;
        Some(lo..(lo + self.tile).min(self.rows.end))
    }
}

/// Counters from one scheduler run (observability + tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    /// tiles executed in total
    pub tiles: usize,
    /// tiles a worker took from a non-home shard (work stealing)
    pub steals: usize,
    /// workers spawned (1 = ran inline)
    pub workers: usize,
}

/// Execute `work(shard_index, rows)` for every tile of every queue.
///
/// Workers are striped across shards: worker `w` drains shard `w mod S`
/// first, then walks the remaining shards round-robin, stealing whatever
/// tiles are left. Every tile is popped exactly once (the queues are
/// atomic), and every worker visits every queue, so all tiles complete
/// even with a single worker.
pub fn run<F>(queues: &[ShardQueue], work: F) -> RunStats
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let s = queues.len();
    let total_tiles: usize = queues.iter().map(|q| q.n_tiles()).sum();
    if s == 0 || total_tiles == 0 {
        return RunStats::default();
    }
    let workers = par::num_threads().min(total_tiles).max(1);
    if workers == 1 {
        let mut tiles = 0;
        for (si, q) in queues.iter().enumerate() {
            while let Some(r) = q.pop() {
                work(si, r);
                tiles += 1;
            }
        }
        return RunStats {
            tiles,
            steals: 0,
            workers: 1,
        };
    }
    let tiles = AtomicUsize::new(0);
    let steals = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let work = &work;
            let tiles = &tiles;
            let steals = &steals;
            scope.spawn(move || {
                let home = w % s;
                for k in 0..s {
                    let si = (home + k) % s;
                    while let Some(r) = queues[si].pop() {
                        work(si, r);
                        tiles.fetch_add(1, Ordering::Relaxed);
                        if k > 0 {
                            steals.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    RunStats {
        tiles: tiles.load(Ordering::Relaxed),
        steals: steals.load(Ordering::Relaxed),
        workers,
    }
}

/// Raw-pointer wrapper so disjoint row-blocks of one buffer can be written
/// from several workers. Safe because the scheduler only ever hands out
/// pairwise-disjoint tiles.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Like [`run`], but for tile work that writes rows of a flat row-major
/// buffer (`rows × row_len`): `work(shard, tile_rows, out_rows)` receives
/// the mutable sub-slice for exactly `tile_rows`.
pub fn run_rows_mut<T, F>(
    buf: &mut [T],
    rows: usize,
    row_len: usize,
    queues: &[ShardQueue],
    work: F,
) -> RunStats
where
    T: Send,
    F: Fn(usize, Range<usize>, &mut [T]) + Sync,
{
    assert_eq!(buf.len(), rows * row_len, "buffer/rows mismatch");
    // The unsafe aliasing argument below requires the queues' row ranges to
    // be pairwise disjoint and in-bounds — validate rather than trust, since
    // this function is safe to call with arbitrary queues.
    let mut spans: Vec<Range<usize>> = queues.iter().map(|q| q.rows()).collect();
    spans.sort_by_key(|r| r.start);
    for w in spans.windows(2) {
        assert!(w[0].end <= w[1].start, "queue row ranges overlap: {w:?}");
    }
    if let Some(last) = spans.last() {
        assert!(last.end <= rows, "queue rows exceed buffer rows");
    }
    let base = SendPtr(buf.as_mut_ptr());
    run(queues, move |shard, r| {
        let start = r.start * row_len;
        let len = (r.end - r.start) * row_len;
        // SAFETY: tiles popped from the queues are pairwise-disjoint row
        // ranges within `0..rows`, so these sub-slices never alias, and the
        // scope of `run` ends before `buf`'s borrow does.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        work(shard, r, slice);
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        for &(n, s) in &[(10usize, 3usize), (7, 7), (5, 9), (0, 4), (100, 1), (64, 8)] {
            let parts = partition_rows(n, s);
            assert!(!parts.is_empty());
            assert!(parts.len() <= s.max(1));
            let mut lo = 0;
            for p in &parts {
                assert_eq!(p.start, lo);
                lo = p.end;
            }
            assert_eq!(lo, n);
            let lens: Vec<usize> = parts.iter().map(|p| p.end - p.start).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {lens:?}");
        }
    }

    #[test]
    fn queue_pops_cover_shard_once() {
        let q = ShardQueue::new(10..47, 8);
        let mut seen = vec![0u32; 47];
        while let Some(r) = q.pop() {
            assert!(r.end - r.start <= 8);
            for i in r {
                seen[i] += 1;
            }
        }
        for (i, &c) in seen.iter().enumerate() {
            assert_eq!(c, u32::from(i >= 10), "row {i}");
        }
        assert_eq!(q.n_tiles(), 5);
    }

    #[test]
    fn run_visits_every_row_exactly_once() {
        let n = 503;
        let queues: Vec<ShardQueue> = partition_rows(n, 5)
            .into_iter()
            .map(|r| ShardQueue::new(r, 7))
            .collect();
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let stats = run(&queues, |_shard, rows| {
            for i in rows {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
        let expected_tiles: usize = queues.iter().map(|q| q.n_tiles()).sum();
        assert_eq!(stats.tiles, expected_tiles);
    }

    #[test]
    fn run_rows_mut_writes_disjoint_blocks() {
        let (rows, row_len) = (61, 3);
        let mut buf = vec![0.0f64; rows * row_len];
        let queues: Vec<ShardQueue> = partition_rows(rows, 4)
            .into_iter()
            .map(|r| ShardQueue::new(r, 5))
            .collect();
        run_rows_mut(&mut buf, rows, row_len, &queues, |shard, tile, out| {
            for (ri, row) in out.chunks_mut(row_len).enumerate() {
                let i = tile.start + ri;
                for (c, v) in row.iter_mut().enumerate() {
                    *v = (shard * 1_000_000 + i * 10 + c) as f64;
                }
            }
        });
        let parts = partition_rows(rows, 4);
        for i in 0..rows {
            let shard = parts.iter().position(|p| p.contains(&i)).unwrap();
            for c in 0..row_len {
                assert_eq!(buf[i * row_len + c], (shard * 1_000_000 + i * 10 + c) as f64);
            }
        }
    }

    #[test]
    fn skewed_shards_get_stolen_from() {
        if par::num_threads() < 2 {
            return; // stealing needs at least two workers
        }
        // shard 0 owns everything; other workers' home shards are empty, so
        // any tile they execute is a steal. Retried because a very fast
        // first worker could in principle drain the queue before the
        // second worker is scheduled.
        let n = 100_000;
        for attempt in 0..5 {
            let queues = vec![ShardQueue::new(0..n, 1), ShardQueue::new(n..n, 1)];
            let stats = run(&queues, |_s, rows| {
                let mut acc = 0u64;
                for i in rows {
                    acc = acc.wrapping_add(i as u64).wrapping_mul(31);
                }
                std::hint::black_box(acc);
            });
            assert_eq!(stats.tiles, n);
            assert!(stats.workers >= 2);
            if stats.steals > 0 {
                return;
            }
            eprintln!("attempt {attempt}: no steals observed, retrying");
        }
        panic!("no steals across 5 attempts on a fully skewed shard plan");
    }

    #[test]
    fn empty_queues_are_a_noop() {
        let stats = run(&[], |_, _| panic!("no work expected"));
        assert_eq!(stats.tiles, 0);
        let queues = vec![ShardQueue::new(3..3, 4)];
        let stats = run(&queues, |_, _| panic!("no work expected"));
        assert_eq!(stats.tiles, 0);
    }
}
