//! Length-prefixed binary wire protocol between the driver and `bbmm
//! shard-worker` processes.
//!
//! Every message is one frame: `[tag: u8][payload_len: u64 LE][payload]`.
//! Payloads are flat little-endian scalars — no self-describing container,
//! because both ends are the same binary and the vocabulary is tiny. The
//! driver broadcasts one [`WireMsg::Matmul`] per mBCG iteration (the skinny
//! RHS, `n × t`) and gathers one [`WireMsg::MatmulResult`] per worker (that
//! worker's owned row-blocks), so traffic is O(n·t) per iteration — never
//! per tile.

use crate::kernels::ShardBlock;
use crate::tensor::Mat;
use std::io::{self, Read, Write};

/// Protocol version — bumped on any wire-format change; [`WireMsg::Hello`]
/// carries it and the driver refuses mismatched workers. v2 added the
/// shared-memory attach handshake (ShmAttach/ShmReady) and ParamsAck.
pub const PROTOCOL_VERSION: u32 = 2;

/// Refuse frames claiming more than this many payload bytes (corruption
/// guard; a 10⁶-row broadcast at t = 64 is ~0.5 GiB, well under the cap).
const MAX_FRAME: u64 = 1 << 34;

/// One row-block of a gathered partial product.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultBlock {
    /// global shard id (indexes the driver's partition)
    pub shard: u64,
    /// the shard's rows of the product, `shard_len × t`
    pub data: Mat,
}

/// Every message either side can send. See module docs for framing.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// worker → driver greeting, sent once after connecting
    Hello {
        /// must equal [`PROTOCOL_VERSION`]
        version: u32,
        /// worker process id (diagnostics)
        pid: u32,
    },
    /// driver → worker: full problem state (sent at spawn and respawn)
    LoadShard {
        /// training inputs, `n × d` (every worker holds X; only K is sharded)
        x: Mat,
        /// kernel family name (see `worker::kernel_by_name`)
        kernel: String,
        /// raw kernel parameters
        raw: Vec<f64>,
        /// noise σ² (used only when a product asks for a fused diagonal)
        sigma2: f64,
        /// total shard count of the driver's partition
        n_shards: u64,
        /// shard ids this worker owns
        owned: Vec<u64>,
        /// per-worker MmmPlan budget (MiB) for panel materialisation
        budget_mb: u64,
    },
    /// driver → worker: hyperparameter update (panels for old params drop)
    SetParams {
        /// raw kernel parameters
        raw: Vec<f64>,
        /// new σ², if the noise changed too
        sigma2: Option<f64>,
    },
    /// driver → worker: compute owned row-blocks of one kernel product
    Matmul {
        /// which kernel function (value / fused-noise value / ∂ param)
        block: ShardBlock,
        /// the broadcast RHS, `n × t`
        m: Mat,
    },
    /// worker → driver: the owned row-blocks for the last [`WireMsg::Matmul`]
    MatmulResult {
        /// one block per owned shard, in owned order
        blocks: Vec<ResultBlock>,
    },
    /// driver → worker heartbeat probe
    Ping,
    /// worker → driver heartbeat reply
    Pong,
    /// driver → worker: exit cleanly
    Shutdown,
    /// either direction: fatal condition description
    Err {
        /// human-readable cause
        message: String,
    },
    /// driver → worker: map the shared-memory segment at `path` and serve
    /// rounds from it (doorbell slot `slot`); sent once after LoadShard
    ShmAttach {
        /// segment file path (same host by construction)
        path: String,
        /// probe capacity the segment was sized for
        t_max: u64,
        /// this worker's doorbell slot index
        slot: u64,
    },
    /// worker → driver: outcome of [`WireMsg::ShmAttach`] — a failed map
    /// keeps that worker on the TCP data plane
    ShmReady {
        /// whether the segment mapped and validated
        ok: bool,
        /// failure cause when `ok` is false (diagnostics)
        detail: String,
    },
    /// worker → driver: SetParams applied. Needed because the shm data
    /// plane bypasses the socket: without an ack, a posted round could
    /// race a SetParams still in the socket buffer.
    ParamsAck,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(buf: &mut Vec<u8>, vs: &[f64]) {
    put_u64(buf, vs.len() as u64);
    buf.reserve(vs.len() * 8);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_mat(buf: &mut Vec<u8>, m: &Mat) {
    put_u64(buf, m.rows() as u64);
    put_u64(buf, m.cols() as u64);
    buf.reserve(m.data().len() * 8);
    for v in m.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_block(buf: &mut Vec<u8>, b: &ShardBlock) {
    match b {
        ShardBlock::Value { noise: None } => {
            buf.push(0);
            put_f64(buf, 0.0);
        }
        ShardBlock::Value { noise: Some(s2) } => {
            buf.push(1);
            put_f64(buf, *s2);
        }
        ShardBlock::DParam(p) => {
            buf.push(2);
            put_f64(buf, 0.0);
            put_u64(buf, *p as u64);
        }
    }
}

/// Byte-slice cursor for payload parsing; truncation reads as `InvalidData`.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("wire: {msg}"))
}

impl<'a> Cur<'a> {
    fn take(&mut self, len: usize) -> io::Result<&'a [u8]> {
        if self.pos + len > self.buf.len() {
            return Err(bad("truncated payload"));
        }
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> io::Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| bad("length overflows usize"))
    }

    fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64s(&mut self) -> io::Result<Vec<f64>> {
        let len = self.usize()?;
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn str(&mut self) -> io::Result<String> {
        let len = self.usize()?;
        String::from_utf8(self.take(len)?.to_vec()).map_err(|_| bad("non-utf8 string"))
    }

    fn mat(&mut self) -> io::Result<Mat> {
        let rows = self.usize()?;
        let cols = self.usize()?;
        let raw = self.take(rows * cols * 8)?;
        let data: Vec<f64> = raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Mat::from_vec(rows, cols, data))
    }

    fn block(&mut self) -> io::Result<ShardBlock> {
        let code = self.u8()?;
        let noise = self.f64()?;
        Ok(match code {
            0 => ShardBlock::Value { noise: None },
            1 => ShardBlock::Value { noise: Some(noise) },
            2 => ShardBlock::DParam(self.usize()?),
            _ => return Err(bad("unknown ShardBlock code")),
        })
    }

    fn done(&self) -> io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad("trailing payload bytes"));
        }
        Ok(())
    }
}

impl WireMsg {
    fn tag(&self) -> u8 {
        match self {
            WireMsg::Hello { .. } => 1,
            WireMsg::LoadShard { .. } => 2,
            WireMsg::SetParams { .. } => 3,
            WireMsg::Matmul { .. } => 4,
            WireMsg::MatmulResult { .. } => 5,
            WireMsg::Ping => 6,
            WireMsg::Pong => 7,
            WireMsg::Shutdown => 8,
            WireMsg::Err { .. } => 9,
            WireMsg::ShmAttach { .. } => 10,
            WireMsg::ShmReady { .. } => 11,
            WireMsg::ParamsAck => 12,
        }
    }

    /// Serialise to one frame (`tag`, length, payload) on `w`. One
    /// `write_all` per frame so a concurrent reader never sees a torn
    /// header.
    pub fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        let mut payload = Vec::new();
        match self {
            WireMsg::Hello { version, pid } => {
                put_u32(&mut payload, *version);
                put_u32(&mut payload, *pid);
            }
            WireMsg::LoadShard {
                x,
                kernel,
                raw,
                sigma2,
                n_shards,
                owned,
                budget_mb,
            } => {
                put_mat(&mut payload, x);
                put_str(&mut payload, kernel);
                put_f64s(&mut payload, raw);
                put_f64(&mut payload, *sigma2);
                put_u64(&mut payload, *n_shards);
                put_u64(&mut payload, owned.len() as u64);
                for s in owned {
                    put_u64(&mut payload, *s);
                }
                put_u64(&mut payload, *budget_mb);
            }
            WireMsg::SetParams { raw, sigma2 } => {
                put_f64s(&mut payload, raw);
                match sigma2 {
                    Some(s2) => {
                        payload.push(1);
                        put_f64(&mut payload, *s2);
                    }
                    None => payload.push(0),
                }
            }
            WireMsg::Matmul { block, m } => {
                put_block(&mut payload, block);
                put_mat(&mut payload, m);
            }
            WireMsg::MatmulResult { blocks } => {
                put_u64(&mut payload, blocks.len() as u64);
                for b in blocks {
                    put_u64(&mut payload, b.shard);
                    put_mat(&mut payload, &b.data);
                }
            }
            WireMsg::Ping | WireMsg::Pong | WireMsg::Shutdown | WireMsg::ParamsAck => {}
            WireMsg::Err { message } => put_str(&mut payload, message),
            WireMsg::ShmAttach { path, t_max, slot } => {
                put_str(&mut payload, path);
                put_u64(&mut payload, *t_max);
                put_u64(&mut payload, *slot);
            }
            WireMsg::ShmReady { ok, detail } => {
                payload.push(u8::from(*ok));
                put_str(&mut payload, detail);
            }
        }
        let mut frame = Vec::with_capacity(9 + payload.len());
        frame.push(self.tag());
        frame.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        frame.extend_from_slice(&payload);
        w.write_all(&frame)
    }

    /// Read and parse one frame from `r` (blocking until a full frame or an
    /// I/O error — a closed peer surfaces as `UnexpectedEof`).
    pub fn decode(r: &mut impl Read) -> io::Result<WireMsg> {
        let mut header = [0u8; 9];
        r.read_exact(&mut header)?;
        let tag = header[0];
        let len = u64::from_le_bytes(header[1..9].try_into().unwrap());
        if len > MAX_FRAME {
            return Err(bad("oversized frame"));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        let mut c = Cur {
            buf: &payload,
            pos: 0,
        };
        let msg = match tag {
            1 => WireMsg::Hello {
                version: c.u32()?,
                pid: c.u32()?,
            },
            2 => {
                let x = c.mat()?;
                let kernel = c.str()?;
                let raw = c.f64s()?;
                let sigma2 = c.f64()?;
                let n_shards = c.u64()?;
                let n_owned = c.usize()?;
                let mut owned = Vec::with_capacity(n_owned);
                for _ in 0..n_owned {
                    owned.push(c.u64()?);
                }
                let budget_mb = c.u64()?;
                WireMsg::LoadShard {
                    x,
                    kernel,
                    raw,
                    sigma2,
                    n_shards,
                    owned,
                    budget_mb,
                }
            }
            3 => {
                let raw = c.f64s()?;
                let sigma2 = match c.u8()? {
                    0 => None,
                    1 => Some(c.f64()?),
                    _ => return Err(bad("bad Option tag")),
                };
                WireMsg::SetParams { raw, sigma2 }
            }
            4 => WireMsg::Matmul {
                block: c.block()?,
                m: c.mat()?,
            },
            5 => {
                let nb = c.usize()?;
                let mut blocks = Vec::with_capacity(nb);
                for _ in 0..nb {
                    let shard = c.u64()?;
                    let data = c.mat()?;
                    blocks.push(ResultBlock { shard, data });
                }
                WireMsg::MatmulResult { blocks }
            }
            6 => WireMsg::Ping,
            7 => WireMsg::Pong,
            8 => WireMsg::Shutdown,
            9 => WireMsg::Err { message: c.str()? },
            10 => WireMsg::ShmAttach {
                path: c.str()?,
                t_max: c.u64()?,
                slot: c.u64()?,
            },
            11 => WireMsg::ShmReady {
                ok: match c.u8()? {
                    0 => false,
                    1 => true,
                    _ => return Err(bad("bad bool tag")),
                },
                detail: c.str()?,
            },
            12 => WireMsg::ParamsAck,
            _ => return Err(bad("unknown message tag")),
        };
        c.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(msg: WireMsg) {
        let mut buf = Vec::new();
        msg.encode(&mut buf).unwrap();
        let got = WireMsg::decode(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(got, msg);
        // framing is exact: nothing left in the stream
        let mut c = Cursor::new(&buf);
        WireMsg::decode(&mut c).unwrap();
        assert_eq!(c.position() as usize, buf.len());
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(WireMsg::Hello {
            version: PROTOCOL_VERSION,
            pid: 4242,
        });
        roundtrip(WireMsg::LoadShard {
            x: Mat::from_vec(2, 3, vec![1.0, -2.5, 0.0, 3.25, 4.0, -0.125]),
            kernel: "rbf".into(),
            raw: vec![-0.7, 0.2],
            sigma2: 0.01,
            n_shards: 8,
            owned: vec![1, 5],
            budget_mb: 256,
        });
        roundtrip(WireMsg::SetParams {
            raw: vec![0.1],
            sigma2: None,
        });
        roundtrip(WireMsg::SetParams {
            raw: vec![],
            sigma2: Some(0.5),
        });
        roundtrip(WireMsg::Matmul {
            block: ShardBlock::Value { noise: Some(0.25) },
            m: Mat::from_vec(3, 1, vec![1.0, 2.0, 3.0]),
        });
        roundtrip(WireMsg::Matmul {
            block: ShardBlock::DParam(1),
            m: Mat::zeros(1, 1),
        });
        roundtrip(WireMsg::MatmulResult {
            blocks: vec![
                ResultBlock {
                    shard: 0,
                    data: Mat::from_vec(1, 2, vec![9.0, -9.0]),
                },
                ResultBlock {
                    shard: 3,
                    data: Mat::zeros(2, 2),
                },
            ],
        });
        roundtrip(WireMsg::Ping);
        roundtrip(WireMsg::Pong);
        roundtrip(WireMsg::Shutdown);
        roundtrip(WireMsg::Err {
            message: "worker died".into(),
        });
        roundtrip(WireMsg::ShmAttach {
            path: "/dev/shm/bbmm-seg-1-0.shm".into(),
            t_max: 64,
            slot: 3,
        });
        roundtrip(WireMsg::ShmReady {
            ok: true,
            detail: String::new(),
        });
        roundtrip(WireMsg::ShmReady {
            ok: false,
            detail: "mmap failed".into(),
        });
        roundtrip(WireMsg::ParamsAck);
    }

    #[test]
    fn consecutive_frames_stream() {
        let mut buf = Vec::new();
        WireMsg::Ping.encode(&mut buf).unwrap();
        WireMsg::Pong.encode(&mut buf).unwrap();
        WireMsg::Shutdown.encode(&mut buf).unwrap();
        let mut c = Cursor::new(&buf);
        assert_eq!(WireMsg::decode(&mut c).unwrap(), WireMsg::Ping);
        assert_eq!(WireMsg::decode(&mut c).unwrap(), WireMsg::Pong);
        assert_eq!(WireMsg::decode(&mut c).unwrap(), WireMsg::Shutdown);
    }

    #[test]
    fn corrupt_frames_error_cleanly() {
        // truncated header
        assert!(WireMsg::decode(&mut Cursor::new(&[1u8, 2, 3])).is_err());
        // unknown tag
        let mut buf = vec![99u8];
        buf.extend_from_slice(&0u64.to_le_bytes());
        assert!(WireMsg::decode(&mut Cursor::new(&buf)).is_err());
        // oversized frame claim
        let mut buf = vec![6u8];
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(WireMsg::decode(&mut Cursor::new(&buf)).is_err());
        // trailing garbage inside the payload
        let mut buf = vec![6u8];
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.push(0);
        assert!(WireMsg::decode(&mut Cursor::new(&buf)).is_err());
    }
}
