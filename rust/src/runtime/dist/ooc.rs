//! Out-of-core shard backend: checkpointed kernel panels.
//!
//! Wang et al. 2019 train million-point GPs by never holding K resident:
//! each partition's rows are (re)materialised under a per-worker memory
//! budget. [`OutOfCoreBackend`] is the single-host version of that memory
//! model — every shard's noise-free kernel rows are materialised **once**
//! per hyperparameter setting and checkpointed to a disk spool, then each
//! product streams the panels back through a bounded window
//! ([`OutOfCoreBackend::window_rows`]) and contracts them against the
//! broadcast RHS. Resident kernel memory is O(window · n) regardless of
//! how many shards exist, while repeated products still amortise the
//! kernel evaluation exactly like [`crate::linalg::op::MmmPlan`]'s
//! `MaterializeK` — the plan decision is per shard, against the spool
//! window, via [`crate::linalg::op::MmmPlan::auto_sharded`].
//!
//! Numerics: panels are written by `ShardedCovOp::shard_panel` and
//! contracted by `contract_panel_rows`, both of which mirror the streaming
//! fill exactly, so out-of-core products are bit-identical to in-process
//! ones (asserted in the tests).

use super::{contract_panel_rows, BackendStats, ShardBackend};
use crate::kernels::{ShardBlock, ShardedKernelOp};
use crate::linalg::op::MmmPlan;
use crate::tensor::Mat;
use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

static SPOOL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Checkpointed-panel backend: shard kernel rows live on disk, products
/// stream them through a bounded in-memory window.
pub struct OutOfCoreBackend {
    /// the generator for panels and the fallback for streamed derivatives;
    /// plan forced to `Stream` so it never materialises n×n state itself
    op: RwLock<ShardedKernelOp>,
    /// spool directory holding one `panel_<s>.f64` per shard
    dir: PathBuf,
    /// panel-window budget in bytes (max resident spool bytes per product)
    budget_bytes: usize,
    stats: Mutex<BackendStats>,
}

impl OutOfCoreBackend {
    /// Materialise every shard panel of `op` into a fresh spool directory
    /// under the system temp dir. `budget_bytes` bounds the read-back
    /// window per product (not the spool size — that is the whole point).
    pub fn new(mut op: ShardedKernelOp, budget_bytes: usize) -> io::Result<OutOfCoreBackend> {
        assert!(
            op.backend().is_none(),
            "OutOfCoreBackend must wrap a backend-less operator"
        );
        op.set_plan(MmmPlan::Stream);
        let dir = std::env::temp_dir().join(format!(
            "bbmm-ooc-{}-{}",
            std::process::id(),
            SPOOL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&dir)?;
        let backend = OutOfCoreBackend {
            op: RwLock::new(op),
            dir,
            budget_bytes: budget_bytes.max(1),
            stats: Mutex::new(BackendStats::default()),
        };
        backend.checkpoint_panels()?;
        Ok(backend)
    }

    /// Rows of panel data the streaming window holds at once.
    pub fn window_rows(&self) -> usize {
        let n = self.n();
        (self.budget_bytes / (n.max(1) * 8)).max(1)
    }

    /// The spool directory (tests probe it; removed by `shutdown`/drop).
    pub fn spool_dir(&self) -> &PathBuf {
        &self.dir
    }

    fn panel_path(&self, s: usize) -> PathBuf {
        self.dir.join(format!("panel_{s}.f64"))
    }

    /// (Re)write every shard's noise-free kernel rows to the spool.
    fn checkpoint_panels(&self) -> io::Result<()> {
        let op = self.op.read().unwrap();
        let mut written = 0u64;
        for s in 0..op.shard_count() {
            let panel = op.cov().shard_panel(s);
            let mut f = File::create(self.panel_path(s))?;
            let mut bytes = Vec::with_capacity(panel.data().len() * 8);
            for v in panel.data() {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&bytes)?;
            written += bytes.len() as u64;
        }
        self.stats.lock().unwrap().bytes_tx += written;
        Ok(())
    }

    /// Stream one shard's spooled panel through the window, contracting
    /// each chunk of rows against `m` into the matching rows of `out`.
    fn stream_shard(
        &self,
        s: usize,
        rows: Range<usize>,
        noise: Option<f64>,
        m: &Mat,
        out: &mut Mat,
    ) -> io::Result<u64> {
        let n = m.rows();
        let t = m.cols();
        let window = self.window_rows();
        let mut f = File::open(self.panel_path(s))?;
        let mut raw = Vec::new();
        let mut panel = Vec::new();
        let mut read = 0u64;
        let mut row = rows.start;
        while row < rows.end {
            let chunk = window.min(rows.end - row);
            raw.resize(chunk * n * 8, 0);
            f.read_exact(&mut raw)?;
            read += raw.len() as u64;
            panel.clear();
            panel.extend(
                raw.chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
            );
            let out_rows = &mut out.data_mut()[row * t..(row + chunk) * t];
            contract_panel_rows(&panel, n, m, noise, row, out_rows);
            row += chunk;
        }
        Ok(read)
    }
}

impl ShardBackend for OutOfCoreBackend {
    fn describe(&self) -> String {
        format!(
            "ooc:{} (spool {}, window {} rows)",
            self.n_shards(),
            self.dir.display(),
            self.window_rows()
        )
    }

    fn n(&self) -> usize {
        self.op.read().unwrap().x().rows()
    }

    fn n_shards(&self) -> usize {
        self.op.read().unwrap().shard_count()
    }

    fn shard_rows(&self, s: usize) -> Range<usize> {
        self.op.read().unwrap().shards()[s].clone()
    }

    fn matmul_block(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        let n = m.rows();
        let t = m.cols();
        assert_eq!(out.shape(), (n, t));
        let op = self.op.read().unwrap();
        assert_eq!(n, op.x().rows());
        let stationary = op.kernel().stationary().is_some();
        // which requests the K spool can serve: value products always, and
        // ∂/∂log-outputscale (= the value tile) for stationary kernels
        let spooled: Option<Option<f64>> = match block {
            ShardBlock::Value { noise } => Some(*noise),
            ShardBlock::DParam(1) if stationary => Some(None),
            ShardBlock::DParam(_) => None,
        };
        let mut read = 0u64;
        for s in 0..op.shard_count() {
            let rows = op.shards()[s].clone();
            match spooled {
                Some(noise) => {
                    read += self
                        .stream_shard(s, rows, noise, m, out)
                        .unwrap_or_else(|e| panic!("ooc spool read failed: {e}"));
                }
                None => {
                    // parameter derivatives that aren't the value tile are
                    // streamed from X (plan is Stream, so O(row) memory)
                    let out_rows = &mut out.data_mut()[rows.start * t..rows.end * t];
                    op.cov().fill_shard(s, m, block, out_rows);
                }
            }
        }
        let mut st = self.stats.lock().unwrap();
        st.rounds += 1;
        st.bytes_rx += read;
    }

    fn set_params(&self, raw: &[f64], sigma2: Option<f64>) {
        {
            let mut op = self.op.write().unwrap();
            let nk = op.kernel().n_params();
            assert_eq!(raw.len(), nk);
            let mut full = raw.to_vec();
            let cur = op.params();
            full.push(match sigma2 {
                Some(s2) => s2.ln(),
                None => cur[nk],
            });
            op.set_params(&full);
        }
        // panels hold values for the old parameters — rebuild the spool
        self.checkpoint_panels()
            .unwrap_or_else(|e| panic!("ooc spool rebuild failed: {e}"));
    }

    fn stats(&self) -> BackendStats {
        *self.stats.lock().unwrap()
    }

    fn shutdown(&self) {
        let _ = fs::remove_dir_all(&self.dir);
    }
}

impl Drop for OutOfCoreBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::linalg::op::LinearOp;
    use crate::util::Rng;

    fn setup(n: usize, shards: usize, budget: usize) -> (OutOfCoreBackend, DenseKernelOp, Mat) {
        let mut rng = Rng::new(41);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(n, 4, |_, _| rng.normal());
        let op = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)), 0.1, shards);
        let dense = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.2)), 0.1);
        (OutOfCoreBackend::new(op, budget).unwrap(), dense, m)
    }

    #[test]
    fn spooled_products_match_dense_bit_for_tiny_windows() {
        // budget of one row: the window is as small as it gets
        let (backend, dense, m) = setup(50, 3, 1);
        assert_eq!(backend.window_rows(), 1);
        assert!(backend.spool_dir().join("panel_0.f64").exists());
        let mut got = Mat::zeros(50, 4);
        backend.matmul_block(&ShardBlock::Value { noise: Some(0.1) }, &m, &mut got);
        assert!(got.max_abs_diff(&dense.matmul(&m)) < 1e-12);
        // noise-free + derivatives
        let mut noisefree = Mat::zeros(50, 4);
        backend.matmul_block(&ShardBlock::Value { noise: None }, &m, &mut noisefree);
        let mut d0 = Mat::zeros(50, 4);
        backend.matmul_block(&ShardBlock::DParam(0), &m, &mut d0);
        let mut d1 = Mat::zeros(50, 4);
        backend.matmul_block(&ShardBlock::DParam(1), &m, &mut d1);
        assert!(d0.max_abs_diff(&dense.dmatmul(0, &m)) < 1e-12);
        assert!(d1.max_abs_diff(&dense.dmatmul(1, &m)) < 1e-12);
        let st = backend.stats();
        assert_eq!(st.rounds, 4);
        assert!(st.bytes_tx > 0 && st.bytes_rx > 0);
    }

    #[test]
    fn set_params_rebuilds_the_spool() {
        let (backend, _dense, m) = setup(40, 2, 1 << 20);
        let raw = vec![-0.3, 0.25];
        backend.set_params(&raw, Some(0.05));
        let mut fresh = DenseKernelOp::new(
            {
                let mut rng = Rng::new(41);
                Mat::from_fn(40, 2, |_, _| rng.uniform_in(-1.0, 1.0))
            },
            Box::new(Rbf::new(0.5, 1.2)),
            0.1,
        );
        fresh.set_params(&[raw[0], raw[1], 0.05f64.ln()]);
        let mut got = Mat::zeros(40, 4);
        backend.matmul_block(&ShardBlock::Value { noise: Some(0.05) }, &m, &mut got);
        assert!(got.max_abs_diff(&fresh.matmul(&m)) < 1e-12);
    }

    #[test]
    fn shutdown_removes_the_spool() {
        let (backend, _dense, _m) = setup(20, 2, 1 << 20);
        let dir = backend.spool_dir().clone();
        assert!(dir.exists());
        backend.shutdown();
        assert!(!dir.exists());
        // idempotent
        backend.shutdown();
    }
}
