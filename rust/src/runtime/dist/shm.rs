//! Zero-copy shared-memory data plane + NUMA placement helpers for the
//! multi-process shard backend.
//!
//! [`super::proc::MultiProcessBackend`] serializes the O(n·t) broadcast
//! probe and every per-shard result panel through a TCP socket **per mBCG
//! iteration** — even when driver and workers share one host. This module
//! removes that copy chain: the driver creates one memory-mapped segment
//! file (under `/dev/shm` when available, so pages live in tmpfs and
//! never touch disk), every forked worker maps the same file, and a
//! product round becomes
//!
//! 1. driver writes the probe block and a round descriptor into the
//!    segment, then bumps a **sequence word** (Release store);
//! 2. each worker observes the new sequence (Acquire load), reads the
//!    probe, contracts its owned shards, writes the result rows at their
//!    global offsets, and rings its **doorbell** (stores the sequence it
//!    served, Release);
//! 3. the driver waits on the doorbells and copies each worker's rows
//!    straight out of the segment.
//!
//! Zero bytes of payload cross a socket and nothing is serialized — the
//! f64 panels are memcpy'd in and out of shared pages. TCP remains the
//! control plane (LoadShard, SetParams, heartbeats) and the fallback when
//! mapping fails, so remote workers keep working unchanged.
//!
//! The mapping uses a raw `mmap` FFI shim declared here (the workspace
//! bakes in a zero-external-dependency rule, so no `libc`/`memmap`
//! crates); non-unix or non-64-bit targets get an `Unsupported` error and
//! the backend silently stays on TCP.
//!
//! NUMA helpers live here too: [`numa_nodes`] parses
//! `/sys/devices/system/node/`, [`pin_to_cpus`] wraps
//! `sched_setaffinity`, and the backend round-robins worker slots across
//! nodes so each worker first-touches its panels on its own node.

use crate::kernels::ShardBlock;
use crate::tensor::Mat;
use std::fs::OpenOptions;
use std::io;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Segment file magic ("BBMM" + "SHM1", little-endian u64).
const MAGIC: u64 = 0x314d_4853_4d4d_4242;

/// Bumped on any segment layout change; `open` refuses mismatches (a
/// respawned worker from a newer binary must never misread the map).
const SHM_LAYOUT_VERSION: u64 = 1;

// -- fixed header offsets (all 8-byte aligned u64 cells) ----------------
const OFF_MAGIC: usize = 0;
const OFF_VERSION: usize = 8;
const OFF_N: usize = 16;
const OFF_TMAX: usize = 24;
const OFF_SLOTS: usize = 32;
const OFF_SHUTDOWN: usize = 40;
/// round sequence word, alone on its cache line
const OFF_SEQ: usize = 64;
/// round descriptor: which kernel function the probe should hit
const OFF_BLOCK_CODE: usize = 128;
const OFF_BLOCK_NOISE: usize = 136;
const OFF_BLOCK_PARAM: usize = 144;
const OFF_T: usize = 152;
/// per-worker doorbells, one cache line each
const OFF_ACKS: usize = 192;
const ACK_STRIDE: usize = 64;
/// header page; probe region starts here (page-aligned)
const HEADER_BYTES: usize = 4096;

/// Doorbell slots that fit in the fixed header page.
pub const MAX_SLOTS: usize = (HEADER_BYTES - OFF_ACKS) / ACK_STRIDE;

/// Total file size for an `n × t_max` probe + result pair.
fn segment_len(n: usize, t_max: usize) -> usize {
    HEADER_BYTES + 2 * n * t_max * 8
}

/// Segment tuning knobs (the `Transport::Shm` payload).
#[derive(Debug, Clone, Default)]
pub struct ShmOptions {
    /// directory override for the segment file. `None` tries `/dev/shm`
    /// (tmpfs — shared pages, no disk) and then the system temp dir; a
    /// `Some` dir is tried alone, which doubles as the mapping-failure
    /// seam the fallback tests use.
    pub dir: Option<PathBuf>,
    /// probe capacity in columns; rounds wider than this fall back to TCP
    /// per round. 0 means the default (`BBMM_SHM_TMAX`, else 64 — wide
    /// enough for every mBCG probe block in the tree).
    pub t_max: usize,
}

impl ShmOptions {
    /// The effective probe capacity (resolving 0 through the environment).
    pub fn resolved_t_max(&self) -> usize {
        if self.t_max > 0 {
            return self.t_max;
        }
        std::env::var("BBMM_SHM_TMAX")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&v| v > 0)
            .unwrap_or(64)
    }
}

// -- raw mmap shim (no external crates) ---------------------------------

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    // The two calls the data plane needs, declared directly against libc's
    // C ABI. The flag values are identical on Linux and macOS.
    pub const PROT_READ: i32 = 0x1;
    pub const PROT_WRITE: i32 = 0x2;
    pub const MAP_SHARED: i32 = 0x01;
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
fn map_file(file: &std::fs::File, len: usize) -> io::Result<*mut u8> {
    use std::os::unix::io::AsRawFd;
    let ptr = unsafe {
        sys::mmap(
            std::ptr::null_mut(),
            len,
            sys::PROT_READ | sys::PROT_WRITE,
            sys::MAP_SHARED,
            file.as_raw_fd(),
            0,
        )
    };
    if ptr.is_null() || ptr as isize == -1 {
        return Err(io::Error::new(io::ErrorKind::Other, "mmap failed"));
    }
    Ok(ptr)
}

#[cfg(not(all(unix, target_pointer_width = "64")))]
fn map_file(_file: &std::fs::File, _len: usize) -> io::Result<*mut u8> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "shared-memory transport needs a 64-bit unix target",
    ))
}

fn unmap(ptr: *mut u8, len: usize) {
    #[cfg(all(unix, target_pointer_width = "64"))]
    unsafe {
        let _ = sys::munmap(ptr, len);
    }
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    let _ = (ptr, len);
}

static SEG_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Whether a process id is still alive (Linux: `/proc/<pid>` exists).
/// Non-Linux targets have no cheap portable probe, so everything counts
/// as alive there and the sweep below never removes anything.
#[cfg(target_os = "linux")]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
fn pid_alive(_pid: u32) -> bool {
    true
}

/// Best-effort sweep of segment files leaked by dead drivers. The owner
/// normally unlinks its file on Drop, but a SIGKILL'd (or abort-panicked)
/// driver never runs Drop, and a leaked segment under `/dev/shm` pins
/// tmpfs RAM — ~1 GiB at n = 1e6, t_max = 64 — until someone removes it.
/// Segment names carry the creator pid (`bbmm-seg-<pid>-<k>.shm`), so any
/// such file whose process is gone is removed here; errors are ignored
/// (the sweep is hygiene, not correctness).
fn sweep_stale_segments(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let Some(rest) = name.strip_prefix("bbmm-seg-") else {
            continue;
        };
        if !rest.ends_with(".shm") {
            continue;
        }
        let Some(pid) = rest.split('-').next().and_then(|p| p.parse::<u32>().ok()) else {
            continue;
        };
        if pid != std::process::id() && !pid_alive(pid) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// One mapped segment handle. The driver `create`s (and owns — the file
/// is unlinked on drop, and files leaked by drivers that died without
/// running Drop are swept at the next `create`); each worker `open`s the
/// same path. All header
/// words are accessed through `AtomicU64` views of the mapped page, so
/// the seqlock/doorbell protocol has real Acquire/Release edges across
/// the processes sharing the map.
pub struct ShmSegment {
    ptr: *mut u8,
    len: usize,
    path: PathBuf,
    n: usize,
    t_max: usize,
    n_slots: usize,
    owner: bool,
}

// The raw pointer aliases a shared file mapping; all mutation goes
// through atomics or region copies governed by the seq/doorbell protocol.
unsafe impl Send for ShmSegment {}
unsafe impl Sync for ShmSegment {}

impl ShmSegment {
    /// Create, size, and stamp a fresh segment file for an `n`-row
    /// problem with `n_slots` worker doorbells. Tries `/dev/shm` first
    /// (unless `opts.dir` overrides), then the temp dir; any failure is
    /// the caller's cue to stay on TCP.
    pub fn create(n: usize, t_max: usize, n_slots: usize, opts: &ShmOptions) -> io::Result<ShmSegment> {
        if n == 0 || t_max == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "shm segment needs n ≥ 1 and t_max ≥ 1",
            ));
        }
        if n_slots == 0 || n_slots > MAX_SLOTS {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("shm doorbell slots must be in 1..={MAX_SLOTS}, got {n_slots}"),
            ));
        }
        let len = segment_len(n, t_max);
        let dirs: Vec<PathBuf> = match &opts.dir {
            Some(d) => vec![d.clone()],
            None => {
                let mut v = Vec::new();
                let dev = PathBuf::from("/dev/shm");
                if dev.is_dir() {
                    v.push(dev);
                }
                v.push(std::env::temp_dir());
                v
            }
        };
        let mut last_err = io::Error::new(io::ErrorKind::NotFound, "no shm directory candidate");
        for dir in dirs {
            sweep_stale_segments(&dir);
            let name = format!(
                "bbmm-seg-{}-{}.shm",
                std::process::id(),
                SEG_COUNTER.fetch_add(1, Ordering::Relaxed)
            );
            let path = dir.join(name);
            let mapped = (|| -> io::Result<*mut u8> {
                let file = OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create_new(true)
                    .open(&path)?;
                file.set_len(len as u64)?;
                map_file(&file, len)
            })();
            match mapped {
                Ok(ptr) => {
                    let seg = ShmSegment {
                        ptr,
                        len,
                        path,
                        n,
                        t_max,
                        n_slots,
                        owner: true,
                    };
                    seg.atom(OFF_MAGIC).store(MAGIC, Ordering::Relaxed);
                    seg.atom(OFF_N).store(n as u64, Ordering::Relaxed);
                    seg.atom(OFF_TMAX).store(t_max as u64, Ordering::Relaxed);
                    seg.atom(OFF_SLOTS).store(n_slots as u64, Ordering::Relaxed);
                    seg.atom(OFF_SHUTDOWN).store(0, Ordering::Relaxed);
                    seg.atom(OFF_SEQ).store(0, Ordering::Relaxed);
                    for slot in 0..n_slots {
                        seg.atom(OFF_ACKS + slot * ACK_STRIDE).store(0, Ordering::Relaxed);
                    }
                    // publish last: an `open` racing this create sees the
                    // version only after the geometry words are in place
                    seg.atom(OFF_VERSION)
                        .store(SHM_LAYOUT_VERSION, Ordering::Release);
                    return Ok(seg);
                }
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Map an existing segment file (the worker side of ShmAttach),
    /// validating magic, layout version, and geometry against the file
    /// length before trusting any offset.
    pub fn open(path: &Path) -> io::Result<ShmSegment> {
        use std::io::Read;
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut head = [0u8; 48];
        file.read_exact(&mut head)?;
        let word = |off: usize| u64::from_le_bytes(head[off..off + 8].try_into().unwrap());
        let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, format!("shm open: {msg}"));
        if word(OFF_MAGIC) != MAGIC {
            return Err(bad("bad magic"));
        }
        if word(OFF_VERSION) != SHM_LAYOUT_VERSION {
            return Err(bad("layout version mismatch"));
        }
        let n = word(OFF_N) as usize;
        let t_max = word(OFF_TMAX) as usize;
        let n_slots = word(OFF_SLOTS) as usize;
        if n == 0 || t_max == 0 || n_slots == 0 || n_slots > MAX_SLOTS {
            return Err(bad("corrupt geometry"));
        }
        let len = segment_len(n, t_max);
        if file.metadata()?.len() as usize != len {
            return Err(bad("file length does not match geometry"));
        }
        let ptr = map_file(&file, len)?;
        Ok(ShmSegment {
            ptr,
            len,
            path: path.to_path_buf(),
            n,
            t_max,
            n_slots,
            owner: false,
        })
    }

    fn atom(&self, off: usize) -> &AtomicU64 {
        debug_assert!(off % 8 == 0 && off + 8 <= HEADER_BYTES);
        // mmap returns page-aligned memory, so every 8-aligned header
        // offset is a valid AtomicU64 cell
        unsafe { &*(self.ptr.add(off) as *const AtomicU64) }
    }

    fn result_off(&self) -> usize {
        HEADER_BYTES + self.n * self.t_max * 8
    }

    /// Row count the segment was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Probe capacity in columns.
    pub fn t_max(&self) -> usize {
        self.t_max
    }

    /// Worker doorbell slot count.
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// The segment file's path (travels to workers in ShmAttach).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Mapped byte length.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True for the degenerate zero-length map (never constructed; keeps
    /// clippy's `len_without_is_empty` satisfied).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Current round sequence (Acquire: pairs with [`Self::post_round`]).
    pub fn seq(&self) -> u64 {
        self.atom(OFF_SEQ).load(Ordering::Acquire)
    }

    /// Driver side: publish one round — copy the probe block in, write
    /// the descriptor, then bump the sequence (Release, so an Acquire
    /// reader of the new sequence sees the complete payload). Returns the
    /// new sequence number workers will ack.
    pub fn post_round(&self, block: &ShardBlock, m: &Mat) -> u64 {
        let t = m.cols();
        assert_eq!(m.rows(), self.n, "probe row count mismatch");
        assert!(t >= 1 && t <= self.t_max, "probe block wider than the segment");
        unsafe {
            std::ptr::copy_nonoverlapping(
                m.data().as_ptr(),
                self.ptr.add(HEADER_BYTES) as *mut f64,
                self.n * t,
            );
        }
        let (code, noise, param) = block_code(block);
        self.atom(OFF_BLOCK_CODE).store(code, Ordering::Relaxed);
        self.atom(OFF_BLOCK_NOISE).store(noise.to_bits(), Ordering::Relaxed);
        self.atom(OFF_BLOCK_PARAM).store(param, Ordering::Relaxed);
        self.atom(OFF_T).store(t as u64, Ordering::Relaxed);
        self.atom(OFF_SEQ).fetch_add(1, Ordering::Release) + 1
    }

    /// Driver side: re-dispatch the already-posted round under a fresh
    /// sequence number (crash recovery: the payload and descriptor are
    /// still in place; a respawned worker joined at the stale sequence
    /// and needs a new edge to serve). Every attached worker recomputes —
    /// shard fills are deterministic, so the rewrite is bit-identical.
    pub fn repost(&self) -> u64 {
        self.atom(OFF_SEQ).fetch_add(1, Ordering::Release) + 1
    }

    /// Worker side: decode the posted round descriptor.
    pub fn round_desc(&self) -> io::Result<(ShardBlock, usize)> {
        let code = self.atom(OFF_BLOCK_CODE).load(Ordering::Relaxed);
        let noise = f64::from_bits(self.atom(OFF_BLOCK_NOISE).load(Ordering::Relaxed));
        let param = self.atom(OFF_BLOCK_PARAM).load(Ordering::Relaxed) as usize;
        let t = self.atom(OFF_T).load(Ordering::Relaxed) as usize;
        let block = match code {
            0 => ShardBlock::Value { noise: None },
            1 => ShardBlock::Value { noise: Some(noise) },
            2 => ShardBlock::DParam(param),
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "shm: unknown round descriptor",
                ))
            }
        };
        if t == 0 || t > self.t_max {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "shm: round width out of range",
            ));
        }
        Ok((block, t))
    }

    /// Worker side: copy the posted `n × t` probe block out of the map.
    pub fn read_probe(&self, t: usize) -> Mat {
        assert!(t >= 1 && t <= self.t_max);
        let mut data = vec![0.0f64; self.n * t];
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.ptr.add(HEADER_BYTES) as *const f64,
                data.as_mut_ptr(),
                self.n * t,
            );
        }
        Mat::from_vec(self.n, t, data)
    }

    /// Worker side: place `rows × t` result values at global row `row0`
    /// (rows are packed at the **current round's** `t`, so the driver can
    /// lift a shard's range out in one contiguous copy).
    pub fn write_result_rows(&self, row0: usize, t: usize, data: &[f64]) {
        assert!(t >= 1 && t <= self.t_max);
        assert_eq!(data.len() % t, 0);
        let rows = data.len() / t;
        assert!(row0 + rows <= self.n, "result rows out of range");
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                (self.ptr.add(self.result_off()) as *mut f64).add(row0 * t),
                data.len(),
            );
        }
    }

    /// Driver side: copy a shard's result rows out (after that worker's
    /// doorbell confirmed the round — the Acquire in [`Self::ack_of`]
    /// pairs with the worker's Release in [`Self::ack`]).
    pub fn read_result_rows(&self, rows: Range<usize>, t: usize, out: &mut [f64]) {
        assert!(t >= 1 && t <= self.t_max);
        assert!(rows.end <= self.n);
        assert_eq!(out.len(), rows.len() * t);
        unsafe {
            std::ptr::copy_nonoverlapping(
                (self.ptr.add(self.result_off()) as *const f64).add(rows.start * t),
                out.as_mut_ptr(),
                out.len(),
            );
        }
    }

    /// Worker side: ring slot `slot`'s doorbell for sequence `seq`
    /// (Release: publishes the result rows written before it).
    pub fn ack(&self, slot: usize, seq: u64) {
        assert!(slot < self.n_slots);
        self.atom(OFF_ACKS + slot * ACK_STRIDE).store(seq, Ordering::Release);
    }

    /// Driver side: the last sequence slot `slot` acked.
    pub fn ack_of(&self, slot: usize) -> u64 {
        assert!(slot < self.n_slots);
        self.atom(OFF_ACKS + slot * ACK_STRIDE).load(Ordering::Acquire)
    }

    /// Ask every attached worker's data-plane thread to exit.
    pub fn request_shutdown(&self) {
        self.atom(OFF_SHUTDOWN).store(1, Ordering::Release);
    }

    /// Whether shutdown was requested (polled by worker data threads).
    pub fn shutdown_requested(&self) -> bool {
        self.atom(OFF_SHUTDOWN).load(Ordering::Acquire) != 0
    }
}

impl Drop for ShmSegment {
    fn drop(&mut self) {
        unmap(self.ptr, self.len);
        if self.owner {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

fn block_code(b: &ShardBlock) -> (u64, f64, u64) {
    match b {
        ShardBlock::Value { noise: None } => (0, 0.0, 0),
        ShardBlock::Value { noise: Some(s2) } => (1, *s2, 0),
        ShardBlock::DParam(p) => (2, 0.0, *p as u64),
    }
}

/// Poll backoff for doorbell/sequence waits: brief spin, then yields,
/// then short sleeps — a single-CPU host must never busy-wait its peer
/// off the core (the forked worker and the driver may share one core).
pub fn backoff(step: &mut u32) {
    *step = step.saturating_add(1);
    if *step < 64 {
        std::hint::spin_loop();
    } else if *step < 256 {
        std::thread::yield_now();
    } else {
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
}

// -- NUMA topology + pinning --------------------------------------------

/// `--numa` placement policy for the worker fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumaMode {
    /// detect nodes, round-robin workers across them, pin before load
    Auto,
    /// no detection, no pinning (the scheduler places workers freely)
    Off,
}

impl NumaMode {
    /// Parse the CLI form; errors name the accepted grammar.
    pub fn parse(s: &str) -> Result<NumaMode, String> {
        match s {
            "auto" => Ok(NumaMode::Auto),
            "off" => Ok(NumaMode::Off),
            _ => Err(format!("unknown numa mode '{s}' (expected auto | off)")),
        }
    }
}

impl std::fmt::Display for NumaMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NumaMode::Auto => write!(f, "auto"),
            NumaMode::Off => write!(f, "off"),
        }
    }
}

/// One NUMA node: its id and the CPUs it owns.
#[derive(Debug, Clone)]
pub struct NumaNode {
    /// node index (`nodeN` under the sysfs root)
    pub id: usize,
    /// raw kernel cpulist string (e.g. `0-3,8-11`), handed to workers
    pub cpulist: String,
    /// the parsed CPU ids
    pub cpus: Vec<usize>,
}

/// Parse a kernel cpulist (`0-3,8,10-11`) into CPU ids. Unparseable
/// pieces are skipped — topology is best-effort, never fatal.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a <= 4096 {
                    cpus.extend(a..=b);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            cpus.push(c);
        }
    }
    cpus
}

/// Detect NUMA topology from `/sys/devices/system/node/`. Empty when the
/// sysfs tree is absent (containers, non-Linux) — callers treat that as
/// "no placement to do".
pub fn numa_nodes() -> Vec<NumaNode> {
    numa_nodes_at(Path::new("/sys/devices/system/node"))
}

/// [`numa_nodes`] against an arbitrary sysfs-shaped root (test seam).
pub fn numa_nodes_at(root: &Path) -> Vec<NumaNode> {
    let mut nodes = Vec::new();
    let Ok(entries) = std::fs::read_dir(root) else {
        return nodes;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let Some(idx) = name.strip_prefix("node") else {
            continue;
        };
        let Ok(id) = idx.parse::<usize>() else {
            continue;
        };
        let Ok(raw) = std::fs::read_to_string(entry.path().join("cpulist")) else {
            continue;
        };
        let cpus = parse_cpulist(&raw);
        if !cpus.is_empty() {
            nodes.push(NumaNode {
                id,
                cpulist: raw.trim().to_string(),
                cpus,
            });
        }
    }
    nodes.sort_by_key(|node| node.id);
    nodes
}

/// Pin the calling process (and its future threads) to `cpus` via
/// `sched_setaffinity`. Returns whether the pin took effect; on
/// non-Linux targets this is a no-op returning `false`. Workers call it
/// **before** LoadShard builds panels, so first-touch places the pages
/// on the pinned node.
#[cfg(target_os = "linux")]
pub fn pin_to_cpus(cpus: &[usize]) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; 16]; // 1024 CPUs
    let mut any = false;
    for &c in cpus {
        if c < 1024 {
            mask[c / 64] |= 1 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux stub: affinity is best-effort, so "couldn't pin" is fine.
#[cfg(not(target_os = "linux"))]
pub fn pin_to_cpus(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_math_is_page_aligned_and_bounded() {
        assert_eq!(MAX_SLOTS, 61);
        assert_eq!(segment_len(100, 8), 4096 + 2 * 100 * 8 * 8);
        assert_eq!(HEADER_BYTES % 4096, 0);
        assert!(OFF_ACKS + MAX_SLOTS * ACK_STRIDE <= HEADER_BYTES);
        // seq, descriptor, and doorbells never share a cache line
        assert!(OFF_SEQ >= OFF_SHUTDOWN + 8 && OFF_BLOCK_CODE >= OFF_SEQ + 64);
        assert!(OFF_ACKS >= OFF_T + 8);
    }

    #[test]
    fn options_resolve_t_max_default() {
        assert_eq!(ShmOptions::default().resolved_t_max(), 64);
        assert_eq!(
            ShmOptions {
                t_max: 7,
                ..ShmOptions::default()
            }
            .resolved_t_max(),
            7
        );
    }

    #[test]
    fn create_open_roundtrip_runs_the_doorbell_protocol() {
        let n = 12;
        let seg = ShmSegment::create(n, 4, 2, &ShmOptions::default()).expect("create segment");
        assert_eq!((seg.n(), seg.t_max(), seg.n_slots()), (n, 4, 2));
        assert_eq!(seg.len(), segment_len(n, 4));
        assert!(!seg.is_empty());
        assert_eq!(seg.seq(), 0);
        let path = seg.path().to_path_buf();
        assert!(path.exists());

        // second handle = the worker's view of the same pages
        let peer = ShmSegment::open(&path).expect("open segment");
        assert_eq!((peer.n(), peer.t_max(), peer.n_slots()), (n, 4, 2));

        // driver posts a round; the peer sees payload + descriptor
        let m = Mat::from_fn(n, 3, |i, j| (i * 3 + j) as f64 - 5.5);
        let seq = seg.post_round(&ShardBlock::Value { noise: Some(0.25) }, &m);
        assert_eq!(seq, 1);
        assert_eq!(peer.seq(), 1);
        let (block, t) = peer.round_desc().unwrap();
        assert_eq!(block, ShardBlock::Value { noise: Some(0.25) });
        assert_eq!(t, 3);
        assert_eq!(peer.read_probe(3).max_abs_diff(&m), 0.0);

        // peer writes its result rows and rings the doorbell
        let rows = 4..9;
        let vals: Vec<f64> = (0..rows.len() * t).map(|v| v as f64 * 0.5).collect();
        peer.write_result_rows(rows.start, t, &vals);
        peer.ack(1, seq);
        assert_eq!(seg.ack_of(1), 1);
        assert_eq!(seg.ack_of(0), 0);
        let mut got = vec![0.0; vals.len()];
        seg.read_result_rows(rows, t, &mut got);
        assert_eq!(got, vals);

        // re-dispatch bumps the sequence without touching the payload
        assert_eq!(seg.repost(), 2);
        assert_eq!(peer.read_probe(3).max_abs_diff(&m), 0.0);

        // descriptor codes cover every ShardBlock variant
        for b in [ShardBlock::Value { noise: None }, ShardBlock::DParam(1)] {
            seg.post_round(&b, &m);
            assert_eq!(peer.round_desc().unwrap().0, b);
        }

        assert!(!seg.shutdown_requested());
        seg.request_shutdown();
        assert!(peer.shutdown_requested());

        drop(peer); // non-owner: file stays
        assert!(path.exists());
        drop(seg); // owner: file unlinked
        assert!(!path.exists());
    }

    #[test]
    fn create_rejects_bad_geometry_and_missing_dirs() {
        let opts = ShmOptions::default();
        assert!(ShmSegment::create(0, 4, 1, &opts).is_err());
        assert!(ShmSegment::create(8, 0, 1, &opts).is_err());
        assert!(ShmSegment::create(8, 4, 0, &opts).is_err());
        assert!(ShmSegment::create(8, 4, MAX_SLOTS + 1, &opts).is_err());
        // a Some(dir) override is tried alone — the fallback seam
        let gone = ShmOptions {
            dir: Some(std::env::temp_dir().join("bbmm-no-such-dir-shm-test")),
            t_max: 4,
        };
        assert!(ShmSegment::create(8, 4, 1, &gone).is_err());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn create_sweeps_segments_leaked_by_dead_drivers() {
        let dir = std::env::temp_dir().join(format!("bbmm-shm-sweep-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a "leaked" file stamped with a pid that cannot be alive
        // (pid_max tops out well below u32::MAX) and a live-owner file
        let dead = dir.join(format!("bbmm-seg-{}-0.shm", u32::MAX));
        let live = dir.join(format!("bbmm-seg-{}-999.shm", std::process::id()));
        let noise = dir.join("not-a-segment.shm");
        for f in [&dead, &live, &noise] {
            std::fs::write(f, b"stale").unwrap();
        }
        let opts = ShmOptions {
            dir: Some(dir.clone()),
            t_max: 4,
        };
        let seg = ShmSegment::create(8, 4, 1, &opts).expect("create sweeps, then succeeds");
        assert!(!dead.exists(), "dead driver's segment must be swept");
        assert!(live.exists(), "a live owner's segment must survive");
        assert!(noise.exists(), "non-segment files are never touched");
        drop(seg);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_rejects_foreign_files() {
        let path = std::env::temp_dir().join(format!(
            "bbmm-shm-foreign-{}.bin",
            std::process::id()
        ));
        std::fs::write(&path, vec![0u8; 8192]).unwrap();
        assert!(ShmSegment::open(&path).is_err(), "zero magic must be refused");
        std::fs::remove_file(&path).unwrap();
        assert!(ShmSegment::open(&path).is_err(), "missing file must error");
    }

    #[test]
    fn cpulists_parse_kernel_syntax() {
        assert_eq!(parse_cpulist("0-3,8,10-11"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("0\n"), vec![0]);
        assert_eq!(parse_cpulist(" 2 - 4 , 7 "), vec![2, 3, 4, 7]);
        assert!(parse_cpulist("").is_empty());
        assert!(parse_cpulist("x-y,,-").is_empty());
        assert_eq!(parse_cpulist("5-3"), Vec::<usize>::new(), "inverted range skipped");
    }

    #[test]
    fn numa_modes_parse() {
        assert_eq!(NumaMode::parse("auto").unwrap(), NumaMode::Auto);
        assert_eq!(NumaMode::parse("off").unwrap(), NumaMode::Off);
        assert!(NumaMode::parse("on").is_err());
        assert_eq!(NumaMode::Auto.to_string(), "auto");
    }

    #[test]
    fn topology_parses_a_sysfs_shaped_tree() {
        let root = std::env::temp_dir().join(format!("bbmm-numa-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (node, list) in [("node0", "0-1\n"), ("node1", "2-3\n")] {
            std::fs::create_dir_all(root.join(node)).unwrap();
            std::fs::write(root.join(node).join("cpulist"), list).unwrap();
        }
        // distractors: no cpulist, not a node dir
        std::fs::create_dir_all(root.join("node7")).unwrap();
        std::fs::create_dir_all(root.join("cpu0")).unwrap();
        let nodes = numa_nodes_at(&root);
        assert_eq!(nodes.len(), 2);
        assert_eq!((nodes[0].id, nodes[0].cpus.clone()), (0, vec![0, 1]));
        assert_eq!((nodes[1].id, nodes[1].cpulist.as_str()), (1, "2-3"));
        std::fs::remove_dir_all(&root).unwrap();
        assert!(numa_nodes_at(&root).is_empty(), "missing tree is no topology");
    }

    #[test]
    fn pinning_is_a_safe_call_on_any_host() {
        // no assertion on the outcome — CI may or may not allow affinity
        // calls — only that the FFI path neither crashes nor errors out
        // of the harness; an empty set is always refused
        assert!(!pin_to_cpus(&[]));
        let _ = pin_to_cpus(&[0]);
    }
}
