//! Multi-process shard backend: forked `bbmm shard-worker` children.
//!
//! The driver binds an ephemeral localhost TCP listener, forks N worker
//! processes (`bbmm shard-worker --connect <addr>`), and hands each a
//! round-robin subset of the shard partition via
//! [`WireMsg::LoadShard`]. Every product is then one broadcast/gather
//! round: the skinny RHS goes out to all workers in one frame each, the
//! per-shard row-blocks come back in one frame each — O(n·t) bytes per
//! mBCG iteration, no per-tile traffic.
//!
//! **Fault model.** Workers are stateless beyond what `LoadShard` carries,
//! so recovery is re-derivation: a heartbeat monitor pings workers between
//! products, and any socket error (heartbeat or mid-gather) kills the
//! slot, forks a replacement, replays `LoadShard` with the *current*
//! hyperparameters, and re-dispatches the same product. Shard fills are
//! deterministic serial loops, so the re-computed block is bit-identical
//! to what the lost worker would have sent — a crash can delay an answer
//! but never change it (asserted in `tests/dist_backend.rs`).

use super::protocol::{ResultBlock, WireMsg, PROTOCOL_VERSION};
use super::{kernel_wire_name, BackendStats, ShardBackend};
use crate::kernels::{Kernel, ShardBlock};
use crate::runtime::shard::partition_rows;
use crate::tensor::Mat;
use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How worker processes are forked and supervised.
#[derive(Debug, Clone)]
pub struct WorkerLaunch {
    /// worker executable (default: this process's own binary)
    pub exe: PathBuf,
    /// leading argv (the connect address is appended as the final arg)
    pub args: Vec<String>,
    /// heartbeat period in ms; 0 disables the background monitor
    pub heartbeat_ms: u64,
    /// deadline for a forked worker to connect and greet
    pub spawn_timeout_ms: u64,
    /// per-product read deadline (a hung worker counts as crashed)
    pub product_timeout_ms: u64,
}

impl Default for WorkerLaunch {
    fn default() -> WorkerLaunch {
        WorkerLaunch {
            exe: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("bbmm")),
            args: vec!["shard-worker".into(), "--connect".into()],
            heartbeat_ms: 1000,
            spawn_timeout_ms: 15_000,
            product_timeout_ms: 600_000,
        }
    }
}

struct WorkerProc {
    child: Child,
    stream: TcpStream,
}

struct ProcState {
    workers: Vec<Option<WorkerProc>>,
    raw: Vec<f64>,
    sigma2: f64,
    shut: bool,
}

struct MpInner {
    n: usize,
    partition: Vec<Range<usize>>,
    /// per worker slot: the shard ids it owns (round-robin, fixed)
    assign: Vec<Vec<usize>>,
    kernel_name: String,
    x: Mat,
    budget_mb: u64,
    launch: WorkerLaunch,
    listener: TcpListener,
    addr: String,
    state: Mutex<ProcState>,
    stats: Mutex<BackendStats>,
    stop: AtomicBool,
}

/// Process-parallel shard backend (see module docs).
pub struct MultiProcessBackend {
    inner: Arc<MpInner>,
    monitor: Option<JoinHandle<()>>,
}

const MAX_ROUND_ATTEMPTS: usize = 3;

impl MpInner {
    fn accept_deadline(&self) -> io::Result<TcpStream> {
        let deadline = Instant::now() + Duration::from_millis(self.launch.spawn_timeout_ms);
        loop {
            match self.listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    return Ok(s);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "shard worker did not connect in time",
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Fork one worker, wait for its greeting, leave it ready for LoadShard.
    fn spawn_one(&self) -> io::Result<WorkerProc> {
        let mut child = Command::new(&self.launch.exe)
            .args(&self.launch.args)
            .arg(&self.addr)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()?;
        let stream = match self.accept_deadline() {
            Ok(s) => s,
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        };
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_millis(self.launch.spawn_timeout_ms)))?;
        let hello = WireMsg::decode(&mut (&stream));
        match hello {
            Ok(WireMsg::Hello { version, .. }) if version == PROTOCOL_VERSION => {}
            other => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad worker greeting: {other:?}"),
                ));
            }
        }
        stream.set_read_timeout(Some(Duration::from_millis(self.launch.product_timeout_ms)))?;
        Ok(WorkerProc { child, stream })
    }

    fn send_load(&self, state: &ProcState, w: usize) -> io::Result<()> {
        let msg = WireMsg::LoadShard {
            x: self.x.clone(),
            kernel: self.kernel_name.clone(),
            raw: state.raw.clone(),
            sigma2: state.sigma2,
            n_shards: self.partition.len() as u64,
            owned: self.assign[w].iter().map(|&s| s as u64).collect(),
            budget_mb: self.budget_mb,
        };
        let wp = state.workers[w].as_ref().expect("booting an empty slot");
        msg.encode(&mut (&wp.stream))
    }

    /// Fill slot `w` with a freshly forked + loaded worker.
    fn boot(&self, state: &mut ProcState, w: usize) -> io::Result<()> {
        state.workers[w] = Some(self.spawn_one()?);
        self.send_load(state, w)
    }

    /// Kill + re-fork slot `w`, replaying current params (counts a restart).
    fn respawn(&self, state: &mut ProcState, w: usize) -> io::Result<()> {
        if let Some(mut wp) = state.workers[w].take() {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
        }
        self.boot(state, w)?;
        self.stats.lock().unwrap().restarts += 1;
        Ok(())
    }

    /// One broadcast/gather round with crash recovery (see module docs).
    fn round(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        let t = m.cols();
        assert_eq!(m.rows(), self.n);
        assert_eq!(out.shape(), (self.n, t));
        let mut frame = Vec::new();
        WireMsg::Matmul {
            block: *block,
            m: m.clone(),
        }
        .encode(&mut frame)
        .expect("in-memory encode cannot fail");

        let mut state = self.state.lock().unwrap();
        assert!(!state.shut, "backend is shut down");
        let nw = state.workers.len();
        let mut done = vec![false; nw];
        let mut covered = vec![false; self.partition.len()];
        let (mut tx, mut rx) = (0u64, 0u64);
        for attempt in 0..MAX_ROUND_ATTEMPTS {
            // 1) make every pending slot live (respawn replays params)
            for w in 0..nw {
                if !done[w] && state.workers[w].is_none() {
                    if let Err(e) = self.respawn(&mut state, w) {
                        if attempt + 1 == MAX_ROUND_ATTEMPTS {
                            panic!("shard worker {w} cannot be respawned: {e}");
                        }
                        continue;
                    }
                }
            }
            // 2) broadcast the RHS to every pending worker (pipelined: all
            //    writes go out before any gather blocks on a read)
            for w in 0..nw {
                if done[w] {
                    continue;
                }
                let sent = match state.workers[w].as_ref() {
                    Some(wp) => (&wp.stream).write_all(&frame).is_ok(),
                    None => continue,
                };
                if sent {
                    tx += frame.len() as u64;
                } else {
                    state.workers[w] = None; // discovered dead on write
                }
            }
            // 3) gather per-shard row-blocks; any failure marks the slot
            //    dead for the next attempt's deterministic re-dispatch
            for w in 0..nw {
                if done[w] {
                    continue;
                }
                let gathered = match state.workers[w].as_ref() {
                    Some(wp) => WireMsg::decode(&mut (&wp.stream)),
                    None => continue,
                };
                match gathered {
                    Ok(WireMsg::MatmulResult { blocks }) => {
                        for rb in &blocks {
                            rx += self.scatter(rb, t, &mut covered, out);
                        }
                        done[w] = true;
                    }
                    Ok(WireMsg::Err { message }) => {
                        // a worker-side *logic* error is deterministic —
                        // respawning cannot fix it
                        panic!("shard worker {w} failed: {message}");
                    }
                    _ => state.workers[w] = None,
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        assert!(
            done.iter().all(|&d| d),
            "shard workers kept failing after {MAX_ROUND_ATTEMPTS} dispatch attempts"
        );
        assert!(
            covered.iter().all(|&c| c),
            "gathered blocks do not cover the shard partition"
        );
        let mut st = self.stats.lock().unwrap();
        st.rounds += 1;
        st.bytes_tx += tx;
        st.bytes_rx += rx;
    }

    /// Copy one gathered row-block into the assembled product.
    fn scatter(&self, rb: &ResultBlock, t: usize, covered: &mut [bool], out: &mut Mat) -> u64 {
        let s = rb.shard as usize;
        assert!(s < self.partition.len(), "worker returned unknown shard");
        let rows = self.partition[s].clone();
        assert_eq!(
            rb.data.shape(),
            (rows.len(), t),
            "worker returned a misshapen block"
        );
        assert!(!covered[s], "shard {s} gathered twice in one round");
        covered[s] = true;
        out.data_mut()[rows.start * t..rows.end * t].copy_from_slice(rb.data.data());
        rb.data.data().len() as u64 * 8
    }

    /// Ping every worker; respawn the dead. Skips (without error) when a
    /// product currently holds the state lock — active traffic is its own
    /// liveness proof.
    fn heartbeat(&self) {
        let Ok(mut state) = self.state.try_lock() else {
            return;
        };
        if state.shut {
            return;
        }
        for w in 0..state.workers.len() {
            let alive = match state.workers[w].as_ref() {
                None => false,
                Some(wp) => {
                    let _ = wp
                        .stream
                        .set_read_timeout(Some(Duration::from_millis(2000)));
                    let ok = WireMsg::Ping.encode(&mut (&wp.stream)).is_ok()
                        && matches!(WireMsg::decode(&mut (&wp.stream)), Ok(WireMsg::Pong));
                    let _ = wp.stream.set_read_timeout(Some(Duration::from_millis(
                        self.launch.product_timeout_ms,
                    )));
                    ok
                }
            };
            if !alive {
                if let Some(mut wp) = state.workers[w].take() {
                    let _ = wp.child.kill();
                    let _ = wp.child.wait();
                }
                let _ = self.respawn(&mut state, w); // next round retries on failure
            }
        }
    }

    fn shutdown_workers(&self) {
        let mut state = self.state.lock().unwrap();
        state.shut = true;
        for slot in state.workers.iter_mut() {
            if let Some(mut wp) = slot.take() {
                let _ = WireMsg::Shutdown.encode(&mut (&wp.stream));
                // grace period, then force
                let deadline = Instant::now() + Duration::from_millis(500);
                loop {
                    match wp.child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10))
                        }
                        _ => {
                            let _ = wp.child.kill();
                            let _ = wp.child.wait();
                            break;
                        }
                    }
                }
            }
        }
    }
}

impl MultiProcessBackend {
    /// Fork `workers` shard workers over an `n_shards` partition of
    /// `K(x, x)` and load them. `budget_mb` is the **per-worker**
    /// materialisation budget (each worker plans its own shards via
    /// [`crate::linalg::op::MmmPlan::auto_sharded`], so aggregate K
    /// storage is sharded, never replicated). Errors if the kernel family
    /// is not wire-encodable ([`kernel_wire_name`]) or workers fail to
    /// fork/connect.
    pub fn launch(
        x: Mat,
        kernel: &dyn Kernel,
        sigma2: f64,
        n_shards: usize,
        workers: usize,
        budget_mb: usize,
        launch: WorkerLaunch,
    ) -> io::Result<MultiProcessBackend> {
        let kernel_name = kernel_wire_name(kernel)
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "kernel family is not wire-encodable (proc backend supports \
                     rbf/matern12/matern32/matern52)",
                )
            })?
            .to_string();
        let n = x.rows();
        let partition = partition_rows(n, n_shards);
        let nw = workers.clamp(1, partition.len().max(1));
        let assign: Vec<Vec<usize>> = (0..nw)
            .map(|w| (w..partition.len()).step_by(nw).collect())
            .collect();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let inner = Arc::new(MpInner {
            n,
            partition,
            assign,
            kernel_name,
            x,
            budget_mb: budget_mb as u64,
            launch,
            listener,
            addr,
            state: Mutex::new(ProcState {
                workers: (0..nw).map(|_| None).collect(),
                raw: kernel.params(),
                sigma2,
                shut: false,
            }),
            stats: Mutex::new(BackendStats::default()),
            stop: AtomicBool::new(false),
        });
        {
            let mut state = inner.state.lock().unwrap();
            for w in 0..nw {
                inner.boot(&mut state, w)?;
            }
        }
        let monitor = (inner.launch.heartbeat_ms > 0).then(|| {
            let mon = Arc::clone(&inner);
            std::thread::spawn(move || {
                let step = Duration::from_millis(50);
                let mut since_ping = 0u64;
                while !mon.stop.load(Ordering::Relaxed) {
                    std::thread::sleep(step);
                    since_ping += 50;
                    if since_ping >= mon.launch.heartbeat_ms {
                        since_ping = 0;
                        mon.heartbeat();
                    }
                }
            })
        });
        Ok(MultiProcessBackend { inner, monitor })
    }

    /// Worker process count.
    pub fn workers(&self) -> usize {
        self.inner.assign.len()
    }

    /// The listener address workers connect back to.
    pub fn addr(&self) -> &str {
        &self.inner.addr
    }

    /// Kill worker `w`'s process **without** clearing its slot — the next
    /// round (or heartbeat) must *discover* the death and recover. This is
    /// the chaos hook for the crash-mid-solve tests.
    pub fn kill_worker(&self, w: usize) {
        let mut state = self.inner.state.lock().unwrap();
        if let Some(wp) = state.workers[w].as_mut() {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
        }
    }

    /// Synchronously ping every worker, respawning the dead; returns the
    /// live count afterwards.
    pub fn ping_all(&self) -> usize {
        self.inner.heartbeat();
        let state = self.inner.state.lock().unwrap();
        state.workers.iter().filter(|w| w.is_some()).count()
    }
}

impl ShardBackend for MultiProcessBackend {
    fn describe(&self) -> String {
        format!(
            "proc:{} ({} shards @ {})",
            self.workers(),
            self.inner.partition.len(),
            self.inner.addr
        )
    }

    fn n(&self) -> usize {
        self.inner.n
    }

    fn n_shards(&self) -> usize {
        self.inner.partition.len()
    }

    fn shard_rows(&self, s: usize) -> Range<usize> {
        self.inner.partition[s].clone()
    }

    fn matmul_block(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        self.inner.round(block, m, out);
    }

    fn set_params(&self, raw: &[f64], sigma2: Option<f64>) {
        let mut state = self.inner.state.lock().unwrap();
        state.raw = raw.to_vec();
        if let Some(s2) = sigma2 {
            state.sigma2 = s2;
        }
        let msg = WireMsg::SetParams {
            raw: raw.to_vec(),
            sigma2,
        };
        for w in 0..state.workers.len() {
            let dead = match state.workers[w].as_ref() {
                Some(wp) => msg.encode(&mut (&wp.stream)).is_err(),
                None => false,
            };
            if dead {
                // respawn later with the new params via LoadShard replay
                state.workers[w] = None;
            }
        }
    }

    fn stats(&self) -> BackendStats {
        *self.inner.stats.lock().unwrap()
    }

    fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.shutdown_workers();
    }
}

impl Drop for MultiProcessBackend {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        self.inner.shutdown_workers();
    }
}
