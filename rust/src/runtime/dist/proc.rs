//! Multi-process shard backend: forked `bbmm shard-worker` children.
//!
//! The driver binds an ephemeral localhost TCP listener, forks N worker
//! processes (`bbmm shard-worker --connect <addr>`), and hands each a
//! round-robin subset of the shard partition via
//! [`WireMsg::LoadShard`]. Every product is then one broadcast/gather
//! round: the skinny RHS goes out to all workers in one frame each, the
//! per-shard row-blocks come back in one frame each — O(n·t) bytes per
//! mBCG iteration, no per-tile traffic.
//!
//! **Transports.** The socket round above is the [`Transport::Tcp`] data
//! plane. With [`Transport::Shm`] the driver additionally creates one
//! shared-memory segment (`super::shm`) that every same-host worker maps:
//! a round becomes "write probe, bump sequence, wait on per-worker
//! doorbells, copy rows out" — zero per-iteration serialization and zero
//! payload bytes on the socket. TCP always remains the **control plane**
//! (LoadShard, SetParams + acks, heartbeats, Shutdown, the ShmAttach
//! handshake itself) and the automatic per-worker fallback when the
//! segment cannot be created or a worker fails to map it; rounds wider
//! than the segment's probe capacity also ride TCP per round.
//! `BackendStats::shm_rounds` / `ctrl_bytes` make the split observable.
//!
//! **Fault model.** Workers are stateless beyond what `LoadShard` carries,
//! so recovery is re-derivation: a heartbeat monitor pings workers between
//! products, and any socket error (heartbeat or mid-gather) kills the
//! slot, forks a replacement, replays `LoadShard` with the *current*
//! hyperparameters, and re-dispatches the same product. Shard fills are
//! deterministic serial loops, so the re-computed block is bit-identical
//! to what the lost worker would have sent — a crash can delay an answer
//! but never change it (asserted in `tests/dist_backend.rs`). Over shm
//! the re-dispatch is a **re-post**: the sequence word is bumped again, so
//! every attached worker recomputes the round — the survivors' rewrites
//! are bit-identical to what the driver already copied out, and the
//! respawned worker (which joined at the stale sequence) serves it fresh.
//! A slot is never abandoned with its process still running: every
//! timeout or handshake failure kills + reaps the child before clearing
//! the slot, so a hung shm-attached worker can never surface later as a
//! zombie writing a stale round (at a stale width) over a newer round's
//! rows — and as a second fence, workers re-check the round sequence and
//! discard their compute instead of writing when it has moved.

use super::protocol::{ResultBlock, WireMsg, PROTOCOL_VERSION};
use super::shm::{self, backoff, NumaMode, ShmOptions, ShmSegment};
use super::{kernel_wire_name, BackendStats, ShardBackend};
use crate::kernels::{Kernel, ShardBlock};
use crate::runtime::shard::partition_rows;
use crate::tensor::Mat;
use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How worker processes are forked and supervised.
#[derive(Debug, Clone)]
pub struct WorkerLaunch {
    /// worker executable (default: this process's own binary)
    pub exe: PathBuf,
    /// leading argv (the connect address is appended as the final arg)
    pub args: Vec<String>,
    /// heartbeat period in ms; 0 disables the background monitor
    pub heartbeat_ms: u64,
    /// deadline for a forked worker to connect and greet
    pub spawn_timeout_ms: u64,
    /// per-product read deadline (a hung worker counts as crashed)
    pub product_timeout_ms: u64,
    /// read deadline for a SetParams acknowledgement. Kept well below
    /// the product deadline so one hung worker stalls a hyperparameter
    /// push for seconds, not the full product timeout; generous enough
    /// for a `MaterializeK` worker to rebuild its kernel panels before
    /// acking.
    pub params_ack_timeout_ms: u64,
}

impl Default for WorkerLaunch {
    fn default() -> WorkerLaunch {
        WorkerLaunch {
            exe: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("bbmm")),
            args: vec!["shard-worker".into(), "--connect".into()],
            heartbeat_ms: 1000,
            spawn_timeout_ms: 15_000,
            product_timeout_ms: 600_000,
            params_ack_timeout_ms: 30_000,
        }
    }
}

/// Which data plane carries the per-iteration Matmul traffic.
#[derive(Debug, Clone)]
pub enum Transport {
    /// every round through the socket (works across hosts)
    Tcp,
    /// same-host zero-copy segment for rounds; the socket stays the
    /// control plane and the per-worker fallback when mapping fails
    Shm(ShmOptions),
}

struct WorkerProc {
    child: Child,
    stream: TcpStream,
    /// this worker mapped the segment (ShmReady ok) — rounds go via shm
    shm: bool,
}

struct ProcState {
    workers: Vec<Option<WorkerProc>>,
    raw: Vec<f64>,
    sigma2: f64,
    shut: bool,
}

struct MpInner {
    n: usize,
    partition: Vec<Range<usize>>,
    /// per worker slot: the shard ids it owns (round-robin, fixed)
    assign: Vec<Vec<usize>>,
    kernel_name: String,
    x: Mat,
    budget_mb: u64,
    launch: WorkerLaunch,
    listener: TcpListener,
    addr: String,
    state: Mutex<ProcState>,
    stats: Mutex<BackendStats>,
    stop: AtomicBool,
    /// the shared data-plane segment (`None` = pure TCP, by choice or
    /// because creation failed — see `shm_fallback`)
    seg: Option<ShmSegment>,
    /// probe capacity the segment was sized for (0 when `seg` is None)
    t_max: usize,
    /// why the requested shm transport fell back to TCP, for `describe`
    shm_fallback: Option<String>,
    /// per worker slot: the cpulist it is pinned to (NUMA round-robin);
    /// `None` = unpinned (numa off, or fewer than two nodes)
    numa_cpus: Vec<Option<String>>,
    /// human-readable placement summary for `describe`
    numa_note: String,
}

/// Exact frame size of `msg` on the wire (control-plane accounting).
fn frame_len(msg: &WireMsg) -> u64 {
    let mut buf = Vec::new();
    msg.encode(&mut buf).expect("in-memory encode cannot fail");
    buf.len() as u64
}

/// Process-parallel shard backend (see module docs).
pub struct MultiProcessBackend {
    inner: Arc<MpInner>,
    monitor: Option<JoinHandle<()>>,
}

const MAX_ROUND_ATTEMPTS: usize = 3;

impl MpInner {
    fn accept_deadline(&self) -> io::Result<TcpStream> {
        let deadline = Instant::now() + Duration::from_millis(self.launch.spawn_timeout_ms);
        loop {
            match self.listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    return Ok(s);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "shard worker did not connect in time",
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn note_ctrl(&self, bytes: u64) {
        self.stats.lock().unwrap().ctrl_bytes += bytes;
    }

    /// Fork one worker, wait for its greeting, leave it ready for LoadShard.
    fn spawn_one(&self, w: usize) -> io::Result<WorkerProc> {
        let mut cmd = Command::new(&self.launch.exe);
        cmd.args(&self.launch.args).arg(&self.addr);
        // NUMA placement: the worker pins itself before building panels,
        // so first-touch lands the pages on its node
        if let Some(cpus) = self.numa_cpus.get(w).and_then(|c| c.as_ref()) {
            cmd.arg("--pin-cpus").arg(cpus);
        }
        let mut child = cmd.stdin(Stdio::null()).stdout(Stdio::null()).spawn()?;
        let stream = match self.accept_deadline() {
            Ok(s) => s,
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        };
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_millis(self.launch.spawn_timeout_ms)))?;
        let hello = WireMsg::decode(&mut (&stream));
        match hello {
            Ok(WireMsg::Hello { version, pid }) if version == PROTOCOL_VERSION => {
                self.note_ctrl(frame_len(&WireMsg::Hello { version, pid }));
            }
            other => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad worker greeting: {other:?}"),
                ));
            }
        }
        stream.set_read_timeout(Some(Duration::from_millis(self.launch.product_timeout_ms)))?;
        Ok(WorkerProc {
            child,
            stream,
            shm: false,
        })
    }

    fn send_load(&self, state: &ProcState, w: usize) -> io::Result<()> {
        let msg = WireMsg::LoadShard {
            x: self.x.clone(),
            kernel: self.kernel_name.clone(),
            raw: state.raw.clone(),
            sigma2: state.sigma2,
            n_shards: self.partition.len() as u64,
            owned: self.assign[w].iter().map(|&s| s as u64).collect(),
            budget_mb: self.budget_mb,
        };
        let mut frame = Vec::new();
        msg.encode(&mut frame).expect("in-memory encode cannot fail");
        let wp = state.workers[w].as_ref().expect("booting an empty slot");
        (&wp.stream).write_all(&frame)?;
        self.note_ctrl(frame.len() as u64);
        Ok(())
    }

    /// Offer slot `w` the shared segment. On `ShmReady { ok: true }` the
    /// worker's rounds move to the shm lane; a refused or failed attach
    /// (remote host, map error) keeps it on TCP — never fatal. A worker
    /// that dies during the handshake is dropped for the next round's
    /// respawn path.
    fn attach_worker(&self, state: &mut ProcState, w: usize) {
        let Some(seg) = self.seg.as_ref() else {
            return;
        };
        let msg = WireMsg::ShmAttach {
            path: seg.path().to_string_lossy().into_owned(),
            t_max: seg.t_max() as u64,
            slot: w as u64,
        };
        let mut ctrl = 0u64;
        let outcome: Option<bool> = match state.workers[w].as_ref() {
            None => return,
            Some(wp) => {
                if msg.encode(&mut (&wp.stream)).is_err() {
                    None
                } else {
                    ctrl += frame_len(&msg);
                    match WireMsg::decode(&mut (&wp.stream)) {
                        Ok(WireMsg::ShmReady { ok, detail }) => {
                            ctrl += frame_len(&WireMsg::ShmReady { ok, detail });
                            Some(ok)
                        }
                        _ => None,
                    }
                }
            }
        };
        match outcome {
            Some(ok) => {
                if let Some(wp) = state.workers[w].as_mut() {
                    wp.shm = ok;
                }
            }
            None => self.drop_slot(state, w),
        }
        self.note_ctrl(ctrl);
    }

    /// Fill slot `w` with a freshly forked + loaded (+ attached) worker.
    fn boot(&self, state: &mut ProcState, w: usize) -> io::Result<()> {
        state.workers[w] = Some(self.spawn_one(w)?);
        self.send_load(state, w)?;
        self.attach_worker(state, w);
        Ok(())
    }

    /// Clear slot `w`, killing + reaping any still-running child first.
    /// A slot must never be abandoned with its process alive: an
    /// shm-attached zombie that finishes a stale round later would pack
    /// result rows at the old round's width over a newer round's rows,
    /// and its late doorbell ack could clobber the replacement worker's.
    fn drop_slot(&self, state: &mut ProcState, w: usize) {
        if let Some(mut wp) = state.workers[w].take() {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
        }
    }

    /// Kill + re-fork slot `w`, replaying current params (counts a restart).
    fn respawn(&self, state: &mut ProcState, w: usize) -> io::Result<()> {
        self.drop_slot(state, w);
        self.boot(state, w)?;
        self.stats.lock().unwrap().restarts += 1;
        Ok(())
    }

    /// One broadcast/gather round with crash recovery (see module docs).
    ///
    /// Workers attached to the segment are served over the shm lane (post
    /// sequence, wait doorbells, copy rows out of shared pages); everyone
    /// else gets the classic TCP broadcast/gather. The TCP frame is built
    /// lazily, so an all-shm round performs **zero serialization**.
    fn round(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        let t = m.cols();
        assert_eq!(m.rows(), self.n);
        assert_eq!(out.shape(), (self.n, t));
        // rounds wider than the segment's probe capacity ride TCP
        let shm_capable = self.seg.is_some() && t <= self.t_max;
        let mut frame: Option<Vec<u8>> = None;

        let mut state = self.state.lock().unwrap();
        assert!(!state.shut, "backend is shut down");
        let nw = state.workers.len();
        let mut done = vec![false; nw];
        let mut covered = vec![false; self.partition.len()];
        let (mut tx, mut rx) = (0u64, 0u64);
        let mut posted: Option<u64> = None;
        let mut tcp_used = false;
        for attempt in 0..MAX_ROUND_ATTEMPTS {
            // 1) make every pending slot live (respawn replays params and
            //    re-attaches the segment)
            for w in 0..nw {
                if !done[w] && state.workers[w].is_none() {
                    if let Err(e) = self.respawn(&mut state, w) {
                        if attempt + 1 == MAX_ROUND_ATTEMPTS {
                            panic!("shard worker {w} cannot be respawned: {e}");
                        }
                        continue;
                    }
                }
            }
            let on_shm_lane = |state: &ProcState, w: usize| {
                shm_capable && matches!(state.workers[w].as_ref(), Some(wp) if wp.shm)
            };
            // 2) shm lane: (re)post the round. A re-post after a respawn
            //    bumps the sequence so every attached worker recomputes —
            //    survivors rewrite the bits the driver already copied, and
            //    the replacement (which joined at the stale sequence)
            //    serves the round fresh.
            let shm_pending: Vec<usize> = (0..nw)
                .filter(|&w| !done[w] && on_shm_lane(&state, w))
                .collect();
            if !shm_pending.is_empty() {
                let seg = self.seg.as_ref().expect("shm lane implies a segment");
                posted = Some(match posted {
                    None => seg.post_round(block, m),
                    Some(_) => seg.repost(),
                });
            }
            // 3) TCP lane: broadcast the RHS to every pending worker
            //    (pipelined: all writes go out before any gather blocks)
            for w in 0..nw {
                if done[w] || on_shm_lane(&state, w) {
                    continue;
                }
                let f = frame.get_or_insert_with(|| {
                    let mut buf = Vec::new();
                    WireMsg::Matmul {
                        block: *block,
                        m: m.clone(),
                    }
                    .encode(&mut buf)
                    .expect("in-memory encode cannot fail");
                    buf
                });
                let sent = match state.workers[w].as_ref() {
                    Some(wp) => (&wp.stream).write_all(f).is_ok(),
                    None => continue,
                };
                if sent {
                    tx += f.len() as u64;
                    tcp_used = true;
                } else {
                    self.drop_slot(&mut state, w); // discovered dead on write
                }
            }
            // 4) TCP gathers; any failure marks the slot dead for the next
            //    attempt's deterministic re-dispatch
            for w in 0..nw {
                if done[w] || on_shm_lane(&state, w) {
                    continue;
                }
                let gathered = match state.workers[w].as_ref() {
                    Some(wp) => WireMsg::decode(&mut (&wp.stream)),
                    None => continue,
                };
                match gathered {
                    Ok(WireMsg::MatmulResult { blocks }) => {
                        for rb in &blocks {
                            rx += self.scatter(rb, t, &mut covered, out);
                        }
                        done[w] = true;
                    }
                    Ok(WireMsg::Err { message }) => {
                        // a worker-side *logic* error is deterministic —
                        // respawning cannot fix it
                        panic!("shard worker {w} failed: {message}");
                    }
                    // a gather timeout can leave a hung-but-alive worker:
                    // drop_slot kills it so an shm-attached straggler can
                    // never write into a later round's rows
                    _ => self.drop_slot(&mut state, w),
                }
            }
            // 5) shm doorbell wait: accept a worker once its ack reaches
            //    the latest posted sequence, then lift its rows straight
            //    out of the segment. A worker whose process exits
            //    mid-round is dropped for the next attempt.
            if let (Some(seq), false) = (posted, shm_pending.is_empty()) {
                let seg = self.seg.as_ref().expect("shm lane implies a segment");
                let deadline =
                    Instant::now() + Duration::from_millis(self.launch.product_timeout_ms);
                let mut step = 0u32;
                loop {
                    let mut waiting = false;
                    for &w in &shm_pending {
                        if done[w] {
                            continue;
                        }
                        if seg.ack_of(w) == seq {
                            for &s in &self.assign[w] {
                                let rows = self.partition[s].clone();
                                assert!(!covered[s], "shard {s} gathered twice in one round");
                                covered[s] = true;
                                seg.read_result_rows(
                                    rows.clone(),
                                    t,
                                    &mut out.data_mut()[rows.start * t..rows.end * t],
                                );
                            }
                            done[w] = true;
                            continue;
                        }
                        let died = match state.workers[w].as_mut() {
                            Some(wp) => matches!(wp.child.try_wait(), Ok(Some(_))),
                            None => continue,
                        };
                        if died {
                            self.drop_slot(&mut state, w);
                        } else {
                            waiting = true;
                        }
                    }
                    if !waiting {
                        break;
                    }
                    if Instant::now() >= deadline {
                        // hung but alive: kill before abandoning the slot,
                        // or the zombie's eventual segment write could land
                        // under a later round's (different) row packing
                        for &w in &shm_pending {
                            if !done[w] {
                                self.drop_slot(&mut state, w);
                            }
                        }
                        break;
                    }
                    backoff(&mut step);
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        assert!(
            done.iter().all(|&d| d),
            "shard workers kept failing after {MAX_ROUND_ATTEMPTS} dispatch attempts"
        );
        assert!(
            covered.iter().all(|&c| c),
            "gathered blocks do not cover the shard partition"
        );
        let mut st = self.stats.lock().unwrap();
        st.rounds += 1;
        if posted.is_some() && !tcp_used {
            st.shm_rounds += 1;
        }
        st.bytes_tx += tx;
        st.bytes_rx += rx;
    }

    /// Copy one gathered row-block into the assembled product.
    fn scatter(&self, rb: &ResultBlock, t: usize, covered: &mut [bool], out: &mut Mat) -> u64 {
        let s = rb.shard as usize;
        assert!(s < self.partition.len(), "worker returned unknown shard");
        let rows = self.partition[s].clone();
        assert_eq!(
            rb.data.shape(),
            (rows.len(), t),
            "worker returned a misshapen block"
        );
        assert!(!covered[s], "shard {s} gathered twice in one round");
        covered[s] = true;
        out.data_mut()[rows.start * t..rows.end * t].copy_from_slice(rb.data.data());
        rb.data.data().len() as u64 * 8
    }

    /// Ping every worker; respawn the dead. Skips (without error) when a
    /// product currently holds the state lock — active traffic is its own
    /// liveness proof.
    fn heartbeat(&self) {
        let Ok(mut state) = self.state.try_lock() else {
            return;
        };
        if state.shut {
            return;
        }
        for w in 0..state.workers.len() {
            let alive = match state.workers[w].as_ref() {
                None => false,
                Some(wp) => {
                    let _ = wp
                        .stream
                        .set_read_timeout(Some(Duration::from_millis(2000)));
                    let ok = WireMsg::Ping.encode(&mut (&wp.stream)).is_ok()
                        && matches!(WireMsg::decode(&mut (&wp.stream)), Ok(WireMsg::Pong));
                    let _ = wp.stream.set_read_timeout(Some(Duration::from_millis(
                        self.launch.product_timeout_ms,
                    )));
                    if ok {
                        self.note_ctrl(frame_len(&WireMsg::Ping) + frame_len(&WireMsg::Pong));
                    }
                    ok
                }
            };
            if !alive {
                let _ = self.respawn(&mut state, w); // kills first; next round retries on failure
            }
        }
    }

    fn shutdown_workers(&self) {
        let mut state = self.state.lock().unwrap();
        state.shut = true;
        // wake the data-plane threads first so workers can exit cleanly
        if let Some(seg) = self.seg.as_ref() {
            seg.request_shutdown();
        }
        for slot in state.workers.iter_mut() {
            if let Some(mut wp) = slot.take() {
                let _ = WireMsg::Shutdown.encode(&mut (&wp.stream));
                // grace period, then force
                let deadline = Instant::now() + Duration::from_millis(500);
                loop {
                    match wp.child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10))
                        }
                        _ => {
                            let _ = wp.child.kill();
                            let _ = wp.child.wait();
                            break;
                        }
                    }
                }
            }
        }
    }
}

impl MultiProcessBackend {
    /// Fork `workers` shard workers over an `n_shards` partition of
    /// `K(x, x)` and load them. `budget_mb` is the **per-worker**
    /// materialisation budget (each worker plans its own shards via
    /// [`crate::linalg::op::MmmPlan::auto_sharded`], so aggregate K
    /// storage is sharded, never replicated). Errors if the kernel family
    /// is not wire-encodable ([`kernel_wire_name`]) or workers fail to
    /// fork/connect.
    pub fn launch(
        x: Mat,
        kernel: &dyn Kernel,
        sigma2: f64,
        n_shards: usize,
        workers: usize,
        budget_mb: usize,
        launch: WorkerLaunch,
    ) -> io::Result<MultiProcessBackend> {
        Self::launch_with(
            x,
            kernel,
            sigma2,
            n_shards,
            workers,
            budget_mb,
            launch,
            Transport::Tcp,
            NumaMode::Off,
        )
    }

    /// [`Self::launch`] with an explicit data-plane transport and NUMA
    /// placement policy. A requested shm transport that cannot create its
    /// segment (no usable directory, unsupported target, too many
    /// workers) degrades to TCP with the cause recorded in
    /// [`ShardBackend::describe`] — launching never fails for transport
    /// reasons. With `NumaMode::Auto` and ≥ 2 detected nodes, worker
    /// slots are pinned round-robin across node cpulists.
    #[allow(clippy::too_many_arguments)]
    pub fn launch_with(
        x: Mat,
        kernel: &dyn Kernel,
        sigma2: f64,
        n_shards: usize,
        workers: usize,
        budget_mb: usize,
        launch: WorkerLaunch,
        transport: Transport,
        numa: NumaMode,
    ) -> io::Result<MultiProcessBackend> {
        let kernel_name = kernel_wire_name(kernel)
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "kernel family is not wire-encodable (proc backend supports \
                     rbf/matern12/matern32/matern52)",
                )
            })?
            .to_string();
        let n = x.rows();
        let partition = partition_rows(n, n_shards);
        let nw = workers.clamp(1, partition.len().max(1));
        let assign: Vec<Vec<usize>> = (0..nw)
            .map(|w| (w..partition.len()).step_by(nw).collect())
            .collect();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let nodes = match numa {
            NumaMode::Auto => shm::numa_nodes(),
            NumaMode::Off => Vec::new(),
        };
        let (numa_cpus, numa_note) = if nodes.len() >= 2 {
            let cpus = (0..nw)
                .map(|w| Some(nodes[w % nodes.len()].cpulist.clone()))
                .collect();
            (cpus, format!("numa: {} nodes round-robin", nodes.len()))
        } else {
            let note = match numa {
                NumaMode::Off => "numa: off".to_string(),
                NumaMode::Auto => "numa: single node, no pinning".to_string(),
            };
            (vec![None; nw], note)
        };
        let (seg, t_max, shm_fallback) = match &transport {
            Transport::Tcp => (None, 0, None),
            Transport::Shm(opts) => {
                let t_max = opts.resolved_t_max();
                if nw > shm::MAX_SLOTS {
                    (
                        None,
                        0,
                        Some(format!(
                            "{nw} workers exceed {} doorbell slots",
                            shm::MAX_SLOTS
                        )),
                    )
                } else {
                    match ShmSegment::create(n, t_max, nw, opts) {
                        Ok(seg) => (Some(seg), t_max, None),
                        Err(e) => (None, 0, Some(e.to_string())),
                    }
                }
            }
        };
        let inner = Arc::new(MpInner {
            n,
            partition,
            assign,
            kernel_name,
            x,
            budget_mb: budget_mb as u64,
            launch,
            listener,
            addr,
            state: Mutex::new(ProcState {
                workers: (0..nw).map(|_| None).collect(),
                raw: kernel.params(),
                sigma2,
                shut: false,
            }),
            stats: Mutex::new(BackendStats::default()),
            stop: AtomicBool::new(false),
            seg,
            t_max,
            shm_fallback,
            numa_cpus,
            numa_note,
        });
        {
            let mut state = inner.state.lock().unwrap();
            for w in 0..nw {
                inner.boot(&mut state, w)?;
            }
        }
        let monitor = (inner.launch.heartbeat_ms > 0).then(|| {
            let mon = Arc::clone(&inner);
            std::thread::spawn(move || {
                let step = Duration::from_millis(50);
                let mut since_ping = 0u64;
                while !mon.stop.load(Ordering::Relaxed) {
                    std::thread::sleep(step);
                    since_ping += 50;
                    if since_ping >= mon.launch.heartbeat_ms {
                        since_ping = 0;
                        mon.heartbeat();
                    }
                }
            })
        });
        Ok(MultiProcessBackend { inner, monitor })
    }

    /// Worker process count.
    pub fn workers(&self) -> usize {
        self.inner.assign.len()
    }

    /// The listener address workers connect back to.
    pub fn addr(&self) -> &str {
        &self.inner.addr
    }

    /// Kill worker `w`'s process **without** clearing its slot — the next
    /// round (or heartbeat) must *discover* the death and recover. This is
    /// the chaos hook for the crash-mid-solve tests.
    pub fn kill_worker(&self, w: usize) {
        let mut state = self.inner.state.lock().unwrap();
        if let Some(wp) = state.workers[w].as_mut() {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
        }
    }

    /// Synchronously ping every worker, respawning the dead; returns the
    /// live count afterwards.
    pub fn ping_all(&self) -> usize {
        self.inner.heartbeat();
        let state = self.inner.state.lock().unwrap();
        state.workers.iter().filter(|w| w.is_some()).count()
    }

    /// Whether the zero-copy data plane is live: the segment exists and
    /// **every** worker slot is attached to it (a single TCP-lane worker
    /// means rounds still serialize payload for that lane).
    pub fn shm_active(&self) -> bool {
        if self.inner.seg.is_none() {
            return false;
        }
        let state = self.inner.state.lock().unwrap();
        state
            .workers
            .iter()
            .all(|w| matches!(w, Some(wp) if wp.shm))
    }
}

impl ShardBackend for MultiProcessBackend {
    fn describe(&self) -> String {
        let nw = self.workers();
        let shards = self.inner.partition.len();
        let addr = &self.inner.addr;
        match (&self.inner.seg, &self.inner.shm_fallback) {
            (Some(seg), _) => format!(
                "shm:{nw} ({shards} shards @ {addr}; seg {} MB t_max {} @ {}; {})",
                seg.len() >> 20,
                seg.t_max(),
                seg.path().display(),
                self.inner.numa_note
            ),
            (None, Some(why)) => {
                format!("proc:{nw} ({shards} shards @ {addr}; shm unavailable: {why})")
            }
            (None, None) => format!("proc:{nw} ({shards} shards @ {addr})"),
        }
    }

    fn n(&self) -> usize {
        self.inner.n
    }

    fn n_shards(&self) -> usize {
        self.inner.partition.len()
    }

    fn shard_rows(&self, s: usize) -> Range<usize> {
        self.inner.partition[s].clone()
    }

    fn matmul_block(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        self.inner.round(block, m, out);
    }

    fn set_params(&self, raw: &[f64], sigma2: Option<f64>) {
        let mut state = self.inner.state.lock().unwrap();
        state.raw = raw.to_vec();
        if let Some(s2) = sigma2 {
            state.sigma2 = s2;
        }
        let msg = WireMsg::SetParams {
            raw: raw.to_vec(),
            sigma2,
        };
        let mut frame = Vec::new();
        msg.encode(&mut frame).expect("in-memory encode cannot fail");
        let mut ctrl = 0u64;
        // pipelined: all writes first, then one ParamsAck per worker. The
        // acks matter: shm rounds bypass the socket, so without them a
        // posted round could overtake a SetParams still in a socket
        // buffer and contract against stale hyperparameters.
        let mut await_ack = vec![false; state.workers.len()];
        for w in 0..state.workers.len() {
            let sent = match state.workers[w].as_ref() {
                Some(wp) => (&wp.stream).write_all(&frame).is_ok(),
                None => continue,
            };
            if sent {
                await_ack[w] = true;
                ctrl += frame.len() as u64;
            } else {
                // respawn later with the new params via LoadShard replay
                self.inner.drop_slot(&mut state, w);
            }
        }
        for w in 0..state.workers.len() {
            if !await_ack[w] {
                continue;
            }
            let acked = match state.workers[w].as_ref() {
                Some(wp) => {
                    // dedicated short ack deadline (restored afterwards):
                    // a hung worker must not stall the push for the full
                    // product timeout
                    let _ = wp.stream.set_read_timeout(Some(Duration::from_millis(
                        self.inner.launch.params_ack_timeout_ms.max(1),
                    )));
                    let ok = matches!(
                        WireMsg::decode(&mut (&wp.stream)),
                        Ok(WireMsg::ParamsAck)
                    );
                    let _ = wp.stream.set_read_timeout(Some(Duration::from_millis(
                        self.inner.launch.product_timeout_ms,
                    )));
                    ok
                }
                None => continue,
            };
            if acked {
                ctrl += frame_len(&WireMsg::ParamsAck);
            } else {
                self.inner.drop_slot(&mut state, w);
            }
        }
        self.inner.note_ctrl(ctrl);
    }

    fn stats(&self) -> BackendStats {
        *self.inner.stats.lock().unwrap()
    }

    fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.shutdown_workers();
    }
}

impl Drop for MultiProcessBackend {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        self.inner.shutdown_workers();
    }
}
