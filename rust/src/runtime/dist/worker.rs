//! The `bbmm shard-worker` process body.
//!
//! A worker connects back to the driver, greets, and then serves a strict
//! request/response loop: [`WireMsg::LoadShard`] hands it the full inputs
//! X plus the shard ids it owns, [`WireMsg::Matmul`] asks for its owned
//! row-blocks of one kernel product, [`WireMsg::SetParams`] swaps
//! hyperparameters, [`WireMsg::Ping`] answers liveness.
//!
//! Each worker plans its **own** memory: [`MmmPlan::auto_sharded`] decides
//! per owned shard-set, against the per-worker budget from `LoadShard`,
//! whether to hold cached panels (`CachedDistances` keeps the r² rows —
//! hyperparameter updates keep them; `MaterializeK` keeps kernel rows) or
//! stream every product. Aggregate K storage across W workers is therefore
//! sharded W ways — the Wang et al. 2019 memory model. The wrapped
//! operator itself is forced to `Stream` so no full-matrix panel can ever
//! materialise inside a worker.
//!
//! Workers are deliberately stateless beyond `LoadShard`: the driver can
//! kill one at any point and re-derive its blocks on a replacement with
//! bit-identical results (panel fills and contractions mirror
//! `ShardedCovOp::fill_rows` exactly).

use super::contract_panel_rows;
use super::protocol::{ResultBlock, WireMsg, PROTOCOL_VERSION};
use super::shm::{backoff, parse_cpulist, pin_to_cpus, ShmSegment};
use crate::kernels::operator::{stationary_apply, TileFn};
use crate::kernels::{Kernel, Matern12, Matern32, Matern52, Rbf, ShardBlock, ShardedKernelOp};
use crate::linalg::op::MmmPlan;
use crate::tensor::Mat;
use std::io;
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

/// Construct a kernel from its wire name (parameters are overwritten by
/// the `raw` vector that travels with it). Inverse of
/// [`super::kernel_wire_name`].
pub fn kernel_by_name(name: &str) -> Option<Box<dyn Kernel>> {
    match name {
        "rbf" => Some(Box::new(Rbf::new(1.0, 1.0))),
        "matern12" => Some(Box::new(Matern12::new(1.0, 1.0))),
        "matern32" => Some(Box::new(Matern32::new(1.0, 1.0))),
        "matern52" => Some(Box::new(Matern52::new(1.0, 1.0))),
        _ => None,
    }
}

/// One worker's resident state: the operator over full X (plan forced to
/// `Stream`), the owned shard ids, and this worker's own panel plan.
pub struct WorkerState {
    op: ShardedKernelOp,
    owned: Vec<usize>,
    plan: MmmPlan,
    /// per owned shard: kernel rows (plan `MaterializeK`; param-dependent)
    k_panels: Vec<Option<Mat>>,
    /// per owned shard: r² rows (plan `CachedDistances`; parameter-free)
    r2_panels: Vec<Option<Mat>>,
}

impl WorkerState {
    /// Build from the fields of a [`WireMsg::LoadShard`].
    pub fn build(
        x: Mat,
        kernel_name: &str,
        raw: &[f64],
        sigma2: f64,
        n_shards: usize,
        owned: Vec<usize>,
        budget_mb: u64,
    ) -> Result<WorkerState, String> {
        let mut kernel = kernel_by_name(kernel_name)
            .ok_or_else(|| format!("unknown kernel family '{kernel_name}'"))?;
        if raw.len() != kernel.n_params() {
            return Err(format!(
                "kernel '{kernel_name}' expects {} raw params, got {}",
                kernel.n_params(),
                raw.len()
            ));
        }
        kernel.set_params(raw);
        let stationary = kernel.stationary().is_some();
        let n = x.rows();
        let mut op = ShardedKernelOp::new(x, kernel, sigma2, n_shards);
        op.set_plan(MmmPlan::Stream);
        if let Some(&bad) = owned.iter().find(|&&s| s >= op.shard_count()) {
            return Err(format!("owned shard {bad} out of range"));
        }
        let max_len = owned
            .iter()
            .map(|&s| op.shards()[s].len())
            .max()
            .unwrap_or(0);
        let plan = MmmPlan::auto_sharded(
            max_len,
            n,
            stationary,
            (budget_mb as usize).saturating_mul(1024 * 1024),
        );
        let mut st = WorkerState {
            op,
            owned,
            plan,
            k_panels: Vec::new(),
            r2_panels: Vec::new(),
        };
        st.build_panels();
        Ok(st)
    }

    /// This worker's panel plan (its own `auto_sharded` decision).
    pub fn plan(&self) -> MmmPlan {
        self.plan
    }

    /// Total row count of the problem this worker was loaded with.
    pub fn n(&self) -> usize {
        self.op.x().rows()
    }

    fn build_panels(&mut self) {
        let cov = self.op.cov();
        self.k_panels = match self.plan {
            MmmPlan::MaterializeK => self
                .owned
                .iter()
                .map(|&s| Some(cov.shard_panel(s)))
                .collect(),
            _ => vec![None; self.owned.len()],
        };
        if self.r2_panels.is_empty() || self.r2_panels.len() != self.owned.len() {
            // r² is parameter-free: built once, kept across SetParams
            self.r2_panels = match self.plan {
                MmmPlan::CachedDistances => self
                    .owned
                    .iter()
                    .map(|&s| Some(cov.shard_r2_panel(s)))
                    .collect(),
                _ => vec![None; self.owned.len()],
            };
        }
    }

    /// Swap hyperparameters; parameter-dependent panels rebuild, the r²
    /// panels survive.
    pub fn set_params(&mut self, raw: &[f64], sigma2: Option<f64>) {
        let nk = self.op.kernel().n_params();
        assert_eq!(raw.len(), nk);
        let mut full = raw.to_vec();
        let cur = self.op.params();
        full.push(match sigma2 {
            Some(s2) => s2.ln(),
            None => cur[nk],
        });
        self.op.set_params(&full);
        if self.plan == MmmPlan::MaterializeK {
            self.k_panels = self
                .owned
                .iter()
                .map(|&s| Some(self.op.cov().shard_panel(s)))
                .collect();
        }
    }

    /// Compute this worker's owned row-blocks of one product.
    pub fn product(&self, block: &ShardBlock, m: &Mat) -> Vec<ResultBlock> {
        let n = self.op.x().rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        let sp = self.op.kernel().stationary();
        let mut blocks = Vec::with_capacity(self.owned.len());
        let mut krow = vec![0.0f64; n];
        for (i, &s) in self.owned.iter().enumerate() {
            let rows = self.op.shards()[s].clone();
            let mut out = Mat::zeros(rows.len(), t);
            // which fused noise the K-valued panel path should apply, if
            // this request is panel-servable at all (∂/∂log-outputscale of
            // a stationary kernel IS the value tile)
            let panel_noise: Option<Option<f64>> = match block {
                ShardBlock::Value { noise } => Some(*noise),
                ShardBlock::DParam(1) if sp.is_some() => Some(None),
                ShardBlock::DParam(_) => None,
            };
            match (self.plan, panel_noise, &sp) {
                (MmmPlan::MaterializeK, Some(noise), _) => {
                    let panel = self.k_panels[i].as_ref().expect("k panel built");
                    contract_panel_rows(panel.data(), n, m, noise, rows.start, out.data_mut());
                }
                (MmmPlan::CachedDistances, _, Some(sp)) => {
                    let panel = self.r2_panels[i].as_ref().expect("r2 panel built");
                    let (tf, noise) = match block {
                        ShardBlock::Value { noise } => (TileFn::Value, *noise),
                        ShardBlock::DParam(0) => (TileFn::DLogLengthscale, None),
                        ShardBlock::DParam(_) => (TileFn::Value, None),
                    };
                    for (ri, gi) in rows.clone().enumerate() {
                        stationary_apply(sp, tf, panel.row(ri), &mut krow);
                        let orow = &mut out.data_mut()[ri * t..(ri + 1) * t];
                        for (j, &kv) in krow.iter().enumerate() {
                            if kv == 0.0 {
                                continue;
                            }
                            let mrow = m.row(j);
                            for c in 0..t {
                                orow[c] += kv * mrow[c];
                            }
                        }
                        if let Some(s2) = noise {
                            let mrow = m.row(gi);
                            for c in 0..t {
                                orow[c] += s2 * mrow[c];
                            }
                        }
                    }
                }
                _ => {
                    // stream from X: the wrapped op's plan is Stream, so
                    // this is O(row) memory per product
                    self.op.cov().fill_shard(s, m, block, out.data_mut());
                }
            }
            blocks.push(ResultBlock {
                shard: s as u64,
                data: out,
            });
        }
        blocks
    }

    /// Shared-memory variant of [`Self::product`]: compute the owned
    /// row-blocks and place them directly at their global row offsets in
    /// the segment's result region — no serialization, no socket.
    ///
    /// The write is guarded by `seq`: if the segment's sequence moved
    /// while the product computed (the driver gave up on this worker and
    /// posted a newer round, possibly at a different width), nothing is
    /// written and `false` is returned — rows packed at a stale width
    /// must never overlap a newer round's packing.
    pub fn product_into_segment(
        &self,
        seg: &ShmSegment,
        block: &ShardBlock,
        m: &Mat,
        seq: u64,
    ) -> bool {
        let t = m.cols();
        let blocks = self.product(block, m);
        if seg.seq() != seq {
            return false;
        }
        for rb in blocks {
            let row0 = self.op.shards()[rb.shard as usize].start;
            seg.write_result_rows(row0, t, rb.data.data());
        }
        true
    }
}

/// The worker-side shared-memory data plane: poll the round sequence, and
/// for each new round read the descriptor + probe, contract the owned
/// shards straight into the segment, and ring this worker's doorbell.
/// Exits on the control loop's stop flag or the segment's shutdown word.
///
/// `joined` is the sequence already acked at attach time — rounds posted
/// before this worker existed are NOT served here; the driver re-posts
/// the in-flight round under a fresh sequence after a respawn, which is
/// the edge that makes every attached worker (re)compute it.
fn shm_data_plane(
    seg: Arc<ShmSegment>,
    slot: usize,
    joined: u64,
    state: Arc<Mutex<Option<WorkerState>>>,
    stop: Arc<AtomicBool>,
) {
    let mut served = joined;
    let mut step = 0u32;
    loop {
        if stop.load(Ordering::Relaxed) || seg.shutdown_requested() {
            return;
        }
        let seq = seg.seq();
        if seq == served {
            backoff(&mut step);
            continue;
        }
        // A torn descriptor read (driver re-posting while we woke for the
        // previous sequence) is harmless: the segment write below is
        // guarded by a sequence re-check, so a round computed against a
        // superseded descriptor is discarded, never written or acked. An
        // undecodable descriptor just waits for the next post.
        let Ok((block, t)) = seg.round_desc() else {
            backoff(&mut step);
            continue;
        };
        let m = seg.read_probe(t);
        let wrote = {
            let guard = state.lock().unwrap();
            let Some(st) = guard.as_ref() else {
                drop(guard);
                backoff(&mut step);
                continue;
            };
            st.product_into_segment(&seg, &block, &m, seq)
        };
        if wrote {
            served = seq;
            seg.ack(slot, served);
        }
        // !wrote: the sequence moved mid-compute — leave `served` behind
        // so the next pass re-reads the newer round's descriptor
        step = 0;
    }
}

/// Handle [`WireMsg::ShmAttach`]: map + validate the segment, ack the
/// joined sequence (so stale rounds are never mistaken for served ones),
/// and start the data-plane thread. Any `Err` keeps this worker on TCP.
fn attach_segment(
    path: &Path,
    t_max: u64,
    slot: u64,
    state: &Arc<Mutex<Option<WorkerState>>>,
    stop: &Arc<AtomicBool>,
    plane: &mut Option<thread::JoinHandle<()>>,
) -> Result<(), String> {
    if plane.is_some() {
        return Err("already attached to a segment".into());
    }
    let n = match state.lock().unwrap().as_ref() {
        Some(st) => st.n(),
        None => return Err("ShmAttach before LoadShard".into()),
    };
    let seg = ShmSegment::open(path).map_err(|e| e.to_string())?;
    if seg.n() != n {
        return Err(format!("segment rows {} != problem rows {n}", seg.n()));
    }
    if seg.t_max() != t_max as usize {
        return Err(format!("segment t_max {} != attach t_max {t_max}", seg.t_max()));
    }
    let slot = slot as usize;
    if slot >= seg.n_slots() {
        return Err(format!("slot {slot} out of range ({} slots)", seg.n_slots()));
    }
    let joined = seg.seq();
    seg.ack(slot, joined);
    let seg = Arc::new(seg);
    let state = Arc::clone(state);
    let stop = Arc::clone(stop);
    *plane = Some(thread::spawn(move || {
        shm_data_plane(seg, slot, joined, state, stop)
    }));
    Ok(())
}

/// Run the worker protocol loop over a fresh connection to `connect`.
/// Returns when the driver sends [`WireMsg::Shutdown`] or closes the
/// socket (a vanished driver is a normal exit, not an error).
///
/// TCP is the control plane; after a [`WireMsg::ShmAttach`] the Matmul
/// rounds normally arrive through the mapped segment instead (served by a
/// dedicated thread), though TCP Matmul keeps working — the driver uses
/// it for rounds wider than the segment's probe capacity.
pub fn run_worker(connect: &str) -> io::Result<()> {
    let stream = TcpStream::connect(connect)?;
    let _ = stream.set_nodelay(true);
    WireMsg::Hello {
        version: PROTOCOL_VERSION,
        pid: std::process::id(),
    }
    .encode(&mut (&stream))?;
    let state: Arc<Mutex<Option<WorkerState>>> = Arc::new(Mutex::new(None));
    let stop = Arc::new(AtomicBool::new(false));
    let mut plane: Option<thread::JoinHandle<()>> = None;
    let out = control_loop(&stream, &state, &stop, &mut plane);
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = plane {
        let _ = h.join();
    }
    out
}

fn control_loop(
    stream: &TcpStream,
    state: &Arc<Mutex<Option<WorkerState>>>,
    stop: &Arc<AtomicBool>,
    plane: &mut Option<thread::JoinHandle<()>>,
) -> io::Result<()> {
    loop {
        let msg = match WireMsg::decode(&mut (&*stream)) {
            Ok(m) => m,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::UnexpectedEof | io::ErrorKind::ConnectionReset
                ) =>
            {
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        match msg {
            WireMsg::LoadShard {
                x,
                kernel,
                raw,
                sigma2,
                n_shards,
                owned,
                budget_mb,
            } => {
                let owned: Vec<usize> = owned.iter().map(|&s| s as usize).collect();
                match WorkerState::build(
                    x,
                    &kernel,
                    &raw,
                    sigma2,
                    n_shards as usize,
                    owned,
                    budget_mb,
                ) {
                    Ok(st) => *state.lock().unwrap() = Some(st),
                    Err(message) => WireMsg::Err { message }.encode(&mut (&*stream))?,
                }
            }
            WireMsg::SetParams { raw, sigma2 } => {
                // the state lock serialises the swap against in-flight shm
                // rounds; the ack tells the driver the swap landed (the
                // shm plane broke the socket's FIFO guarantee)
                let reply = match state.lock().unwrap().as_mut() {
                    Some(st) => {
                        st.set_params(&raw, sigma2);
                        WireMsg::ParamsAck
                    }
                    None => WireMsg::Err {
                        message: "SetParams before LoadShard".into(),
                    },
                };
                reply.encode(&mut (&*stream))?;
            }
            WireMsg::Matmul { block, m } => {
                let reply = match state.lock().unwrap().as_ref() {
                    Some(st) => WireMsg::MatmulResult {
                        blocks: st.product(&block, &m),
                    },
                    None => WireMsg::Err {
                        message: "Matmul before LoadShard".into(),
                    },
                };
                reply.encode(&mut (&*stream))?;
            }
            WireMsg::ShmAttach { path, t_max, slot } => {
                let reply = match attach_segment(
                    Path::new(&path),
                    t_max,
                    slot,
                    state,
                    stop,
                    plane,
                ) {
                    Ok(()) => WireMsg::ShmReady {
                        ok: true,
                        detail: String::new(),
                    },
                    Err(detail) => WireMsg::ShmReady { ok: false, detail },
                };
                reply.encode(&mut (&*stream))?;
            }
            WireMsg::Ping => WireMsg::Pong.encode(&mut (&*stream))?,
            WireMsg::Shutdown => return Ok(()),
            other => {
                WireMsg::Err {
                    message: format!("unexpected message: {other:?}"),
                }
                .encode(&mut (&*stream))?;
            }
        }
    }
}

/// Self-exec guard for examples/binaries that fork themselves as workers:
/// call first thing in `main`; when the process was invoked as
/// `<exe> shard-worker --connect <addr>` this runs the worker loop and
/// returns `true` (the caller should exit immediately).
pub fn maybe_run_worker() -> bool {
    let args: Vec<String> = std::env::args().collect();
    if args.get(1).map(String::as_str) != Some("shard-worker") {
        return false;
    }
    let addr = args
        .windows(2)
        .find(|w| w[0] == "--connect")
        .map(|w| w[1].clone());
    // NUMA placement: pin before LoadShard so panel pages are
    // first-touched on the pinned node
    if let Some(list) = args.windows(2).find(|w| w[0] == "--pin-cpus").map(|w| &w[1]) {
        let cpus = parse_cpulist(list);
        if !cpus.is_empty() {
            let _ = pin_to_cpus(&cpus);
        }
    }
    match addr {
        Some(addr) => {
            if let Err(e) = run_worker(&addr) {
                eprintln!("shard-worker: {e}");
                std::process::exit(1);
            }
        }
        None => {
            eprintln!("shard-worker: missing --connect <addr>");
            std::process::exit(2);
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DenseKernelOp;
    use crate::linalg::op::LinearOp;
    use crate::util::Rng;

    fn dense_ref(n: usize, seed: u64) -> (Mat, Mat, DenseKernelOp) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(n, 3, |_, _| rng.normal());
        let dense = DenseKernelOp::new(x.clone(), Box::new(Matern32::new(0.6, 1.1)), 0.08);
        (x, m, dense)
    }

    fn assemble(blocks: &[ResultBlock], st: &WorkerState, n: usize, t: usize) -> Mat {
        let mut out = Mat::zeros(n, t);
        for rb in blocks {
            let rows = st.op.shards()[rb.shard as usize].clone();
            out.data_mut()[rows.start * t..rows.end * t].copy_from_slice(rb.data.data());
        }
        out
    }

    #[test]
    fn worker_products_match_dense_across_plans() {
        let n = 48;
        let (x, m, dense) = dense_ref(n, 51);
        let raw = dense.params();
        // budget 0 → Stream; huge budget → CachedDistances (stationary)
        for (budget_mb, want_plan) in [(0u64, MmmPlan::Stream), (1024, MmmPlan::CachedDistances)] {
            // two "workers" covering a 3-shard partition between them
            let build = |owned: Vec<usize>| {
                WorkerState::build(x.clone(), "matern32", &raw[..2], 0.08, 3, owned, budget_mb)
                    .unwrap()
            };
            let a = build(vec![0, 2]);
            let b = build(vec![1]);
            assert_eq!(a.plan(), want_plan);
            for block in [
                ShardBlock::Value { noise: Some(0.08) },
                ShardBlock::Value { noise: None },
                ShardBlock::DParam(0),
                ShardBlock::DParam(1),
            ] {
                let mut blocks = a.product(&block, &m);
                blocks.extend(b.product(&block, &m));
                let got = assemble(&blocks, &a, n, 3);
                let want = match block {
                    ShardBlock::Value { noise: Some(_) } => dense.matmul(&m),
                    ShardBlock::Value { noise: None } => {
                        let mut w = dense.matmul(&m);
                        let mut noise_m = m.clone();
                        noise_m.scale_assign(0.08);
                        w.sub_assign(&noise_m);
                        w
                    }
                    ShardBlock::DParam(p) => dense.dmatmul(p, &m),
                };
                let diff = got.max_abs_diff(&want);
                assert!(diff < 1e-12, "plan {want_plan:?} block {block:?}: {diff}");
            }
        }
    }

    #[test]
    fn worker_set_params_matches_rebuilt_dense() {
        let n = 40;
        let (x, m, dense) = dense_ref(n, 52);
        let raw0 = dense.params();
        let mut st =
            WorkerState::build(x.clone(), "matern32", &raw0[..2], 0.08, 2, vec![0, 1], 1024)
                .unwrap();
        st.set_params(&[-0.4, 0.3], Some(0.02));
        let mut fresh = DenseKernelOp::new(x, Box::new(Matern32::new(0.6, 1.1)), 0.08);
        fresh.set_params(&[-0.4, 0.3, 0.02f64.ln()]);
        let got = assemble(
            &st.product(&ShardBlock::Value { noise: Some(0.02) }, &m),
            &st,
            n,
            3,
        );
        assert!(got.max_abs_diff(&fresh.matmul(&m)) < 1e-12);
    }

    #[test]
    fn products_into_a_segment_match_the_wire_blocks() {
        use super::super::shm::{ShmOptions, ShmSegment};
        let n = 32;
        let (x, m, _) = dense_ref(n, 53);
        let st = WorkerState::build(x, "matern32", &[-0.2, 0.1], 0.05, 3, vec![0, 2], 0).unwrap();
        let seg = ShmSegment::create(n, 4, 1, &ShmOptions::default()).unwrap();
        let block = ShardBlock::Value { noise: Some(0.05) };
        assert!(st.product_into_segment(&seg, &block, &m, seg.seq()));
        let t = m.cols();
        for rb in st.product(&block, &m) {
            let rows = st.op.shards()[rb.shard as usize].clone();
            let mut got = vec![0.0; rows.len() * t];
            seg.read_result_rows(rows, t, &mut got);
            assert_eq!(got, rb.data.data(), "shard {} rows differ", rb.shard);
        }

        // a stale sequence guard (the driver moved on) must write nothing:
        // scribble a sentinel, bump the sequence, retry at the old seq
        let stale = seg.seq();
        let rows0 = st.op.shards()[0].clone();
        let sentinel = vec![12345.0f64; rows0.len() * t];
        seg.write_result_rows(rows0.start, t, &sentinel);
        seg.repost();
        assert!(
            !st.product_into_segment(&seg, &block, &m, stale),
            "a superseded round must be refused"
        );
        let mut after = vec![0.0; sentinel.len()];
        seg.read_result_rows(rows0, t, &mut after);
        assert_eq!(after, sentinel, "stale product must not touch the segment");
    }

    #[test]
    fn build_rejects_bad_configs() {
        let x = Mat::zeros(8, 1);
        assert!(WorkerState::build(x.clone(), "nope", &[0.0, 0.0], 0.1, 2, vec![0], 64).is_err());
        assert!(WorkerState::build(x.clone(), "rbf", &[0.0], 0.1, 2, vec![0], 64).is_err());
        assert!(WorkerState::build(x, "rbf", &[0.0, 0.0], 0.1, 2, vec![7], 64).is_err());
        assert!(kernel_by_name("rbf").is_some());
        assert!(kernel_by_name("linear").is_none());
    }
}
