//! Distributed shard backends: *where* a shard's rows live and execute.
//!
//! The partitioned-kernel mBCG of Wang et al. 2019 (*Exact Gaussian
//! Processes on a Million Data Points*) never holds K in one memory space:
//! each worker owns a row-block, the driver broadcasts the skinny RHS once
//! per iteration and gathers per-shard partial products. This module is
//! that execution layer for the BBMM stack. A [`ShardBackend`] abstracts
//! the placement; [`crate::kernels::ShardedCovOp`] routes its products
//! through an attached backend, so everything above the operator — mBCG,
//! batched solves, training, serving — is backend-agnostic.
//!
//! Three implementations:
//!
//! - [`InProcessBackend`] — today's thread pool (the default). Same
//!   numerics, same memory model, now behind the trait so the solve path
//!   is identical across placements.
//! - [`proc::MultiProcessBackend`] — forked `bbmm shard-worker` processes
//!   speaking the length-prefixed binary protocol of [`protocol`] over
//!   TCP, with heartbeats, restart-on-crash, and deterministic re-dispatch
//!   of a lost worker's shards (recomputing a shard is bit-identical, so a
//!   crash never changes the answer). Its data plane is pluggable
//!   ([`proc::Transport`]): with `Transport::Shm`, same-host workers map a
//!   shared segment ([`shm`]) and a round is "write probe, bump sequence,
//!   wait doorbells" — zero payload bytes on the socket, TCP demoted to
//!   control plane + fallback. NUMA-aware placement ([`shm::NumaMode`])
//!   pins workers round-robin across `/sys/devices/system/node/` nodes.
//! - [`ooc::OutOfCoreBackend`] — checkpointed panels: every shard's kernel
//!   rows are materialised once to disk and streamed back through a small
//!   window per product, so resident K memory is O(window) while keeping
//!   panel-amortised products.
//!
//! Communication is **one round trip per product**: O(n·t) bytes per mBCG
//! iteration, never per tile. [`BackendStats::rounds`] counts them, which
//! the tests use to pin the claim down.

use crate::kernels::{Kernel, ShardBlock, ShardedKernelOp, StationaryFamily};
use crate::tensor::Mat;
use std::ops::Range;
use std::sync::{Mutex, RwLock};

pub mod ooc;
pub mod proc;
pub mod protocol;
pub mod shm;
pub mod worker;

pub use ooc::OutOfCoreBackend;
pub use proc::{MultiProcessBackend, Transport, WorkerLaunch};
pub use shm::{NumaMode, ShmOptions};

/// Traffic and liveness counters a backend accumulates across products.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BackendStats {
    /// broadcast/gather round trips (one per product = one per iteration)
    pub rounds: u64,
    /// rounds served entirely by the shared-memory data plane (no payload
    /// frame written to any socket)
    pub shm_rounds: u64,
    /// payload bytes sent to workers / written to spool (Matmul frames)
    pub bytes_tx: u64,
    /// payload bytes received from workers / read back from spool
    /// (MatmulResult frames)
    pub bytes_rx: u64,
    /// control-plane socket bytes (LoadShard, SetParams + acks, the shm
    /// attach handshake, heartbeats, shutdown) — the O(1)-per-event
    /// traffic that remains when the shm plane carries the payload
    pub ctrl_bytes: u64,
    /// worker processes restarted after a crash or failed heartbeat
    pub restarts: u64,
}

/// Where a shard's rows live and execute.
///
/// One `matmul_block` call is one full product `f(K)·M` over **all** shards
/// — the backend owns the fan-out (threads, worker processes, panel
/// streams) and the gather. Implementations must be deterministic: the
/// same `(params, block, m)` must reproduce the same bits regardless of
/// scheduling, worker count, or crash recovery, which is what keeps every
/// placement 1e-8-comparable (in practice bit-equal) to the in-process
/// reference.
pub trait ShardBackend: Send + Sync {
    /// Human-readable placement summary (for logs and `bbmm serve` output).
    fn describe(&self) -> String;

    /// Total row count n.
    fn n(&self) -> usize;

    /// Shard count of this backend's partition.
    fn n_shards(&self) -> usize;

    /// Row range of shard `s` (contiguous, ordered, covering `0..n`).
    fn shard_rows(&self, s: usize) -> Range<usize>;

    /// Compute `f(K)·M` (`f` selected by `block`) into `out` (`n × t`,
    /// overwritten). This is the one-round-trip-per-iteration seam: `m` is
    /// broadcast whole, per-shard row-blocks are gathered back.
    fn matmul_block(&self, block: &ShardBlock, m: &Mat, out: &mut Mat);

    /// Push new raw kernel parameters (and optionally a new σ²) to wherever
    /// the shards execute, invalidating parameter-dependent panels.
    fn set_params(&self, raw: &[f64], sigma2: Option<f64>);

    /// Snapshot of the traffic/liveness counters.
    fn stats(&self) -> BackendStats;

    /// Release remote/on-disk resources (idempotent; also runs on drop for
    /// the implementations that own any).
    fn shutdown(&self) {}
}

/// Parsed `--backend` CLI spec: `inproc` | `proc:N` | `shm:N` | `ooc:N`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSpec {
    /// thread-pool execution in this process (the default)
    InProcess,
    /// N forked `bbmm shard-worker` processes (TCP data plane)
    MultiProcess {
        /// worker process count (≥ 1)
        workers: usize,
    },
    /// N forked workers with the zero-copy shared-memory data plane
    /// (TCP control plane; automatic TCP fallback if mapping fails)
    Shm {
        /// worker process count (≥ 1)
        workers: usize,
    },
    /// out-of-core checkpointed panels over N shards
    OutOfCore {
        /// spooled shard count (≥ 1)
        shards: usize,
    },
}

impl BackendSpec {
    /// Parse a spec string; errors name the accepted grammar.
    pub fn parse(s: &str) -> Result<BackendSpec, String> {
        let count = |txt: &str, what: &str| -> Result<usize, String> {
            match txt.parse::<usize>() {
                Ok(v) if v >= 1 => Ok(v),
                _ => Err(format!("backend spec '{s}': {what} count must be ≥ 1")),
            }
        };
        if s == "inproc" {
            Ok(BackendSpec::InProcess)
        } else if let Some(w) = s.strip_prefix("proc:") {
            Ok(BackendSpec::MultiProcess {
                workers: count(w, "worker")?,
            })
        } else if let Some(w) = s.strip_prefix("shm:") {
            Ok(BackendSpec::Shm {
                workers: count(w, "worker")?,
            })
        } else if let Some(w) = s.strip_prefix("ooc:") {
            Ok(BackendSpec::OutOfCore {
                shards: count(w, "shard")?,
            })
        } else {
            Err(format!(
                "unknown backend spec '{s}' (expected inproc | proc:N | shm:N | ooc:N)"
            ))
        }
    }
}

impl std::fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendSpec::InProcess => write!(f, "inproc"),
            BackendSpec::MultiProcess { workers } => write!(f, "proc:{workers}"),
            BackendSpec::Shm { workers } => write!(f, "shm:{workers}"),
            BackendSpec::OutOfCore { shards } => write!(f, "ooc:{shards}"),
        }
    }
}

/// Wire name of a kernel family, for [`protocol::WireMsg::LoadShard`].
/// `None` means the kernel is not stationary-encodable and cannot ship to a
/// worker process (use `inproc`/`ooc` for composite kernels).
pub fn kernel_wire_name(kernel: &dyn Kernel) -> Option<&'static str> {
    kernel.stationary().map(|sp| match sp.family {
        StationaryFamily::Rbf => "rbf",
        StationaryFamily::Matern12 => "matern12",
        StationaryFamily::Matern32 => "matern32",
        StationaryFamily::Matern52 => "matern52",
    })
}

/// Contract pre-materialised kernel rows against the broadcast RHS —
/// **the** shared gather kernel. `panel` holds `rows × n` kernel-row
/// values starting at global row `row0`; `out` (`rows × t`) is overwritten.
/// The loop mirrors `ShardedCovOp::fill_rows` exactly (same skip-zero test,
/// same accumulation order, same fused-noise placement), which is what
/// makes panel-based products bit-identical to streamed ones.
pub(crate) fn contract_panel_rows(
    panel: &[f64],
    n: usize,
    m: &Mat,
    noise: Option<f64>,
    row0: usize,
    out: &mut [f64],
) {
    let t = m.cols();
    let rows = panel.len() / n;
    assert_eq!(panel.len(), rows * n);
    assert_eq!(out.len(), rows * t);
    out.fill(0.0);
    for ri in 0..rows {
        let krow = &panel[ri * n..(ri + 1) * n];
        let orow = &mut out[ri * t..(ri + 1) * t];
        for (j, &kv) in krow.iter().enumerate() {
            if kv == 0.0 {
                continue;
            }
            let mrow = m.row(j);
            for c in 0..t {
                orow[c] += kv * mrow[c];
            }
        }
        if let Some(s2) = noise {
            let mrow = m.row(row0 + ri);
            for c in 0..t {
                orow[c] += s2 * mrow[c];
            }
        }
    }
}

/// The default backend: shard products on this process's persistent thread
/// pool. Wraps a [`ShardedKernelOp`] (whose own backend slot must be
/// empty — the wrapped operator is the executor, not a router).
pub struct InProcessBackend {
    op: RwLock<ShardedKernelOp>,
    stats: Mutex<BackendStats>,
}

impl InProcessBackend {
    /// Wrap a sharded operator as the executing backend.
    pub fn new(op: ShardedKernelOp) -> InProcessBackend {
        assert!(
            op.backend().is_none(),
            "InProcessBackend must wrap a backend-less operator"
        );
        InProcessBackend {
            op: RwLock::new(op),
            stats: Mutex::new(BackendStats::default()),
        }
    }
}

impl ShardBackend for InProcessBackend {
    fn describe(&self) -> String {
        let op = self.op.read().unwrap();
        format!(
            "inproc ({} shards, {} threads)",
            op.shard_count(),
            crate::util::par::num_threads()
        )
    }

    fn n(&self) -> usize {
        self.op.read().unwrap().x().rows()
    }

    fn n_shards(&self) -> usize {
        self.op.read().unwrap().shard_count()
    }

    fn shard_rows(&self, s: usize) -> Range<usize> {
        self.op.read().unwrap().shards()[s].clone()
    }

    fn matmul_block(&self, block: &ShardBlock, m: &Mat, out: &mut Mat) {
        let op = self.op.read().unwrap();
        op.cov().block_matmul_into(m, *block, out);
        let moved = (m.data().len() + out.data().len()) as u64 * 8;
        let mut st = self.stats.lock().unwrap();
        st.rounds += 1;
        st.bytes_tx += moved / 2;
        st.bytes_rx += moved / 2;
    }

    fn set_params(&self, raw: &[f64], sigma2: Option<f64>) {
        let mut op = self.op.write().unwrap();
        let nk = op.kernel().n_params();
        assert_eq!(raw.len(), nk);
        let mut full = raw.to_vec();
        let cur = op.params();
        full.push(match sigma2 {
            Some(s2) => s2.ln(),
            None => cur[nk],
        });
        op.set_params(&full);
    }

    fn stats(&self) -> BackendStats {
        *self.stats.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, ProductKernel, Rbf, ShardedCovOp};
    use crate::linalg::op::LinearOp;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn backend_spec_parses_and_prints() {
        assert_eq!(BackendSpec::parse("inproc").unwrap(), BackendSpec::InProcess);
        assert_eq!(
            BackendSpec::parse("proc:4").unwrap(),
            BackendSpec::MultiProcess { workers: 4 }
        );
        assert_eq!(
            BackendSpec::parse("shm:3").unwrap(),
            BackendSpec::Shm { workers: 3 }
        );
        assert_eq!(
            BackendSpec::parse("ooc:2").unwrap(),
            BackendSpec::OutOfCore { shards: 2 }
        );
        for bad in ["", "proc", "proc:0", "proc:x", "shm", "shm:0", "ooc:", "threads:2"] {
            assert!(BackendSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
        assert_eq!(BackendSpec::MultiProcess { workers: 2 }.to_string(), "proc:2");
        assert_eq!(BackendSpec::Shm { workers: 4 }.to_string(), "shm:4");
        assert_eq!(
            BackendSpec::parse(&BackendSpec::Shm { workers: 4 }.to_string()).unwrap(),
            BackendSpec::Shm { workers: 4 }
        );
        assert_eq!(
            BackendSpec::parse(&BackendSpec::OutOfCore { shards: 3 }.to_string()).unwrap(),
            BackendSpec::OutOfCore { shards: 3 }
        );
    }

    #[test]
    fn kernel_wire_names_cover_the_stationary_families() {
        assert_eq!(kernel_wire_name(&Rbf::new(0.5, 1.0)), Some("rbf"));
        let composite = ProductKernel::new(
            Box::new(Rbf::new(0.5, 1.0)),
            Box::new(Rbf::new(0.9, 1.0)),
        );
        assert_eq!(kernel_wire_name(&composite), None);
    }

    #[test]
    fn inprocess_backend_routes_products_bit_identically() {
        let mut rng = Rng::new(31);
        let x = Mat::from_fn(64, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(64, 5, |_, _| rng.normal());
        let dense = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.1)), 0.05);

        let backend = Arc::new(InProcessBackend::new(ShardedKernelOp::new(
            x.clone(),
            Box::new(Rbf::new(0.6, 1.1)),
            0.05,
            4,
        )));
        assert_eq!(backend.n(), 64);
        assert_eq!(backend.n_shards(), 4);
        assert_eq!(backend.shard_rows(0).start, 0);

        // a routed ShardedCovOp (noise-free part) vs the plain sharded op
        let routed = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.1)), 4)
            .with_backend(backend.clone());
        let plain = ShardedCovOp::new(x, Box::new(Rbf::new(0.6, 1.1)), 4);
        assert_eq!(
            routed.matmul(&m).max_abs_diff(&plain.matmul(&m)),
            0.0,
            "backend routing changed bits"
        );
        assert_eq!(routed.dmatmul(0, &m).max_abs_diff(&plain.dmatmul(0, &m)), 0.0);

        // fused-noise product matches the dense training operator
        let mut khat = Mat::zeros(64, 5);
        backend.matmul_block(&ShardBlock::Value { noise: Some(0.05) }, &m, &mut khat);
        assert!(khat.max_abs_diff(&dense.matmul(&m)) < 1e-12);

        // every product was one round trip
        assert_eq!(backend.stats().rounds, 3);
        assert!(backend.describe().starts_with("inproc"));
    }

    #[test]
    fn inprocess_set_params_tracks_the_dense_operator() {
        let mut rng = Rng::new(33);
        let x = Mat::from_fn(40, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(40, 3, |_, _| rng.normal());
        let backend = InProcessBackend::new(ShardedKernelOp::new(
            x.clone(),
            Box::new(Rbf::new(0.6, 1.1)),
            0.05,
            3,
        ));
        let raw = vec![-0.2, 0.3];
        backend.set_params(&raw, Some(0.02));
        let mut dense = DenseKernelOp::new(x, Box::new(Rbf::new(0.6, 1.1)), 0.05);
        dense.set_params(&[raw[0], raw[1], 0.02f64.ln()]);
        let mut got = Mat::zeros(40, 3);
        backend.matmul_block(&ShardBlock::Value { noise: Some(0.02) }, &m, &mut got);
        assert!(got.max_abs_diff(&dense.matmul(&m)) < 1e-12);
    }
}
