//! PJRT runtime — the L3↔L2 bridge of the three-layer architecture.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas BBMM graphs to **HLO text**
//! (text, not serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids). This module
//! loads those artifacts, compiles them once on the PJRT CPU client, caches
//! the executables, and runs them from the Rust hot path. Python is never
//! on the request path.

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A named, compiled artifact registry over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    artifact_dir: PathBuf,
}

/// Shape + data of one f32 input tensor.
pub struct TensorF32<'a> {
    pub data: &'a [f32],
    pub dims: Vec<i64>,
}

impl Runtime {
    /// Create a CPU-backed runtime rooted at `artifact_dir`.
    pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            executables: HashMap::new(),
            artifact_dir: artifact_dir.into(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt` under key `name`
    /// (no-op if already loaded).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.executables.keys().cloned().collect();
        v.sort();
        v
    }

    /// List artifacts available on disk (without loading them).
    pub fn available(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.artifact_dir) {
            for e in rd.flatten() {
                if let Some(fname) = e.file_name().to_str() {
                    if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    /// Execute artifact `name` with f32 inputs, returning all f32 outputs
    /// (the jax lowering uses `return_tuple=True`, so the single result is
    /// a tuple we decompose).
    pub fn execute_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = xla::Literal::vec1(inp.data)
                .reshape(&inp.dims)
                .map_err(|e| anyhow!("reshape input: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e:?}"))?,
            );
        }
        Ok(outputs)
    }

    /// Convenience: check an artifact exists on disk.
    pub fn artifact_exists(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }
}

/// Locate the repo's artifact directory: $BBMM_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("BBMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

// NOTE: runtime integration tests live in rust/tests/runtime_artifacts.rs —
// they require `make artifacts` to have produced the HLO files and are
// skipped (with a notice) when the artifacts are absent.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_initialises() {
        let rt = Runtime::cpu("artifacts").unwrap();
        assert!(!rt.platform().is_empty());
        assert!(!rt.is_loaded("nope"));
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let mut rt = Runtime::cpu("/nonexistent_dir_xyz").unwrap();
        assert!(rt.load("missing").is_err());
        assert!(rt.execute_f32("missing", &[]).is_err());
        assert!(rt.available().is_empty());
    }
}
