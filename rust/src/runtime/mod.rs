//! Runtime substrate: the shard scheduler plus the PJRT artifact bridge.
//!
//! Two very different "runtimes" live here:
//!
//! - [`shard`] — the in-process scheduler (static striping + work stealing
//!   over row shards) that backs [`crate::kernels::ShardedKernelOp`].
//! - [`dist`] — the distributed shard layer: a [`dist::ShardBackend`]
//!   trait saying *where* a shard's rows live and execute, with in-process,
//!   multi-process (forked `bbmm shard-worker` children over a
//!   length-prefixed TCP protocol) and out-of-core (checkpointed panel)
//!   implementations.
//! - [`Runtime`] — the L3↔L2 bridge of the three-layer architecture.
//!   `python/compile/aot.py` lowers the JAX/Pallas BBMM graphs to **HLO
//!   text** (text, not serialized proto: jax ≥ 0.5 emits 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!   The runtime loads those artifacts, compiles them once on the PJRT CPU
//!   client, caches the executables, and runs them from the Rust hot path.
//!   Python is never on the request path.
//!
//! The PJRT client needs the vendored `xla` crate, which the offline build
//! environment does not ship — so the xla-backed implementation lives
//! behind the `pjrt` cargo feature (`src/runtime/pjrt.rs`) and the default
//! build provides a stub with the same API: artifact *discovery* on disk
//! works everywhere, while `load`/`execute_f32` fail cleanly and
//! [`Runtime::backend_available`] reports `false` so callers can skip.

pub mod dist;
pub mod shard;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use std::path::{Path, PathBuf};

/// Runtime error type (the offline crate set has no `anyhow`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shape + data of one f32 input tensor.
pub struct TensorF32<'a> {
    pub data: &'a [f32],
    pub dims: Vec<i64>,
}

/// Locate the repo's artifact directory: $BBMM_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("BBMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// List `<name>.hlo.txt` artifact stems in a directory (shared by the stub
/// and the pjrt backend; missing directories read as empty).
pub(crate) fn scan_artifacts(dir: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if let Some(fname) = e.file_name().to_str() {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
    }
    names.sort();
    names
}

// NOTE: runtime integration tests live in rust/tests/runtime_artifacts.rs —
// they require `make artifacts` plus the `pjrt` feature and are skipped
// (with a notice) otherwise.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_initialises() {
        let rt = Runtime::cpu("artifacts").unwrap();
        assert!(!rt.platform().is_empty());
        assert!(!rt.is_loaded("nope"));
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let mut rt = Runtime::cpu("/nonexistent_dir_xyz").unwrap();
        assert!(rt.load("missing").is_err());
        assert!(rt.execute_f32("missing", &[]).is_err());
        assert!(rt.available().is_empty());
        assert!(!rt.artifact_exists("missing"));
    }
}
