//! Stub artifact runtime (default build, `pjrt` feature disabled).
//!
//! Mirrors the API of the pjrt backend (`runtime/pjrt.rs`) so callers
//! compile unchanged:
//! artifact discovery on disk works, but loading/executing reports a clean
//! error and [`Runtime::backend_available`] returns `false` so tests and
//! CLIs can skip the PJRT path instead of failing.

use super::{scan_artifacts, Result, RuntimeError, TensorF32};
use std::path::{Path, PathBuf};

/// A named artifact registry with no execution backend.
pub struct Runtime {
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a runtime rooted at `artifact_dir` (always succeeds — there
    /// is no client to initialise).
    pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Runtime {
            artifact_dir: artifact_dir.into(),
        })
    }

    /// Whether compiled-artifact execution is possible in this build.
    pub fn backend_available(&self) -> bool {
        false
    }

    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Always fails: there is no PJRT client to compile with.
    pub fn load(&mut self, name: &str) -> Result<()> {
        Err(RuntimeError::new(format!(
            "cannot load artifact {name:?}: this build has no PJRT backend \
             (enable the `pjrt` cargo feature with a vendored `xla` crate)"
        )))
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn loaded_names(&self) -> Vec<String> {
        Vec::new()
    }

    /// List artifacts available on disk (without loading them).
    pub fn available(&self) -> Vec<String> {
        scan_artifacts(&self.artifact_dir)
    }

    /// Always fails: see [`Runtime::load`].
    pub fn execute_f32(&self, name: &str, _inputs: &[TensorF32]) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError::new(format!(
            "cannot execute artifact {name:?}: this build has no PJRT backend"
        )))
    }

    /// Check an artifact exists on disk.
    pub fn artifact_exists(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }
}
