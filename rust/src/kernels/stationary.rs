//! Stationary kernels: RBF and the Matérn family (paper §3, §6 — the
//! experiments use RBF and Matérn-5/2).
//!
//! All kernels carry an outputscale `s = exp(raw_os)` and lengthscale
//! `ℓ = exp(raw_ls)`; derivatives are with respect to the raw (log) values,
//! which is what Adam optimises.

use super::{Kernel, StationaryFamily, StationaryParams};

#[inline]
fn sq_dist(x1: &[f64], x2: &[f64]) -> f64 {
    debug_assert_eq!(x1.len(), x2.len());
    let mut s = 0.0;
    for i in 0..x1.len() {
        let d = x1[i] - x2[i];
        s += d * d;
    }
    s
}

/// RBF (squared-exponential): `k = s · exp(−r² / 2ℓ²)`.
#[derive(Clone, Debug)]
pub struct Rbf {
    pub raw_ls: f64,
    pub raw_os: f64,
}

impl Rbf {
    pub fn new(lengthscale: f64, outputscale: f64) -> Self {
        Rbf {
            raw_ls: lengthscale.ln(),
            raw_os: outputscale.ln(),
        }
    }

    #[inline]
    pub fn lengthscale(&self) -> f64 {
        self.raw_ls.exp()
    }
    #[inline]
    pub fn outputscale(&self) -> f64 {
        self.raw_os.exp()
    }
}

impl Kernel for Rbf {
    fn n_params(&self) -> usize {
        2
    }
    fn params(&self) -> Vec<f64> {
        vec![self.raw_ls, self.raw_os]
    }
    fn set_params(&mut self, raw: &[f64]) {
        self.raw_ls = raw[0];
        self.raw_os = raw[1];
    }
    fn param_names(&self) -> Vec<String> {
        vec!["log_lengthscale".into(), "log_outputscale".into()]
    }

    #[inline]
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let r2 = sq_dist(x1, x2);
        let ls = self.lengthscale();
        self.outputscale() * (-r2 / (2.0 * ls * ls)).exp()
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let r2 = sq_dist(x1, x2);
        let ls = self.lengthscale();
        let k = self.outputscale() * (-r2 / (2.0 * ls * ls)).exp();
        // ∂k/∂raw_ls = k · r²/ℓ²   (chain rule through ℓ = e^{raw})
        out[0] = k * r2 / (ls * ls);
        // ∂k/∂raw_os = k
        out[1] = k;
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }

    fn stationary(&self) -> Option<StationaryParams> {
        Some(StationaryParams {
            family: StationaryFamily::Rbf,
            lengthscale: self.raw_ls.exp(),
            outputscale: self.raw_os.exp(),
        })
    }
}

/// Matérn-1/2 (exponential): `k = s · exp(−r/ℓ)`.
#[derive(Clone, Debug)]
pub struct Matern12 {
    pub raw_ls: f64,
    pub raw_os: f64,
}

impl Matern12 {
    pub fn new(lengthscale: f64, outputscale: f64) -> Self {
        Matern12 {
            raw_ls: lengthscale.ln(),
            raw_os: outputscale.ln(),
        }
    }
}

impl Kernel for Matern12 {
    fn n_params(&self) -> usize {
        2
    }
    fn params(&self) -> Vec<f64> {
        vec![self.raw_ls, self.raw_os]
    }
    fn set_params(&mut self, raw: &[f64]) {
        self.raw_ls = raw[0];
        self.raw_os = raw[1];
    }
    fn param_names(&self) -> Vec<String> {
        vec!["log_lengthscale".into(), "log_outputscale".into()]
    }

    #[inline]
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let r = sq_dist(x1, x2).sqrt();
        let ls = self.raw_ls.exp();
        self.raw_os.exp() * (-r / ls).exp()
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let r = sq_dist(x1, x2).sqrt();
        let ls = self.raw_ls.exp();
        let k = self.raw_os.exp() * (-r / ls).exp();
        out[0] = k * r / ls; // d/draw_ls
        out[1] = k;
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }

    fn stationary(&self) -> Option<StationaryParams> {
        Some(StationaryParams {
            family: StationaryFamily::Matern12,
            lengthscale: self.raw_ls.exp(),
            outputscale: self.raw_os.exp(),
        })
    }
}

/// Matérn-3/2: `k = s (1 + √3 r/ℓ) exp(−√3 r/ℓ)`.
#[derive(Clone, Debug)]
pub struct Matern32 {
    pub raw_ls: f64,
    pub raw_os: f64,
}

impl Matern32 {
    pub fn new(lengthscale: f64, outputscale: f64) -> Self {
        Matern32 {
            raw_ls: lengthscale.ln(),
            raw_os: outputscale.ln(),
        }
    }
}

impl Kernel for Matern32 {
    fn n_params(&self) -> usize {
        2
    }
    fn params(&self) -> Vec<f64> {
        vec![self.raw_ls, self.raw_os]
    }
    fn set_params(&mut self, raw: &[f64]) {
        self.raw_ls = raw[0];
        self.raw_os = raw[1];
    }
    fn param_names(&self) -> Vec<String> {
        vec!["log_lengthscale".into(), "log_outputscale".into()]
    }

    #[inline]
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let r = sq_dist(x1, x2).sqrt();
        let u = 3f64.sqrt() * r / self.raw_ls.exp();
        self.raw_os.exp() * (1.0 + u) * (-u).exp()
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let r = sq_dist(x1, x2).sqrt();
        let s = self.raw_os.exp();
        let u = 3f64.sqrt() * r / self.raw_ls.exp();
        let e = (-u).exp();
        // k = s (1+u) e^{-u}; du/draw_ls = −u ⇒ dk/draw_ls = s u² e^{-u}
        out[0] = s * u * u * e;
        out[1] = s * (1.0 + u) * e;
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }

    fn stationary(&self) -> Option<StationaryParams> {
        Some(StationaryParams {
            family: StationaryFamily::Matern32,
            lengthscale: self.raw_ls.exp(),
            outputscale: self.raw_os.exp(),
        })
    }
}

/// Matérn-5/2: `k = s (1 + √5 r/ℓ + 5r²/3ℓ²) exp(−√5 r/ℓ)`.
#[derive(Clone, Debug)]
pub struct Matern52 {
    pub raw_ls: f64,
    pub raw_os: f64,
}

impl Matern52 {
    pub fn new(lengthscale: f64, outputscale: f64) -> Self {
        Matern52 {
            raw_ls: lengthscale.ln(),
            raw_os: outputscale.ln(),
        }
    }
}

impl Kernel for Matern52 {
    fn n_params(&self) -> usize {
        2
    }
    fn params(&self) -> Vec<f64> {
        vec![self.raw_ls, self.raw_os]
    }
    fn set_params(&mut self, raw: &[f64]) {
        self.raw_ls = raw[0];
        self.raw_os = raw[1];
    }
    fn param_names(&self) -> Vec<String> {
        vec!["log_lengthscale".into(), "log_outputscale".into()]
    }

    #[inline]
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let r = sq_dist(x1, x2).sqrt();
        let u = 5f64.sqrt() * r / self.raw_ls.exp();
        self.raw_os.exp() * (1.0 + u + u * u / 3.0) * (-u).exp()
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let r = sq_dist(x1, x2).sqrt();
        let s = self.raw_os.exp();
        let u = 5f64.sqrt() * r / self.raw_ls.exp();
        let e = (-u).exp();
        // k = s g(u) e^{-u}, g = 1 + u + u²/3; du/draw_ls = −u
        // dk/draw_ls = s e^{-u} (−u·g′(u) + u·g(u)) = s e^{-u} u²(1 + u)/3
        out[0] = s * e * u * u * (1.0 + u) / 3.0;
        out[1] = s * (1.0 + u + u * u / 3.0) * e;
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }

    fn stationary(&self) -> Option<StationaryParams> {
        Some(StationaryParams {
            family: StationaryFamily::Matern52,
            lengthscale: self.raw_ls.exp(),
            outputscale: self.raw_os.exp(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::check_kernel_gradients;

    #[test]
    fn rbf_values() {
        let k = Rbf::new(1.0, 2.0);
        assert!((k.eval(&[0.0], &[0.0]) - 2.0).abs() < 1e-14);
        let v = k.eval(&[0.0], &[1.0]);
        assert!((v - 2.0 * (-0.5f64).exp()).abs() < 1e-14);
    }

    #[test]
    fn matern_at_zero_equals_outputscale() {
        for k in [
            Box::new(Matern12::new(0.7, 1.3)) as Box<dyn Kernel>,
            Box::new(Matern32::new(0.7, 1.3)),
            Box::new(Matern52::new(0.7, 1.3)),
        ] {
            assert!((k.eval(&[0.2, 0.5], &[0.2, 0.5]) - 1.3).abs() < 1e-14);
        }
    }

    #[test]
    fn kernels_decay_with_distance() {
        let kernels: Vec<Box<dyn Kernel>> = vec![
            Box::new(Rbf::new(0.5, 1.0)),
            Box::new(Matern12::new(0.5, 1.0)),
            Box::new(Matern32::new(0.5, 1.0)),
            Box::new(Matern52::new(0.5, 1.0)),
        ];
        for k in &kernels {
            let mut prev = f64::INFINITY;
            for i in 0..10 {
                let x2 = [i as f64 * 0.3];
                let v = k.eval(&[0.0], &x2);
                assert!(v <= prev + 1e-15);
                assert!(v > 0.0);
                prev = v;
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let x1 = [0.3, -0.2, 0.9];
        let x2 = [-0.1, 0.4, 0.5];
        let mut rbf = Rbf::new(0.8, 1.5);
        check_kernel_gradients(&mut rbf, &x1, &x2, 1e-5);
        let mut m12 = Matern12::new(0.8, 1.5);
        check_kernel_gradients(&mut m12, &x1, &x2, 1e-5);
        let mut m32 = Matern32::new(0.8, 1.5);
        check_kernel_gradients(&mut m32, &x1, &x2, 1e-5);
        let mut m52 = Matern52::new(0.8, 1.5);
        check_kernel_gradients(&mut m52, &x1, &x2, 1e-5);
    }

    #[test]
    fn param_roundtrip() {
        let mut k = Rbf::new(2.0, 3.0);
        let p = k.params();
        assert!((p[0] - 2.0f64.ln()).abs() < 1e-15);
        k.set_params(&[0.0, 0.0]);
        assert!((k.lengthscale() - 1.0).abs() < 1e-15);
        assert_eq!(k.param_names().len(), 2);
    }

    #[test]
    fn symmetry() {
        let k = Matern52::new(0.6, 1.1);
        let a = [0.1, 0.9];
        let b = [0.7, 0.2];
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
    }
}
