//! Deep kernel learning feature extractor (Wilson et al. [52], used in the
//! paper's SKI+DKL experiments, §6).
//!
//! A small MLP `φ: ℝᵈ → ℝᵠ` maps inputs into a learned feature space; a
//! base kernel is then applied to the features: `k(x, x′) = k_base(φ(x),
//! φ(x′))`. The paper's SKI experiments use a deep kernel whose final layer
//! is low-dimensional so `K_UU` can live on a dense inducing grid — our SKI
//! path uses q = 1 (a 1-D grid ⇒ Toeplitz `K_UU`), matching [52]'s
//! "DKL + KISS-GP" configuration.

use crate::tensor::Mat;
use crate::util::Rng;

/// Fully-connected MLP with tanh activations (linear final layer).
#[derive(Clone)]
pub struct DeepFeatureMap {
    /// weight matrices, layer l maps dims[l] → dims[l+1]
    weights: Vec<Mat>,
    biases: Vec<Vec<f64>>,
    dims: Vec<usize>,
}

impl DeepFeatureMap {
    /// Xavier-initialised MLP with the given layer widths
    /// (e.g. `[d, 32, 16, 1]`).
    pub fn new(dims: &[usize], rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..dims.len() - 1 {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            let scale = (2.0 / (fan_in + fan_out) as f64).sqrt();
            weights.push(Mat::from_fn(fan_in, fan_out, |_, _| rng.normal() * scale));
            biases.push(vec![0.0; fan_out]);
        }
        DeepFeatureMap {
            weights,
            biases,
            dims: dims.to_vec(),
        }
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Forward-map a batch of inputs `X (n×d) → Φ (n×q)`.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.input_dim());
        let mut h = x.clone();
        let last = self.weights.len() - 1;
        for (l, w) in self.weights.iter().enumerate() {
            let mut z = h.matmul(w);
            for r in 0..z.rows() {
                let row = z.row_mut(r);
                for (c, v) in row.iter_mut().enumerate() {
                    *v += self.biases[l][c];
                    if l != last {
                        *v = v.tanh();
                    }
                }
            }
            h = z;
        }
        h
    }

    /// Flatten all weights+biases (for counting / checkpointing).
    pub fn parameters(&self) -> Vec<f64> {
        let mut p = Vec::new();
        for (w, b) in self.weights.iter().zip(self.biases.iter()) {
            p.extend_from_slice(w.data());
            p.extend_from_slice(b);
        }
        p
    }

    /// Load parameters from a flat vector (inverse of [`Self::parameters`]).
    pub fn set_parameters(&mut self, flat: &[f64]) {
        let mut off = 0;
        for (w, b) in self.weights.iter_mut().zip(self.biases.iter_mut()) {
            let wn = w.rows() * w.cols();
            w.data_mut().copy_from_slice(&flat[off..off + wn]);
            off += wn;
            let blen = b.len();
            b.copy_from_slice(&flat[off..off + blen]);
            off += blen;
        }
        assert_eq!(off, flat.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let map = DeepFeatureMap::new(&[5, 16, 8, 2], &mut rng);
        let x = Mat::from_fn(10, 5, |_, _| rng.normal());
        let phi = map.forward(&x);
        assert_eq!(phi.shape(), (10, 2));
        assert_eq!(map.output_dim(), 2);
        assert_eq!(map.n_layers(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let m1 = DeepFeatureMap::new(&[3, 8, 1], &mut r1);
        let m2 = DeepFeatureMap::new(&[3, 8, 1], &mut r2);
        let x = Mat::from_fn(4, 3, |r, c| (r * 3 + c) as f64 * 0.1);
        assert!(m1.forward(&x).max_abs_diff(&m2.forward(&x)) == 0.0);
    }

    #[test]
    fn hidden_activations_bounded_final_linear() {
        // tanh hidden layers keep intermediate magnitudes ≤ 1; final layer
        // is linear so outputs can exceed 1 — spot-check continuity instead:
        // nearby inputs map to nearby features
        let mut rng = Rng::new(3);
        let map = DeepFeatureMap::new(&[2, 16, 1], &mut rng);
        let a = Mat::from_vec(1, 2, vec![0.5, -0.2]);
        let b = Mat::from_vec(1, 2, vec![0.5001, -0.2001]);
        let fa = map.forward(&a);
        let fb = map.forward(&b);
        assert!((fa.get(0, 0) - fb.get(0, 0)).abs() < 1e-2);
    }

    #[test]
    fn parameter_roundtrip() {
        let mut rng = Rng::new(4);
        let mut map = DeepFeatureMap::new(&[3, 5, 2], &mut rng);
        let p = map.parameters();
        assert_eq!(p.len(), 3 * 5 + 5 + 5 * 2 + 2);
        let mut p2 = p.clone();
        p2[0] = 42.0;
        map.set_parameters(&p2);
        assert_eq!(map.parameters()[0], 42.0);
    }
}
