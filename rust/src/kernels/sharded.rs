//! Sharded exact-GP covariance: `K(X,X)·M` as `S` row-shards, composed
//! with [`AddedDiagOp`] into the training operator `K̂ = K + σ²I`.
//!
//! [`super::KernelCovOp`] fuses tile generation with the mat-mul but
//! still walks the whole operator in one monolithic parallel-for per mBCG
//! iteration. Following Wang et al. 2019 (*Exact Gaussian Processes on a
//! Million Data Points*, 1903.08114), [`ShardedCovOp`] partitions the
//! training rows into `S` contiguous shards instead. Each shard owns the
//! tile work-queue for its row-block, scheduled by
//! [`crate::runtime::shard`] (static striping + work stealing), and the
//! composed [`ShardedKernelOp`] also exposes each block as a standalone
//! partial product through [`crate::linalg::mbcg::ShardedMmm`] so the
//! solver can assemble `K̂·M` shard by shard — the seam along which shards
//! later map 1:1 onto devices or processes.
//!
//! Numerics are identical to the dense operator (same distance expansion,
//! same summation order), and kernel rows are still produced on the fly,
//! so peak memory stays O(n·t + tile·n) — no n×n matrix is ever formed.

use super::operator::{
    cross_kernel, squared_dists_row, stationary_apply, stationary_apply_f32, TileFn,
};
use super::{Kernel, KernelCov};
use crate::linalg::mbcg::ShardedMmm;
use crate::linalg::op::{mmm, AddedDiagOp, LinearOp, MmmPlan, Precision};
use crate::runtime::dist::ShardBackend;
use crate::runtime::shard::{partition_rows, run_rows_mut, ShardQueue};
use crate::tensor::{Mat, Scalar};
use crate::util::par;
use std::ops::Range;
use std::sync::{Arc, OnceLock, RwLock};

/// Rows per scheduled tile inside a shard (matches the dense operator's
/// cache tile: 64 rows × n cols of f64 stays in L2 for n up to ~8k).
pub const DEFAULT_TILE: usize = 64;

/// Which kernel function a block fill evaluates — the unit of work a shard
/// backend ([`crate::runtime::dist::ShardBackend`]) dispatches, so it is
/// public and wire-encodable (`runtime/dist/protocol.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShardBlock {
    /// `K·M`, optionally plus `σ²M` fused into the shard pass
    Value {
        /// fused added-diagonal term (`None` = noise-free covariance)
        noise: Option<f64>,
    },
    /// `(∂K/∂raw_p)·M` for a kernel parameter `p` (noise handled upstream)
    DParam(usize),
}

/// Former internal name, kept as an alias so the fill paths read unchanged.
type BlockFn = ShardBlock;

/// Noise-free exact covariance over `X (n×d)` partitioned into row shards.
///
/// Consumes the same [`MmmPlan`] as the dense operator: under
/// `CachedDistances` every shard's value/derivative rows derive from one
/// cached r² panel; under `MaterializeK` value rows are read straight from
/// the materialised K; `Stream` rebuilds rows per product (the seed path).
pub struct ShardedCovOp {
    x: Mat,
    kernel: Box<dyn Kernel>,
    /// contiguous, ordered row ranges covering `0..n`
    shards: Vec<Range<usize>>,
    /// rows per scheduled tile within a shard
    tile: usize,
    /// cached Xᵀ (d×n): the distance pass streams over j
    xt: Mat,
    /// cached per-row squared norms |xᵢ|²
    xnorm: Vec<f64>,
    /// how products materialise (fingerprinted via `mmm_tag`)
    plan: MmmPlan,
    /// tile-compute precision (fingerprinted via `mmm_tag`): under
    /// [`Precision::Mixed`] stationary kernel rows are evaluated in f32
    /// (vectorised exp at twice the lane width) and widened once, while
    /// the contraction against M stays in f64 — distances, derivative
    /// epilogue math, and the fused σ²M term are untouched
    precision: Precision,
    /// cached r² panel (parameter-free)
    r2: Arc<OnceLock<Mat>>,
    /// materialised K for the current parameters (cleared on update)
    kmat: RwLock<Option<Arc<Mat>>>,
    /// where shard products execute: `None` = this process's thread pool
    /// (the seed behaviour); `Some` routes every f64 product through a
    /// [`ShardBackend`] (worker processes / out-of-core panels)
    backend: Option<Arc<dyn ShardBackend>>,
}

impl ShardedCovOp {
    /// Build over `n_shards` row shards (clamped to `1..=n`); the plan is
    /// chosen automatically from the [`mmm::budget_bytes`] budget.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, n_shards: usize) -> Self {
        let n = x.rows();
        let shards = partition_rows(n, n_shards);
        let xt = x.transpose();
        let xnorm: Vec<f64> = (0..n)
            .map(|i| x.row(i).iter().map(|v| v * v).sum())
            .collect();
        let plan = MmmPlan::auto(n, kernel.stationary().is_some(), mmm::budget_bytes());
        ShardedCovOp {
            x,
            kernel,
            shards,
            tile: DEFAULT_TILE,
            xt,
            xnorm,
            plan,
            precision: mmm::default_precision(),
            r2: Arc::new(OnceLock::new()),
            kmat: RwLock::new(None),
            backend: None,
        }
    }

    /// Builder form of [`ShardedCovOp::set_backend`].
    pub fn with_backend(mut self, backend: Arc<dyn ShardBackend>) -> Self {
        self.set_backend(backend);
        self
    }

    /// Route every f64 product (`matmul` / `matmul_into` / `dmatmul`)
    /// through `backend` instead of the local thread pool. The backend must
    /// cover the same `n` rows; its shard plan may differ from this
    /// operator's (it owns its own partition). Kernel-parameter updates are
    /// forwarded via [`ShardBackend::set_params`]. `prepare()` becomes a
    /// no-op locally — the backend's workers hold the materialised state.
    pub fn set_backend(&mut self, backend: Arc<dyn ShardBackend>) {
        assert_eq!(
            backend.n(),
            self.x.rows(),
            "backend covers a different row count"
        );
        self.backend = Some(backend);
    }

    /// The attached shard backend, if any.
    pub fn backend(&self) -> Option<&Arc<dyn ShardBackend>> {
        self.backend.as_ref()
    }

    // Plan/panel plumbing below: KEEP IN SYNC with `KernelCovOp`
    // (operator.rs) — same invalidation rules (kmat cleared on parameter
    // or plan change, r² parameter-free); extracting a shared struct is a
    // ROADMAP item.

    /// Builder override of the materialisation plan.
    pub fn with_plan(mut self, plan: MmmPlan) -> Self {
        self.set_plan(plan);
        self
    }

    /// In-place plan override (changes `mmm_tag`, invalidating cached
    /// solve plans against this operator).
    pub fn set_plan(&mut self, plan: MmmPlan) {
        self.plan = plan;
        if plan != MmmPlan::MaterializeK {
            *self.kmat.get_mut().unwrap() = None;
        }
    }

    /// The active materialisation plan.
    pub fn plan(&self) -> MmmPlan {
        self.plan
    }

    /// Builder override of the tile-compute precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.set_precision(precision);
        self
    }

    /// In-place precision override (changes `mmm_tag`, invalidating cached
    /// solve plans against this operator).
    pub fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    /// The active tile-compute precision.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Whether mixed-precision row evaluation actually applies: it needs a
    /// stationary kernel and a non-materialised plan (materialised-K rows
    /// are read from the f64 panel — the knob degrades to f64, never lies).
    pub fn mixed_active(&self) -> bool {
        self.precision == Precision::Mixed
            && self.kernel.stationary().is_some()
            && self.plan != MmmPlan::MaterializeK
    }

    /// The cached r² panel, built on first use (parallel over rows).
    fn r2_panel(&self) -> &Mat {
        self.r2.get_or_init(|| {
            let n = self.x.rows();
            let (x, xt, xnorm) = (&self.x, &self.xt, &self.xnorm[..]);
            let mut panel = Mat::zeros(n, n);
            par::parallel_rows_mut(panel.data_mut(), n, n, |row_lo, chunk| {
                for (ri, row) in chunk.chunks_mut(n).enumerate() {
                    squared_dists_row(x, xt, xnorm, row_lo + ri, row);
                }
            });
            panel
        })
    }

    /// The materialised K for the current parameters, built on first use.
    fn k_panel(&self) -> Arc<Mat> {
        if let Some(k) = self.kmat.read().unwrap().as_ref() {
            return Arc::clone(k);
        }
        let mut guard = self.kmat.write().unwrap();
        if let Some(k) = guard.as_ref() {
            return Arc::clone(k);
        }
        let built = Arc::new(cross_kernel(self.kernel.as_ref(), &self.x, &self.x));
        *guard = Some(Arc::clone(&built));
        built
    }

    /// Override the scheduler tile size (rows per work item).
    pub fn with_tile(mut self, tile: usize) -> Self {
        self.set_tile(tile);
        self
    }

    /// In-place tile-size override (rows per work item).
    pub fn set_tile(&mut self, tile: usize) {
        self.tile = tile.max(1);
    }

    /// The shard plan (contiguous, ordered row ranges).
    pub fn shards(&self) -> &[Range<usize>] {
        &self.shards
    }

    /// Schedule the requested kernel product over the local shard queues
    /// (always in-process — backends call this on their *own* operator).
    pub fn block_matmul<T: Scalar>(&self, m: &Mat<T>, bf: BlockFn) -> Mat<T> {
        let n = self.x.rows();
        let mut out = Mat::<T>::zeros(n, m.cols());
        self.block_matmul_into(m, bf, &mut out);
        out
    }

    /// [`ShardedCovOp::block_matmul`] into a caller-owned `n × t` output
    /// (overwritten), so backends and the solver can reuse buffers.
    pub fn block_matmul_into<T: Scalar>(&self, m: &Mat<T>, bf: BlockFn, out: &mut Mat<T>) {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        assert_eq!(out.shape(), (n, t));
        out.data_mut().fill(T::from_f64(0.0));
        let queues: Vec<ShardQueue> = self
            .shards
            .iter()
            .map(|r| ShardQueue::new(r.clone(), self.tile))
            .collect();
        let bf_ref = &bf;
        run_rows_mut(out.data_mut(), n, t, &queues, |_shard, rows, chunk| {
            self.fill_rows(rows, m, bf_ref, chunk);
        });
    }

    /// Compute shard `s`'s row-block of the requested product into `out`
    /// (`shards[s].len() × m.cols()` row-major, zeroed here) — the unit a
    /// [`ShardBackend`] dispatches. Serial on purpose: the caller owns the
    /// parallelism (thread pool, worker process, panel stream).
    pub fn fill_shard<T: Scalar>(&self, s: usize, m: &Mat<T>, bf: &BlockFn, out: &mut [T]) {
        let rows = self.shards[s].clone();
        assert_eq!(out.len(), rows.len() * m.cols());
        out.fill(T::from_f64(0.0));
        self.fill_rows(rows, m, bf, out);
    }

    /// Materialise shard `s`'s noise-free kernel rows `K[rows(s), :]` as a
    /// `shards[s].len() × n` panel — identical values to what the stream
    /// path produces, so panel-based products (out-of-core checkpoints,
    /// worker-resident panels) stay bit-compatible with streamed ones.
    pub fn shard_panel(&self, s: usize) -> Mat {
        let rows = self.shards[s].clone();
        let n = self.x.rows();
        let mut panel = Mat::zeros(rows.len(), n);
        let sp = self.kernel.stationary();
        let mut r2 = vec![0.0f64; n];
        for (ri, i) in rows.enumerate() {
            let out = panel.row_mut(ri);
            match &sp {
                Some(sp) => {
                    squared_dists_row(&self.x, &self.xt, &self.xnorm, i, &mut r2);
                    stationary_apply(sp, TileFn::Value, &r2, out);
                }
                None => {
                    let xi = self.x.row(i);
                    for (j, kv) in out.iter_mut().enumerate() {
                        *kv = self.kernel.eval(xi, self.x.row(j));
                    }
                }
            }
        }
        panel
    }

    /// Shard `s`'s squared-distance rows (`shards[s].len() × n`) — the
    /// parameter-free panel a worker caches under `CachedDistances` so
    /// hyperparameter updates don't force a rebuild.
    pub fn shard_r2_panel(&self, s: usize) -> Mat {
        let rows = self.shards[s].clone();
        let n = self.x.rows();
        let mut panel = Mat::zeros(rows.len(), n);
        for (ri, i) in rows.enumerate() {
            squared_dists_row(&self.x, &self.xt, &self.xnorm, i, panel.row_mut(ri));
        }
        panel
    }

    /// Compute rows `rows` of the requested kernel product into `out`
    /// (`rows.len() × m.cols()` row-major, zero-initialised by the caller).
    /// Row generation follows the operator's [`MmmPlan`]: materialised-K
    /// rows are read directly, cached-r² rows skip the distance pass, and
    /// the stream plan rebuilds everything (the seed behaviour).
    fn fill_rows<T: Scalar>(&self, rows: Range<usize>, m: &Mat<T>, bf: &BlockFn, out: &mut [T]) {
        let n = self.x.rows();
        let t = m.cols();
        let sp = self.kernel.stationary();
        let nk = self.kernel.n_params();
        let kpanel: Option<Arc<Mat>> = (self.plan == MmmPlan::MaterializeK
            && matches!(bf, BlockFn::Value { .. }))
        .then(|| self.k_panel());
        let r2panel: Option<&Mat> =
            (self.plan == MmmPlan::CachedDistances && sp.is_some()).then(|| self.r2_panel());
        // Mixed: stationary rows are evaluated in f32 (vectorised exp) into
        // `krow32`, widened once into `krow`; the contraction below stays
        // f64 regardless, so only the tile values carry f32 rounding.
        let mixed = self.mixed_active();
        let mut krow = vec![0.0f64; n];
        let mut krow32 = vec![0.0f32; if mixed { n } else { 0 }];
        let mut r2 = vec![0.0f64; n];
        let mut grad = vec![0.0f64; nk];
        for (ri, i) in rows.enumerate() {
            // 1) kernel row i, always evaluated in f64
            let krow_ref: &[f64] = if let Some(kp) = &kpanel {
                kp.row(i)
            } else {
                match (bf, &sp) {
                    (BlockFn::Value { .. }, Some(sp)) => {
                        let r2row: &[f64] = match r2panel {
                            Some(panel) => panel.row(i),
                            None => {
                                squared_dists_row(&self.x, &self.xt, &self.xnorm, i, &mut r2);
                                &r2
                            }
                        };
                        if mixed {
                            stationary_apply_f32(sp, TileFn::Value, r2row, &mut krow32);
                            for (d, &s) in krow.iter_mut().zip(&krow32[..]) {
                                *d = f64::from(s);
                            }
                        } else {
                            stationary_apply(sp, TileFn::Value, r2row, &mut krow);
                        }
                    }
                    (BlockFn::DParam(p), Some(sp)) => {
                        // stationary layout: param 0 = log ℓ, param 1 = log s;
                        // ∂K/∂log s = K (noiseless); derivative rows derive
                        // from the same cached r² panel as value rows
                        debug_assert!(*p < nk);
                        let tf = if *p == 0 {
                            TileFn::DLogLengthscale
                        } else {
                            TileFn::Value
                        };
                        let r2row: &[f64] = match r2panel {
                            Some(panel) => panel.row(i),
                            None => {
                                squared_dists_row(&self.x, &self.xt, &self.xnorm, i, &mut r2);
                                &r2
                            }
                        };
                        if mixed {
                            stationary_apply_f32(sp, tf, r2row, &mut krow32);
                            for (d, &s) in krow.iter_mut().zip(&krow32[..]) {
                                *d = f64::from(s);
                            }
                        } else {
                            stationary_apply(sp, tf, r2row, &mut krow);
                        }
                    }
                    (BlockFn::Value { .. }, None) => {
                        let xi = self.x.row(i);
                        for (j, kv) in krow.iter_mut().enumerate() {
                            *kv = self.kernel.eval(xi, self.x.row(j));
                        }
                    }
                    (BlockFn::DParam(p), None) => {
                        let xi = self.x.row(i);
                        for (j, kv) in krow.iter_mut().enumerate() {
                            self.kernel.eval_grad(xi, self.x.row(j), &mut grad);
                            *kv = grad[*p];
                        }
                    }
                }
                &krow
            };
            // 2) contract against M (accumulating in T), streaming M's rows
            let orow = &mut out[ri * t..(ri + 1) * t];
            for (j, &kv) in krow_ref.iter().enumerate() {
                if kv == 0.0 {
                    continue;
                }
                let kvt = T::from_f64(kv);
                let mrow = m.row(j);
                for c in 0..t {
                    orow[c] += kvt * mrow[c];
                }
            }
            if let BlockFn::Value { noise: Some(s2) } = bf {
                let sigma2 = T::from_f64(*s2);
                let mrow = m.row(i);
                for c in 0..t {
                    orow[c] += sigma2 * mrow[c];
                }
            }
        }
    }
}

impl LinearOp for ShardedCovOp {
    fn shape(&self) -> (usize, usize) {
        (self.x.rows(), self.x.rows())
    }

    fn n_params(&self) -> usize {
        self.kernel.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = Mat::zeros(m.rows(), m.cols());
        self.matmul_into(m, &mut out);
        out
    }

    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        match &self.backend {
            Some(b) => b.matmul_block(&BlockFn::Value { noise: None }, m, out),
            None => self.block_matmul_into(m, BlockFn::Value { noise: None }, out),
        }
    }

    fn prepare(&self) {
        if self.backend.is_some() {
            // workers/panels hold the materialised state; nothing local
            return;
        }
        match self.plan {
            MmmPlan::Stream => {}
            MmmPlan::CachedDistances => {
                if self.kernel.stationary().is_some() {
                    let _ = self.r2_panel();
                }
            }
            MmmPlan::MaterializeK => {
                let _ = self.k_panel();
            }
        }
    }

    fn mmm_tag(&self) -> u64 {
        self.plan.tag() | (self.precision.tag() << 8)
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        assert!(param < self.kernel.n_params());
        match &self.backend {
            Some(b) => {
                let mut out = Mat::zeros(m.rows(), m.cols());
                b.matmul_block(&BlockFn::DParam(param), m, &mut out);
                out
            }
            None => self.block_matmul(m, BlockFn::DParam(param)),
        }
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.x.rows())
            .map(|i| self.kernel.eval(self.x.row(i), self.x.row(i)))
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let xi = self.x.row(i);
        (0..self.x.rows())
            .map(|j| self.kernel.eval(xi, self.x.row(j)))
            .collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.x.row(i), self.x.row(j))
    }

    fn dense(&self) -> Mat {
        cross_kernel(self.kernel.as_ref(), &self.x, &self.x)
    }
}

impl KernelCov for ShardedCovOp {
    fn x(&self) -> &Mat {
        &self.x
    }

    fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    fn set_kernel_params(&mut self, raw: &[f64]) {
        self.kernel.set_params(raw);
        // the materialised K is for the OLD parameters; r² is parameter-free
        *self.kmat.get_mut().unwrap() = None;
        if let Some(b) = &self.backend {
            b.set_params(raw, None);
        }
    }

    fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

/// Sharded training operator `K̂ = K + σ²I` — `AddedDiagOp(ShardedCovOp)`
/// under a model-facing name, with the solver-facing [`ShardedMmm`]
/// partial-product seam implemented on the composition (noise fused into
/// each shard's block fill, so per-shard numerics match the monolithic
/// operator exactly).
pub struct ShardedKernelOp {
    op: AddedDiagOp<ShardedCovOp>,
}

impl ShardedKernelOp {
    /// Compose `K(X,X) + noise·I` over `n_shards` row shards.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, noise: f64, n_shards: usize) -> Self {
        ShardedKernelOp {
            op: AddedDiagOp::new(ShardedCovOp::new(x, kernel, n_shards), noise),
        }
    }

    /// Override the scheduler tile size (rows per work item).
    pub fn with_tile(mut self, tile: usize) -> Self {
        self.op.inner_mut().set_tile(tile);
        self
    }

    /// Override the covariance part's [`MmmPlan`]. Shard executors
    /// (out-of-core spools, worker processes) force `Stream` here and
    /// manage per-shard panels themselves, so the full-matrix panels the
    /// in-process plans would build never materialise.
    pub fn set_plan(&mut self, plan: MmmPlan) {
        self.op.inner_mut().set_plan(plan);
    }

    /// Override the covariance part's tile-compute [`Precision`].
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.set_precision(precision);
        self
    }

    /// In-place precision override (see [`ShardedCovOp::set_precision`]).
    pub fn set_precision(&mut self, precision: Precision) {
        self.op.inner_mut().set_precision(precision);
    }

    /// Route the covariance part's products through a [`ShardBackend`]
    /// (the σ²I term stays local — backends see the noise-free K).
    pub fn with_backend(mut self, backend: Arc<dyn ShardBackend>) -> Self {
        self.op.inner_mut().set_backend(backend);
        self
    }

    /// The attached shard backend, if any.
    pub fn backend(&self) -> Option<&Arc<dyn ShardBackend>> {
        self.op.inner().backend()
    }

    /// Training inputs.
    pub fn x(&self) -> &Mat {
        self.op.inner().x()
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.op.inner().kernel()
    }

    /// The noise-free sharded covariance part of the composition.
    pub fn cov(&self) -> &ShardedCovOp {
        self.op.inner()
    }

    /// Row-shard count.
    pub fn shard_count(&self) -> usize {
        self.op.inner().shards().len()
    }

    /// The shard plan.
    pub fn shards(&self) -> &[Range<usize>] {
        self.op.inner().shards()
    }

    /// Full raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel().params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite all raw parameters.
    pub fn set_params(&mut self, raw: &[f64]) {
        assert_eq!(raw.len(), LinearOp::n_params(self));
        let nk = self.kernel().n_params();
        self.op.inner_mut().set_kernel_params(&raw[..nk]);
        self.op.set_raw_value(raw[nk]);
    }

    /// Cross-kernel matrix `K(A, B)` for arbitrary point sets (predictions).
    pub fn cross(&self, a: &Mat, b: &Mat) -> Mat {
        self.op.inner().cross(a, b)
    }

    /// Generic-precision sharded matmul of the full `K̂` (the f32 path of
    /// the Figure-1 experiments and the precision property tests). Kernel
    /// entries are evaluated in f64 and contracted in `T`.
    pub fn matmul_scalar<T: Scalar>(&self, m: &Mat<T>) -> Mat<T> {
        self.op.inner().block_matmul(
            m,
            BlockFn::Value {
                noise: Some(self.op.value()),
            },
        )
    }
}

impl LinearOp for ShardedKernelOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.op.n_params()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.op.dmatmul(param, m)
    }
}

/// The solver-facing seam: shard `s` computes its own row-block of `K̂·M`
/// serially (the scheduler above this — [`crate::linalg::mbcg::sharded_mmm`]
/// — claims whole shards, which is the granularity that later maps onto
/// devices/processes; in-host load balancing uses the tile queues instead).
impl<T: Scalar> ShardedMmm<T> for ShardedKernelOp {
    fn n(&self) -> usize {
        self.op.inner().x.rows()
    }

    fn n_shards(&self) -> usize {
        self.op.inner().shards.len()
    }

    fn shard_rows(&self, s: usize) -> Range<usize> {
        self.op.inner().shards[s].clone()
    }

    fn shard_matmul(&self, s: usize, m: &Mat<T>, out: &mut [T]) {
        let rows = self.op.inner().shards[s].clone();
        self.op.inner().fill_rows(
            rows,
            m,
            &BlockFn::Value {
                noise: Some(self.op.value()),
            },
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::stationary::{Matern32, Rbf};
    use crate::kernels::{DenseKernelOp, SumKernel};
    use crate::linalg::mbcg::sharded_mmm;
    use crate::util::Rng;

    fn setup(n: usize, d: usize, shards: usize, seed: u64) -> (ShardedKernelOp, DenseKernelOp) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let sharded = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)), 0.1, shards);
        let dense = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.2)), 0.1);
        (sharded, dense)
    }

    #[test]
    fn matmul_matches_dense_operator_across_shard_counts() {
        let n = 90;
        for &s in &[1usize, 2, 5, 13, n] {
            let (sharded, dense) = setup(n, 3, s, 1);
            let mut rng = Rng::new(2);
            let m = Mat::from_fn(n, 4, |_, _| rng.normal());
            let got = sharded.matmul(&m);
            let want = dense.matmul(&m);
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "shards {s}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn tiny_tiles_do_not_change_the_result() {
        let (sharded, dense) = setup(70, 2, 4, 3);
        let sharded = sharded.with_tile(1);
        let mut rng = Rng::new(4);
        let m = Mat::from_fn(70, 3, |_, _| rng.normal());
        assert!(sharded.matmul(&m).max_abs_diff(&dense.matmul(&m)) < 1e-12);
    }

    #[test]
    fn dmatmul_matches_dense_operator() {
        let (mut sharded, mut dense) = setup(40, 2, 3, 5);
        let raw = dense.params();
        sharded.set_params(&raw);
        dense.set_params(&raw);
        let mut rng = Rng::new(6);
        let m = Mat::from_fn(40, 2, |_, _| rng.normal());
        for p in 0..LinearOp::n_params(&dense) {
            let got = sharded.dmatmul(p, &m);
            let want = dense.dmatmul(p, &m);
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "param {p}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn non_stationary_kernel_takes_the_generic_path() {
        let mut rng = Rng::new(7);
        let x = Mat::from_fn(35, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let kernel = || -> Box<dyn Kernel> {
            Box::new(SumKernel::new(
                Box::new(Rbf::new(0.5, 1.0)),
                Box::new(Matern32::new(0.7, 0.5)),
            ))
        };
        let sharded = ShardedKernelOp::new(x.clone(), kernel(), 0.07, 6);
        let dense = DenseKernelOp::new(x, kernel(), 0.07);
        let m = Mat::from_fn(35, 3, |_, _| rng.normal());
        assert!(sharded.matmul(&m).max_abs_diff(&dense.matmul(&m)) < 1e-11);
        for p in 0..LinearOp::n_params(&dense) {
            let diff = sharded.dmatmul(p, &m).max_abs_diff(&dense.dmatmul(p, &m));
            assert!(diff < 1e-11, "param {p}: {diff}");
        }
    }

    #[test]
    fn shard_blocks_assemble_to_the_full_product() {
        let (sharded, dense) = setup(57, 3, 5, 8);
        let mut rng = Rng::new(9);
        let m = Mat::from_fn(57, 4, |_, _| rng.normal());
        let got = sharded_mmm(&sharded, &m);
        assert!(got.max_abs_diff(&dense.matmul(&m)) < 1e-12);
    }

    #[test]
    fn f32_matmul_tracks_f64_to_f32_accuracy() {
        let (sharded, dense) = setup(60, 2, 4, 10);
        let mut rng = Rng::new(11);
        let m = Mat::from_fn(60, 3, |_, _| rng.normal());
        let want = dense.matmul(&m);
        let got32 = sharded.matmul_scalar::<f32>(&m.cast());
        let diff = got32.cast::<f64>().max_abs_diff(&want);
        assert!(diff < 1e-3 * (1.0 + want.fro_norm()), "diff {diff}");
    }

    #[test]
    fn mixed_precision_tracks_f64_and_retags() {
        let (mut sharded, dense) = setup(64, 3, 4, 20);
        sharded.set_plan(MmmPlan::Stream);
        let f64_tag = LinearOp::mmm_tag(&sharded);
        let sharded = sharded.with_precision(Precision::Mixed);
        assert!(sharded.cov().mixed_active());
        assert_ne!(
            LinearOp::mmm_tag(&sharded),
            f64_tag,
            "precision switch must change the operator fingerprint"
        );
        let mut rng = Rng::new(21);
        let m = Mat::from_fn(64, 3, |_, _| rng.normal());
        let want = dense.matmul(&m);
        let got = sharded.matmul(&m);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3 * (1.0 + want.fro_norm()), "diff {diff}");
        // derivative rows go through the same f32 tile path
        for p in 0..sharded.kernel().n_params() {
            let dd = sharded
                .dmatmul(p, &m)
                .max_abs_diff(&dense.dmatmul(p, &m));
            assert!(dd < 1e-3 * (1.0 + want.fro_norm()), "param {p}: {dd}");
        }
        // materialised-K rows come from the f64 panel: bit-identical to f64
        let (mut sh2, dn2) = setup(48, 2, 3, 22);
        sh2.set_plan(MmmPlan::MaterializeK);
        let sh2 = sh2.with_precision(Precision::Mixed);
        assert!(!sh2.cov().mixed_active());
        let mut rng = Rng::new(23);
        let m2 = Mat::from_fn(48, 2, |_, _| rng.normal());
        assert!(sh2.matmul(&m2).max_abs_diff(&dn2.matmul(&m2)) < 1e-12);
    }

    #[test]
    fn cross_and_dense_match_the_dense_operator() {
        let (sharded, dense) = setup(25, 2, 3, 12);
        let mut rng = Rng::new(13);
        let xs = Mat::from_fn(9, 2, |_, _| rng.uniform());
        assert!(
            sharded
                .cross(&xs, sharded.x())
                .max_abs_diff(&dense.cross(&xs, dense.x()))
                == 0.0
        );
        let ds = LinearOp::dense(&sharded);
        let dd = LinearOp::dense(&dense);
        assert!(ds.max_abs_diff(&dd) < 1e-12);
    }

    #[test]
    fn params_roundtrip_and_shard_plan() {
        let (mut sharded, _dense) = setup(10, 2, 4, 14);
        assert_eq!(sharded.shard_count(), 4);
        let mut lo = 0;
        for r in sharded.shards() {
            assert_eq!(r.start, lo);
            lo = r.end;
        }
        assert_eq!(lo, 10);
        let mut p = sharded.params();
        assert_eq!(p.len(), LinearOp::n_params(&sharded));
        p[0] += 0.25;
        sharded.set_params(&p);
        assert!((sharded.params()[0] - p[0]).abs() < 1e-15);
        // more shards than rows clamps to n
        let mut rng = Rng::new(15);
        let x = Mat::from_fn(3, 1, |_, _| rng.uniform());
        let tiny = ShardedKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1, 64);
        assert_eq!(tiny.shard_count(), 3);
    }
}
