//! Kernel functions and the **blackbox operator** abstraction (paper §5).
//!
//! BBMM's programmability claim: a GP model is fully specified by a routine
//! that multiplies the (noise-added) kernel matrix `K̂ = K + σ²I` — and its
//! hyperparameter derivatives — against a dense matrix. That routine is the
//! [`KernelOperator`] trait here. Exact GPs ([`operator::DenseKernelOp`]),
//! their row-sharded variant ([`sharded::ShardedKernelOp`]),
//! Bayesian linear regression ([`linear::LinearKernelOp`]), SGPR
//! ([`crate::gp::sgpr::SgprOp`]) and SKI ([`crate::gp::ski::SkiOp`]) are all
//! small implementations of it — mirroring the paper's "50 lines of code"
//! observation (each operator impl here is of that order).
//!
//! Hyperparameters are stored in **log space** (`θ = exp(raw)`) so Adam can
//! run unconstrained; every `dmatmul` is with respect to the *raw*
//! parameter, i.e. `dK̂/draw = θ · dK̂/dθ`.

pub mod compose;
pub mod deep;
pub mod linear;
pub mod operator;
pub mod sharded;
pub mod stationary;

pub use compose::{ProductKernel, SumKernel};
pub use deep::DeepFeatureMap;
pub use linear::LinearKernelOp;
pub use operator::DenseKernelOp;
pub use sharded::ShardedKernelOp;
pub use stationary::{Matern12, Matern32, Matern52, Rbf};

use crate::tensor::Mat;

/// A positive-definite covariance function with analytic derivatives with
/// respect to its raw (log-space) hyperparameters.
pub trait Kernel: Send + Sync {
    /// number of raw hyperparameters
    fn n_params(&self) -> usize;
    /// current raw hyperparameters
    fn params(&self) -> Vec<f64>;
    /// overwrite raw hyperparameters
    fn set_params(&mut self, raw: &[f64]);
    /// human-readable parameter names (for logging)
    fn param_names(&self) -> Vec<String>;
    /// k(x, x′)
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64;
    /// ∂k(x, x′)/∂raw_p for every p, written into `out`
    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]);
    /// clone into a box (kernels are small parameter holders)
    fn boxed_clone(&self) -> Box<dyn Kernel>;
    /// Fast-path descriptor: stationary kernels (functions of r² only)
    /// expose their family + hyperparameters so fused operators can tile
    /// and vectorise instead of making one virtual call per matrix entry.
    fn stationary(&self) -> Option<StationaryParams> {
        None
    }
}

/// Stationary kernel family (for the vectorised fused-mat-mul fast path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StationaryFamily {
    Rbf,
    Matern12,
    Matern32,
    Matern52,
}

/// Stationary kernel descriptor: `k(r) = s · f(r/ℓ)`.
#[derive(Debug, Clone, Copy)]
pub struct StationaryParams {
    pub family: StationaryFamily,
    pub lengthscale: f64,
    pub outputscale: f64,
}

impl Clone for Box<dyn Kernel> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

/// The paper's blackbox: everything an inference engine may ask of a model.
///
/// `matmul` is the hot path (one call per mBCG iteration); `diag`/`row`
/// exist for the pivoted-Cholesky preconditioner; `dmatmul` feeds the
/// stochastic trace term of the gradient (eq. 4).
///
/// Parameter indexing convention: raw kernel parameters come first
/// (`0..n_kernel_params`), and the **last** index is always the raw noise
/// `log σ²`.
pub trait KernelOperator: Sync {
    /// number of training points n
    fn n(&self) -> usize;
    /// total raw parameter count (kernel params + 1 for noise)
    fn n_params(&self) -> usize;
    /// `K̂ · M` — kernel matrix (plus σ²I) times an n×t matrix
    fn matmul(&self, m: &Mat) -> Mat;
    /// `(dK̂/draw_p) · M`
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat;
    /// diagonal of the *noiseless* K (for pivoted Cholesky)
    fn diag(&self) -> Vec<f64>;
    /// row `i` of the *noiseless* K (for pivoted Cholesky)
    fn row(&self, i: usize) -> Vec<f64>;
    /// likelihood noise σ²
    fn noise(&self) -> f64;

    /// Dense materialisation of `K̂` (tests + the Cholesky baseline engine).
    fn dense(&self) -> Mat {
        let n = self.n();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            let r = self.row(i);
            k.row_mut(i).copy_from_slice(&r);
        }
        k.add_diag(self.noise());
        k
    }
}

/// Finite-difference check utility shared by kernel tests: compares
/// `eval_grad` against central differences.
#[cfg(test)]
pub(crate) fn check_kernel_gradients(kernel: &mut dyn Kernel, x1: &[f64], x2: &[f64], tol: f64) {
    let raw = kernel.params();
    let mut analytic = vec![0.0; kernel.n_params()];
    kernel.eval_grad(x1, x2, &mut analytic);
    let h = 1e-6;
    for p in 0..raw.len() {
        let mut plus = raw.clone();
        plus[p] += h;
        kernel.set_params(&plus);
        let fp = kernel.eval(x1, x2);
        let mut minus = raw.clone();
        minus[p] -= h;
        kernel.set_params(&minus);
        let fm = kernel.eval(x1, x2);
        kernel.set_params(&raw);
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (fd - analytic[p]).abs() < tol * (1.0 + fd.abs()),
            "param {p}: fd {fd} vs analytic {}",
            analytic[p]
        );
    }
}
