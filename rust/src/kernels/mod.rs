//! Kernel functions and the kernel-side of the **operator algebra**
//! (paper §5).
//!
//! BBMM's programmability claim: a GP model is fully specified by a routine
//! that multiplies its covariance operator — and its hyperparameter
//! derivatives — against a dense matrix. That routine is the composable
//! [`crate::linalg::op::LinearOp`] trait; every model here is a thin
//! composition over it. A training covariance `K̂ = K + σ²I` is written as
//! `AddedDiagOp(KernelCovOp)` — the noise is a *composition*, not a field
//! baked into each operator — mirroring the paper's "50 lines of code"
//! observation (each noise-free covariance here is of that order).
//!
//! Hyperparameters are stored in **log space** (`θ = exp(raw)`) so Adam can
//! run unconstrained; every `dmatmul` is with respect to the *raw*
//! parameter, i.e. `dK̂/draw = θ · dK̂/dθ`.

pub mod compose;
pub mod deep;
pub mod linear;
pub mod operator;
pub mod sharded;
pub mod stationary;

pub use compose::{ProductKernel, SumKernel};
pub use deep::DeepFeatureMap;
pub use linear::LinearKernelOp;
pub use operator::{DenseKernelOp, KernelCovOp};
pub use sharded::{ShardBlock, ShardedCovOp, ShardedKernelOp};
pub use stationary::{Matern12, Matern32, Matern52, Rbf};

use crate::linalg::op::LinearOp;
use crate::tensor::Mat;

/// A positive-definite covariance function with analytic derivatives with
/// respect to its raw (log-space) hyperparameters.
pub trait Kernel: Send + Sync {
    /// number of raw hyperparameters
    fn n_params(&self) -> usize;
    /// current raw hyperparameters
    fn params(&self) -> Vec<f64>;
    /// overwrite raw hyperparameters
    fn set_params(&mut self, raw: &[f64]);
    /// human-readable parameter names (for logging)
    fn param_names(&self) -> Vec<String>;
    /// k(x, x′)
    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64;
    /// ∂k(x, x′)/∂raw_p for every p, written into `out`
    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]);
    /// clone into a box (kernels are small parameter holders)
    fn boxed_clone(&self) -> Box<dyn Kernel>;
    /// Fast-path descriptor: stationary kernels (functions of r² only)
    /// expose their family + hyperparameters so fused operators can tile
    /// and vectorise instead of making one virtual call per matrix entry.
    fn stationary(&self) -> Option<StationaryParams> {
        None
    }
}

/// Stationary kernel family (for the vectorised fused-mat-mul fast path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StationaryFamily {
    Rbf,
    Matern12,
    Matern32,
    Matern52,
}

/// Stationary kernel descriptor: `k(r) = s · f(r/ℓ)`.
#[derive(Debug, Clone, Copy)]
pub struct StationaryParams {
    pub family: StationaryFamily,
    pub lengthscale: f64,
    pub outputscale: f64,
}

impl Clone for Box<dyn Kernel> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

/// The pluggable noise-free covariance `K(X, X)` seam behind the exact-GP
/// model: any [`LinearOp`] over a training set that can also evaluate
/// cross-covariances and update its kernel hyperparameters. The fused
/// dense operator ([`KernelCovOp`]) and the row-sharded one
/// ([`ShardedCovOp`]) are the two in-tree backends; later structures
/// (per-device shards, batched operators, new approximations) plug in
/// here without touching the model or the engines.
pub trait KernelCov: LinearOp + Send {
    /// Training inputs `X (n×d)`.
    fn x(&self) -> &Mat;
    /// The covariance function.
    fn kernel(&self) -> &dyn Kernel;
    /// Overwrite the kernel's raw hyperparameters.
    fn set_kernel_params(&mut self, raw: &[f64]);
    /// Cross-covariance `K(A, B)` for arbitrary point sets (predictions).
    fn cross(&self, a: &Mat, b: &Mat) -> Mat {
        operator::cross_kernel(self.kernel(), a, b)
    }
    /// Row-shard count of the backend (1 = monolithic).
    fn shard_count(&self) -> usize {
        1
    }
}

/// Finite-difference check utility shared by kernel tests: compares
/// `eval_grad` against central differences.
#[cfg(test)]
pub(crate) fn check_kernel_gradients(kernel: &mut dyn Kernel, x1: &[f64], x2: &[f64], tol: f64) {
    let raw = kernel.params();
    let mut analytic = vec![0.0; kernel.n_params()];
    kernel.eval_grad(x1, x2, &mut analytic);
    let h = 1e-6;
    for p in 0..raw.len() {
        let mut plus = raw.clone();
        plus[p] += h;
        kernel.set_params(&plus);
        let fp = kernel.eval(x1, x2);
        let mut minus = raw.clone();
        minus[p] -= h;
        kernel.set_params(&minus);
        let fm = kernel.eval(x1, x2);
        kernel.set_params(&raw);
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (fd - analytic[p]).abs() < tol * (1.0 + fd.abs()),
            "param {p}: fd {fd} vs analytic {}",
            analytic[p]
        );
    }
}
