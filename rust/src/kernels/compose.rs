//! Kernel compositions (paper §5: "Compositions of kernels can often be
//! handled automatically" — `(K₁K₂ + K₃)M = K₁(K₂M) + K₃M`).
//!
//! At the pointwise level, sums and products of kernels compose both the
//! value and the raw-parameter gradients; the parameter vector is the
//! concatenation of the parts'.

use super::Kernel;

/// `k = k_a + k_b`
#[derive(Clone)]
pub struct SumKernel {
    pub a: Box<dyn Kernel>,
    pub b: Box<dyn Kernel>,
}

impl SumKernel {
    pub fn new(a: Box<dyn Kernel>, b: Box<dyn Kernel>) -> Self {
        SumKernel { a, b }
    }
}

impl Kernel for SumKernel {
    fn n_params(&self) -> usize {
        self.a.n_params() + self.b.n_params()
    }

    fn params(&self) -> Vec<f64> {
        let mut p = self.a.params();
        p.extend(self.b.params());
        p
    }

    fn set_params(&mut self, raw: &[f64]) {
        let na = self.a.n_params();
        self.a.set_params(&raw[..na]);
        self.b.set_params(&raw[na..]);
    }

    fn param_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .a
            .param_names()
            .into_iter()
            .map(|n| format!("a.{n}"))
            .collect();
        names.extend(self.b.param_names().into_iter().map(|n| format!("b.{n}")));
        names
    }

    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        self.a.eval(x1, x2) + self.b.eval(x1, x2)
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let na = self.a.n_params();
        self.a.eval_grad(x1, x2, &mut out[..na]);
        self.b.eval_grad(x1, x2, &mut out[na..]);
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(SumKernel {
            a: self.a.boxed_clone(),
            b: self.b.boxed_clone(),
        })
    }
}

/// `k = k_a · k_b`
#[derive(Clone)]
pub struct ProductKernel {
    pub a: Box<dyn Kernel>,
    pub b: Box<dyn Kernel>,
}

impl ProductKernel {
    pub fn new(a: Box<dyn Kernel>, b: Box<dyn Kernel>) -> Self {
        ProductKernel { a, b }
    }
}

impl Kernel for ProductKernel {
    fn n_params(&self) -> usize {
        self.a.n_params() + self.b.n_params()
    }

    fn params(&self) -> Vec<f64> {
        let mut p = self.a.params();
        p.extend(self.b.params());
        p
    }

    fn set_params(&mut self, raw: &[f64]) {
        let na = self.a.n_params();
        self.a.set_params(&raw[..na]);
        self.b.set_params(&raw[na..]);
    }

    fn param_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .a
            .param_names()
            .into_iter()
            .map(|n| format!("a.{n}"))
            .collect();
        names.extend(self.b.param_names().into_iter().map(|n| format!("b.{n}")));
        names
    }

    fn eval(&self, x1: &[f64], x2: &[f64]) -> f64 {
        self.a.eval(x1, x2) * self.b.eval(x1, x2)
    }

    fn eval_grad(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        let na = self.a.n_params();
        let ka = self.a.eval(x1, x2);
        let kb = self.b.eval(x1, x2);
        self.a.eval_grad(x1, x2, &mut out[..na]);
        for v in out[..na].iter_mut() {
            *v *= kb;
        }
        self.b.eval_grad(x1, x2, &mut out[na..]);
        for v in out[na..].iter_mut() {
            *v *= ka;
        }
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(ProductKernel {
            a: self.a.boxed_clone(),
            b: self.b.boxed_clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::check_kernel_gradients;
    use crate::kernels::stationary::{Matern32, Rbf};

    #[test]
    fn sum_evaluates_to_sum() {
        let k = SumKernel::new(
            Box::new(Rbf::new(1.0, 1.0)),
            Box::new(Matern32::new(0.5, 2.0)),
        );
        let a = [0.1];
        let b = [0.8];
        let want = Rbf::new(1.0, 1.0).eval(&a, &b) + Matern32::new(0.5, 2.0).eval(&a, &b);
        assert!((k.eval(&a, &b) - want).abs() < 1e-15);
    }

    #[test]
    fn product_evaluates_to_product() {
        let k = ProductKernel::new(
            Box::new(Rbf::new(1.0, 1.5)),
            Box::new(Matern32::new(0.5, 2.0)),
        );
        let a = [0.1, 0.4];
        let b = [0.8, -0.3];
        let want = Rbf::new(1.0, 1.5).eval(&a, &b) * Matern32::new(0.5, 2.0).eval(&a, &b);
        assert!((k.eval(&a, &b) - want).abs() < 1e-15);
    }

    #[test]
    fn composite_gradients_match_fd() {
        let mut sum = SumKernel::new(
            Box::new(Rbf::new(0.7, 1.2)),
            Box::new(Matern32::new(0.4, 0.8)),
        );
        check_kernel_gradients(&mut sum, &[0.3, 0.1], &[-0.2, 0.5], 1e-5);
        let mut prod = ProductKernel::new(
            Box::new(Rbf::new(0.7, 1.2)),
            Box::new(Matern32::new(0.4, 0.8)),
        );
        check_kernel_gradients(&mut prod, &[0.3, 0.1], &[-0.2, 0.5], 1e-5);
    }

    #[test]
    fn nested_composition_param_layout() {
        let inner = SumKernel::new(Box::new(Rbf::new(1.0, 1.0)), Box::new(Rbf::new(2.0, 2.0)));
        let outer = ProductKernel::new(Box::new(inner), Box::new(Matern32::new(0.5, 1.0)));
        assert_eq!(outer.n_params(), 6);
        assert_eq!(outer.param_names().len(), 6);
        let mut outer = outer;
        let mut p = outer.params();
        p[0] = 0.123;
        outer.set_params(&p);
        assert!((outer.params()[0] - 0.123).abs() < 1e-15);
    }
}
