//! Bayesian linear regression as a GP (paper §5): `K̂ = v·XXᵀ + σ²I` —
//! written as the composition `AddedDiagOp(ScaledOp(LowRankOp(X)))`.
//!
//! The algebra recovers the efficient algorithm "with no additional
//! derivation" (the paper's point): [`crate::linalg::op::LowRankOp`]
//! multiplies as `X(XᵀM)` — O(tnd) instead of O(tn²) — and the scale and
//! noise ride on generic composition wrappers. The only model-specific
//! code left is the 2-parameter gradient layout below.

use crate::linalg::op::{AddedDiagOp, LinearOp, LowRankOp, ParamOutOfRange, ScaledOp};
use crate::tensor::Mat;

/// Linear-kernel operator (`v = exp(raw_var)` is the weight-space prior
/// variance; raw params: `[log v, log σ²]`).
///
/// Invariant: `raw_var` is the authoritative (lossless, log-space)
/// parameter; the [`ScaledOp`]'s scale is its cached `exp`, written only
/// by [`LinearKernelOp::new`] and [`LinearKernelOp::set_params`].
pub struct LinearKernelOp {
    op: AddedDiagOp<ScaledOp<LowRankOp>>,
    raw_var: f64,
}

impl LinearKernelOp {
    /// Compose `variance·XXᵀ + noise·I`.
    pub fn new(x: Mat, variance: f64, noise: f64) -> Self {
        assert!(variance > 0.0 && noise > 0.0);
        LinearKernelOp {
            op: AddedDiagOp::new(ScaledOp::new(LowRankOp::new(x), variance), noise),
            raw_var: variance.ln(),
        }
    }

    /// Raw parameter vector `[log v, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        vec![self.raw_var, self.op.raw_value()]
    }

    /// Overwrite raw parameters.
    pub fn set_params(&mut self, raw: &[f64]) {
        self.raw_var = raw[0];
        self.op.inner_mut().set_scale(raw[0].exp());
        self.op.set_raw_value(raw[1]);
    }

    /// Weight-space prior variance `v`.
    pub fn variance(&self) -> f64 {
        self.raw_var.exp()
    }

    /// Training inputs (the low-rank factor itself).
    pub fn x(&self) -> &Mat {
        self.op.inner().inner().factor()
    }

    /// The noise-free covariance part `v·XXᵀ` of the composition.
    pub fn cov(&self) -> &ScaledOp<LowRankOp> {
        self.op.inner()
    }

    /// Non-panicking gradient accessor: an out-of-range raw-parameter
    /// index is a proper [`ParamOutOfRange`] error instead of a process
    /// abort (the panicking [`LinearOp::dmatmul`] below routes through
    /// this and fails with the crate-standard message).
    pub fn try_dmatmul(&self, param: usize, m: &Mat) -> Result<Mat, ParamOutOfRange> {
        match param {
            // d(e^raw·XXᵀ)/draw = v·XXᵀ — exactly the scaled inner matmul
            0 => Ok(self.op.inner().matmul(m)),
            1 => {
                let mut out = m.clone();
                out.scale_assign(self.noise());
                Ok(out)
            }
            _ => Err(ParamOutOfRange { n_params: 2, param }),
        }
    }
}

impl LinearOp for LinearKernelOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        2
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.try_dmatmul(param, m)
            .unwrap_or_else(|e| panic!("LinearKernelOp::dmatmul: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_matches_dense() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(30, 4, |_, _| rng.normal());
        let op = LinearKernelOp::new(x, 0.7, 0.2);
        let m = Mat::from_fn(30, 3, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn dmatmul_fd_check() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(15, 3, |_, _| rng.normal());
        let mut op = LinearKernelOp::new(x, 0.5, 0.3);
        let m = Mat::from_fn(15, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..2 {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(analytic.max_abs_diff(&fd) < 1e-5, "param {p}");
        }
    }

    #[test]
    fn bayesian_linear_regression_recovers_weights() {
        // y = Xw + ε; GP posterior mean at training points ≈ Xw
        let n = 200;
        let d = 3;
        let w = [1.5, -2.0, 0.5];
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                r.iter().zip(w.iter()).map(|(a, b)| a * b).sum::<f64>() + 0.01 * rng.normal()
            })
            .collect();
        let op = LinearKernelOp::new(x.clone(), 10.0, 0.01);
        let kd = op.dense();
        let ch = crate::linalg::cholesky::Cholesky::new(&kd).unwrap();
        let alpha = ch.solve_vec(&y);
        // predictive mean at training points: K_noiseless · α — the
        // noise-free rows come from the composition's cov() part
        let mut pred = vec![0.0; n];
        for i in 0..n {
            let row = op.cov().row(i);
            pred[i] = row.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        }
        let mae: f64 = pred
            .iter()
            .zip(y.iter())
            .map(|(p, t)| (p - t).abs())
            .sum::<f64>()
            / n as f64;
        assert!(mae < 0.05, "mae={mae}");
    }

    #[test]
    fn out_of_range_param_is_a_proper_error() {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(10, 2, |_, _| rng.normal());
        let op = LinearKernelOp::new(x, 0.5, 0.1);
        let m = Mat::from_fn(10, 2, |_, _| rng.normal());
        // in-range accessors agree with the panicking trait surface
        for p in 0..2 {
            let a = op.try_dmatmul(p, &m).unwrap();
            let b = op.dmatmul(p, &m);
            assert!(a.max_abs_diff(&b) == 0.0, "param {p}");
        }
        let err = op.try_dmatmul(2, &m).unwrap_err();
        assert_eq!(err, crate::linalg::op::ParamOutOfRange { n_params: 2, param: 2 });
        assert_eq!(format!("{err}"), "operator has 2 parameters, asked for 2");
    }

    #[test]
    #[should_panic(expected = "operator has 2 parameters, asked for 5")]
    fn dmatmul_out_of_range_panics_with_standard_message() {
        let mut rng = Rng::new(6);
        let x = Mat::from_fn(8, 2, |_, _| rng.normal());
        let op = LinearKernelOp::new(x, 0.5, 0.1);
        let m = Mat::from_fn(8, 1, |_, _| rng.normal());
        let _ = op.dmatmul(5, &m);
    }

    #[test]
    fn composition_exposes_woodbury_structure() {
        // v·XXᵀ + σ²I has a (scaled) low-rank core; the bare factor is X,
        // so the generic Woodbury dispatch must not claim it (the scale
        // would be lost) — the hint stays iterative
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(12, 2, |_, _| rng.normal());
        let op = LinearKernelOp::new(x, 0.5, 0.1);
        assert_eq!(
            crate::linalg::op::solve_strategy(&op),
            crate::linalg::op::SolveHint::Iterative
        );
        let (cov, s2) = op.noise_split().unwrap();
        assert!((s2 - 0.1).abs() < 1e-12);
        assert!(cov.low_rank_factor().is_none()); // ScaledOp hides the factor
    }
}
