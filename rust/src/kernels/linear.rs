//! Bayesian linear regression as a GP (paper §5): `K̂ = v·XXᵀ + σ²I`.
//!
//! The blackbox matmul distributes as `v·X(Xᵀ M) + σ²M` — O(tnd) instead of
//! O(tn²) — so BBMM automatically recovers the efficient algorithm with "no
//! additional derivation", which is exactly the paper's point.

use super::KernelOperator;
use crate::tensor::Mat;

/// Linear-kernel operator (`v = exp(raw_var)` is the weight-space prior
/// variance; raw params: `[log v, log σ²]`).
pub struct LinearKernelOp {
    x: Mat,
    raw_var: f64,
    raw_noise: f64,
}

impl LinearKernelOp {
    pub fn new(x: Mat, variance: f64, noise: f64) -> Self {
        assert!(variance > 0.0 && noise > 0.0);
        LinearKernelOp {
            x,
            raw_var: variance.ln(),
            raw_noise: noise.ln(),
        }
    }

    pub fn params(&self) -> Vec<f64> {
        vec![self.raw_var, self.raw_noise]
    }

    pub fn set_params(&mut self, raw: &[f64]) {
        self.raw_var = raw[0];
        self.raw_noise = raw[1];
    }

    pub fn variance(&self) -> f64 {
        self.raw_var.exp()
    }

    pub fn x(&self) -> &Mat {
        &self.x
    }
}

impl KernelOperator for LinearKernelOp {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn n_params(&self) -> usize {
        2
    }

    fn matmul(&self, m: &Mat) -> Mat {
        // v·X(XᵀM) + σ²M — never forms XXᵀ
        let xtm = self.x.t_matmul(m); // d×t
        let mut out = self.x.matmul(&xtm); // n×t
        out.scale_assign(self.variance());
        let sigma2 = self.noise();
        let mut noise_part = m.clone();
        noise_part.scale_assign(sigma2);
        out.add_assign(&noise_part);
        out
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        match param {
            0 => {
                // d/draw_var = v·XXᵀ M
                let xtm = self.x.t_matmul(m);
                let mut out = self.x.matmul(&xtm);
                out.scale_assign(self.variance());
                out
            }
            1 => {
                let mut out = m.clone();
                out.scale_assign(self.noise());
                out
            }
            _ => panic!("linear kernel has 2 params"),
        }
    }

    fn diag(&self) -> Vec<f64> {
        let v = self.variance();
        (0..self.n())
            .map(|i| {
                let r = self.x.row(i);
                v * r.iter().map(|x| x * x).sum::<f64>()
            })
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let v = self.variance();
        let xi = self.x.row(i);
        (0..self.n())
            .map(|j| {
                let xj = self.x.row(j);
                v * xi.iter().zip(xj.iter()).map(|(a, b)| a * b).sum::<f64>()
            })
            .collect()
    }

    fn noise(&self) -> f64 {
        self.raw_noise.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_matches_dense() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(30, 4, |_, _| rng.normal());
        let op = LinearKernelOp::new(x, 0.7, 0.2);
        let m = Mat::from_fn(30, 3, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn dmatmul_fd_check() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(15, 3, |_, _| rng.normal());
        let mut op = LinearKernelOp::new(x, 0.5, 0.3);
        let m = Mat::from_fn(15, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..2 {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(analytic.max_abs_diff(&fd) < 1e-5, "param {p}");
        }
    }

    #[test]
    fn bayesian_linear_regression_recovers_weights() {
        // y = Xw + ε; GP posterior mean at training points ≈ Xw
        let n = 200;
        let d = 3;
        let w = [1.5, -2.0, 0.5];
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                r.iter().zip(w.iter()).map(|(a, b)| a * b).sum::<f64>() + 0.01 * rng.normal()
            })
            .collect();
        let op = LinearKernelOp::new(x.clone(), 10.0, 0.01);
        let kd = op.dense();
        let ch = crate::linalg::cholesky::Cholesky::new(&kd).unwrap();
        let alpha = ch.solve_vec(&y);
        // predictive mean at training points: K_noiseless · α
        let mut pred = vec![0.0; n];
        for i in 0..n {
            let row = op.row(i);
            pred[i] = row.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        }
        let mae: f64 = pred
            .iter()
            .zip(y.iter())
            .map(|(p, t)| (p - t).abs())
            .sum::<f64>()
            / n as f64;
        assert!(mae < 0.05, "mae={mae}");
    }
}
