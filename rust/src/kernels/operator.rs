//! Exact-GP covariance operators: the fused noise-free [`KernelCovOp`]
//! and the model composition [`DenseKernelOp`] =
//! `AddedDiagOp(KernelCovOp)` = `K + σ²I`.
//!
//! The fused matmul is the Rust analogue of the L1 Pallas kernel
//! (`python/compile/kernels/kernel_matmul.py`): rows of K are produced a
//! register-tile group at a time and immediately contracted against `M`
//! through the shared GEMM micro-kernel ([`crate::tensor::gemm`]).
//! Whether those rows are rebuilt per product, derived from a cached r²
//! panel, or read from a materialised K is the operator's [`MmmPlan`]
//! (chosen from the `--mmm-budget-mb` memory budget — streaming keeps
//! peak memory at O(n·t + tile·n), the plans trade O(n²) memory for
//! iteration-amortised work).

use super::{Kernel, KernelCov, StationaryFamily, StationaryParams};
use crate::linalg::op::{mmm, AddedDiagOp, LinearOp, MmmPlan, Precision};
use crate::tensor::{gemm, Mat};
use crate::util::fastmath::{fast_exp_slice, fast_exp_slice_f32};
use crate::util::{par, scratch};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Which function of r² a stationary tile evaluates (shared with the
/// sharded operator in [`super::sharded`]).
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum TileFn {
    /// k(r)
    Value,
    /// ∂k/∂log ℓ
    DLogLengthscale,
}

/// Vectorised stationary-kernel row: given squared distances `r2`, write
/// `out[j] = f(r2[j])` for the family/derivative requested. This is the
/// scalar-free inner loop of the fused mat-mul fast path, organised as
/// three whole-row passes so the expensive middle one runs through the
/// **batched SIMD exp** ([`fast_exp_slice`]): (1) write the exp argument
/// (−a·r² or −u) into `out`, (2) exponentiate the whole row in place,
/// (3) multiply the family's prefactor (recomputing `u = c·√r²` from the
/// untouched `r2` slice where needed — a sqrt is one instruction, the exp
/// was the bottleneck).
pub(crate) fn stationary_apply(sp: &StationaryParams, tf: TileFn, r2: &[f64], out: &mut [f64]) {
    let s = sp.outputscale;
    let ls = sp.lengthscale;
    let m = r2.len();
    match (sp.family, tf) {
        (StationaryFamily::Rbf, TileFn::Value) => {
            let a = 1.0 / (2.0 * ls * ls);
            for j in 0..m {
                out[j] = -a * r2[j];
            }
            fast_exp_slice(&mut out[..m]);
            for o in out[..m].iter_mut() {
                *o = s * *o;
            }
        }
        (StationaryFamily::Rbf, TileFn::DLogLengthscale) => {
            let a = 1.0 / (2.0 * ls * ls);
            let b = 1.0 / (ls * ls);
            for j in 0..m {
                out[j] = -a * r2[j];
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                out[j] = s * out[j] * (b * r2[j]);
            }
        }
        (StationaryFamily::Matern12, TileFn::Value) => {
            let c = 1.0 / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for o in out[..m].iter_mut() {
                *o = s * *o;
            }
        }
        (StationaryFamily::Matern12, TileFn::DLogLengthscale) => {
            let c = 1.0 / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = s * out[j] * u;
            }
        }
        (StationaryFamily::Matern32, TileFn::Value) => {
            let c = 3f64.sqrt() / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = s * (1.0 + u) * out[j];
            }
        }
        (StationaryFamily::Matern32, TileFn::DLogLengthscale) => {
            let c = 3f64.sqrt() / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = s * u * u * out[j];
            }
        }
        (StationaryFamily::Matern52, TileFn::Value) => {
            let c = 5f64.sqrt() / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = s * (1.0 + u + u * u / 3.0) * out[j];
            }
        }
        (StationaryFamily::Matern52, TileFn::DLogLengthscale) => {
            let c = 5f64.sqrt() / ls;
            for j in 0..m {
                out[j] = -(c * r2[j].sqrt());
            }
            fast_exp_slice(&mut out[..m]);
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = s * out[j] * u * u * (1.0 + u) / 3.0;
            }
        }
    }
}

/// f32 twin of [`stationary_apply`] for the mixed-precision tile path:
/// distances stay f64 (they come from the shared r² panel / distance
/// pass), exp arguments are rounded **once** to f32, the batched f32 exp
/// runs at double lane width, and prefactors are computed in f64 and
/// rounded at the store — so the only precision lost is the final f32
/// representation, ~1e-7 relative per entry.
pub(crate) fn stationary_apply_f32(sp: &StationaryParams, tf: TileFn, r2: &[f64], out: &mut [f32]) {
    let s = sp.outputscale;
    let ls = sp.lengthscale;
    let m = r2.len();
    let c = match sp.family {
        StationaryFamily::Rbf => 0.0,
        StationaryFamily::Matern12 => 1.0 / ls,
        StationaryFamily::Matern32 => 3f64.sqrt() / ls,
        StationaryFamily::Matern52 => 5f64.sqrt() / ls,
    };
    // pass 1: exp arguments (−a·r² or −u), rounded to f32 once
    if sp.family == StationaryFamily::Rbf {
        let a = 1.0 / (2.0 * ls * ls);
        for j in 0..m {
            out[j] = (-a * r2[j]) as f32;
        }
    } else {
        for j in 0..m {
            out[j] = (-(c * r2[j].sqrt())) as f32;
        }
    }
    // pass 2: batched exp at f32 lane width
    fast_exp_slice_f32(&mut out[..m]);
    // pass 3: prefactor epilogue (f64 math, one rounding at the store)
    match (sp.family, tf) {
        (StationaryFamily::Rbf, TileFn::Value) => {
            for o in out[..m].iter_mut() {
                *o = (s * *o as f64) as f32;
            }
        }
        (StationaryFamily::Rbf, TileFn::DLogLengthscale) => {
            let b = 1.0 / (ls * ls);
            for j in 0..m {
                out[j] = (s * out[j] as f64 * (b * r2[j])) as f32;
            }
        }
        (StationaryFamily::Matern12, TileFn::Value) => {
            for o in out[..m].iter_mut() {
                *o = (s * *o as f64) as f32;
            }
        }
        (StationaryFamily::Matern12, TileFn::DLogLengthscale) => {
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = (s * out[j] as f64 * u) as f32;
            }
        }
        (StationaryFamily::Matern32, TileFn::Value) => {
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = (s * (1.0 + u) * out[j] as f64) as f32;
            }
        }
        (StationaryFamily::Matern32, TileFn::DLogLengthscale) => {
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = (s * u * u * out[j] as f64) as f32;
            }
        }
        (StationaryFamily::Matern52, TileFn::Value) => {
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = (s * (1.0 + u + u * u / 3.0) * out[j] as f64) as f32;
            }
        }
        (StationaryFamily::Matern52, TileFn::DLogLengthscale) => {
            for j in 0..m {
                let u = c * r2[j].sqrt();
                out[j] = (s * out[j] as f64 * u * u * (1.0 + u) / 3.0) as f32;
            }
        }
    }
}

/// `r2[j] = |xᵢ|² + |xⱼ|² − 2·xᵢᵀxⱼ` for row `i` against the cached
/// transpose `xt (d×n)` and per-row norms, clamped at 0 against rounding —
/// the distance pass shared by the fused stationary operators (dense and
/// [`super::sharded`]). d vectorised axpy passes, streaming over j.
pub(crate) fn squared_dists_row(x: &Mat, xt: &Mat, xnorm: &[f64], i: usize, r2: &mut [f64]) {
    let n = x.rows();
    let d = x.cols();
    let xi = x.row(i);
    r2.iter_mut().for_each(|v| *v = 0.0);
    for dd in 0..d {
        let xv = xi[dd];
        if xv == 0.0 {
            continue;
        }
        let xtrow = xt.row(dd);
        for j in 0..n {
            r2[j] += xv * xtrow[j];
        }
    }
    let xin = xnorm[i];
    for j in 0..n {
        r2[j] = (xin + xnorm[j] - 2.0 * r2[j]).max(0.0);
    }
}

/// Rows of kernel tile built per contraction group — matches the GEMM
/// register-tile height so each group is one micro-kernel panel.
const GROUP: usize = gemm::MR;

/// Noise-free exact covariance operator `K(X, X)` over a training set
/// `X (n×d)` — the fused stationary fast path lives here; composing with
/// [`AddedDiagOp`] yields the training operator `K̂ = K + σ²I`.
///
/// Products run under a [`MmmPlan`] chosen from the materialisation
/// budget (see [`mmm`]): `Stream` rebuilds kernel rows per product,
/// `CachedDistances` derives every value/derivative tile from one cached
/// r² panel, `MaterializeK` builds K once per hyperparameter setting and
/// turns each product into a register-blocked GEMM.
///
/// Training inputs and their derived caches (`Xᵀ`, row norms, the r²
/// panel) sit behind `Arc`s so a hyperparameter sweep's candidates share
/// one copy ([`KernelCovOp::share_cached`]) — sweep memory stays flat in
/// the candidate count.
pub struct KernelCovOp {
    x: Arc<Mat>,
    kernel: Box<dyn Kernel>,
    /// cached Xᵀ (d×n): the distance pass streams over j
    xt: Arc<Mat>,
    /// cached per-row squared norms |xᵢ|²
    xnorm: Arc<Vec<f64>>,
    /// how products materialise (fingerprinted via `mmm_tag`)
    plan: MmmPlan,
    /// tile arithmetic precision (fingerprinted via `mmm_tag`): Mixed
    /// computes stationary `Stream`/`CachedDistances` tiles in f32 with
    /// f64 accumulation; every other path degrades to f64
    precision: Precision,
    /// cached r² panel — depends only on X, so it survives every
    /// hyperparameter update and is shared across `share_cached` clones
    r2: Arc<OnceLock<Mat>>,
    /// materialised K for the CURRENT kernel parameters (cleared by
    /// `set_kernel_params`; per-clone — K depends on the parameters)
    kmat: RwLock<Option<Arc<Mat>>>,
    /// grow-only staging buffer for the Mixed path's f32 copy of `M`
    /// (taken out under the lock for the duration of a product, so warm
    /// products stay allocation-free without touching the per-thread
    /// scratch slots the workers use)
    m32_staging: Mutex<Vec<f32>>,
}

impl KernelCovOp {
    /// Build over training inputs and a covariance function; the plan is
    /// chosen automatically from the [`mmm::budget_bytes`] budget.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>) -> Self {
        Self::from_shared(Arc::new(x), kernel)
    }

    /// Build over **shared** training inputs (the `Arc<Mat>` seam:
    /// callers holding several operators over one dataset pass clones of
    /// one `Arc` instead of cloning the data).
    pub fn from_shared(x: Arc<Mat>, kernel: Box<dyn Kernel>) -> Self {
        let xt = Arc::new(x.transpose());
        let xnorm: Arc<Vec<f64>> = Arc::new(
            (0..x.rows())
                .map(|i| x.row(i).iter().map(|v| v * v).sum())
                .collect(),
        );
        let plan = MmmPlan::auto(x.rows(), kernel.stationary().is_some(), mmm::budget_bytes());
        KernelCovOp {
            x,
            kernel,
            xt,
            xnorm,
            plan,
            precision: mmm::default_precision(),
            r2: Arc::new(OnceLock::new()),
            kmat: RwLock::new(None),
            m32_staging: Mutex::new(Vec::new()),
        }
    }

    /// A sibling operator over the **same** inputs with a different
    /// covariance function: shares `X`, `Xᵀ`, the row norms, and the r²
    /// panel by `Arc` — the seam `fit_sweep` uses so b candidates pay for
    /// one copy of the dataset and one distance panel between them.
    ///
    /// Plan choice under the memory budget: stationary siblings keep the
    /// budget-neutral `CachedDistances` (the r² panel is shared, so b
    /// siblings hold ONE panel); non-stationary siblings take `Stream`
    /// rather than `MaterializeK`, because each sibling's K panel would be
    /// its own n² allocation — b candidates would hold b panels and blow
    /// through a budget sized for one (`with_plan` opts back in).
    pub fn share_cached(&self, kernel: Box<dyn Kernel>) -> Self {
        let plan = if kernel.stationary().is_some() {
            MmmPlan::auto(self.x.rows(), true, mmm::budget_bytes())
        } else {
            MmmPlan::Stream
        };
        KernelCovOp {
            x: Arc::clone(&self.x),
            kernel,
            xt: Arc::clone(&self.xt),
            xnorm: Arc::clone(&self.xnorm),
            plan,
            precision: self.precision,
            r2: Arc::clone(&self.r2),
            kmat: RwLock::new(None),
            m32_staging: Mutex::new(Vec::new()),
        }
    }

    /// Builder override of the materialisation plan.
    pub fn with_plan(mut self, plan: MmmPlan) -> Self {
        self.set_plan(plan);
        self
    }

    /// In-place plan override (changes the operator's `mmm_tag`, so cached
    /// solve plans against it are invalidated).
    pub fn set_plan(&mut self, plan: MmmPlan) {
        self.plan = plan;
        if plan != MmmPlan::MaterializeK {
            *self.kmat.get_mut().unwrap() = None;
        }
    }

    /// The active materialisation plan.
    pub fn plan(&self) -> MmmPlan {
        self.plan
    }

    /// Builder override of the tile precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.set_precision(precision);
        self
    }

    /// In-place precision override (changes the operator's `mmm_tag`, so
    /// cached solve plans against it are invalidated).
    pub fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    /// The active tile precision.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Whether products will actually run the mixed f32-tile path (Mixed
    /// precision degrades to f64 for `MaterializeK` and non-stationary
    /// kernels — it degrades, it never lies).
    pub fn mixed_active(&self) -> bool {
        self.precision == Precision::Mixed
            && self.plan != MmmPlan::MaterializeK
            && self.kernel.stationary().is_some()
    }

    /// The shared training-input handle (for tests and callers that want
    /// to build further operators over the same data).
    pub fn shared_x(&self) -> &Arc<Mat> {
        &self.x
    }

    /// The cached r² panel, built on first use (parallel over rows).
    fn r2_panel(&self) -> &Mat {
        self.r2.get_or_init(|| {
            let n = self.x.rows();
            let x: &Mat = &self.x;
            let xt: &Mat = &self.xt;
            let xnorm: &[f64] = &self.xnorm;
            let mut panel = Mat::zeros(n, n);
            par::parallel_rows_mut(panel.data_mut(), n, n, |row_lo, chunk| {
                for (ri, row) in chunk.chunks_mut(n).enumerate() {
                    squared_dists_row(x, xt, xnorm, row_lo + ri, row);
                }
            });
            panel
        })
    }

    /// The materialised K for the current parameters, built on first use.
    fn k_panel(&self) -> Arc<Mat> {
        if let Some(k) = self.kmat.read().unwrap().as_ref() {
            return Arc::clone(k);
        }
        let mut guard = self.kmat.write().unwrap();
        if let Some(k) = guard.as_ref() {
            return Arc::clone(k);
        }
        let built = Arc::new(cross_kernel(self.kernel.as_ref(), &self.x, &self.x));
        *guard = Some(Arc::clone(&built));
        built
    }

    /// Fused stationary tiles: `K·M` or `(∂K/∂log ℓ)·M` written into
    /// `out`, with r² rows read from the cached panel when available and
    /// rebuilt by vectorised rank-d updates otherwise. Kernel rows are
    /// produced [`GROUP`] at a time and contracted through the
    /// register-blocked GEMM micro-kernel.
    fn stationary_tiles_into(
        &self,
        sp: &StationaryParams,
        tf: TileFn,
        m: &Mat,
        out: &mut Mat,
        r2_panel: Option<&Mat>,
    ) {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        assert_eq!(out.shape(), (n, t), "stationary_tiles_into: output shape");
        let x: &Mat = &self.x;
        let xt: &Mat = &self.xt;
        let xnorm: &[f64] = &self.xnorm;
        let mdata = m.data();
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            chunk.iter_mut().for_each(|v| *v = 0.0);
            let rows_here = chunk.len() / t.max(1);
            scratch::with(2 * GROUP * n, |buf| {
                let (r2buf, kbuf) = buf.split_at_mut(GROUP * n);
                let mut r0 = 0;
                while r0 < rows_here {
                    let g = GROUP.min(rows_here - r0);
                    for rr in 0..g {
                        let i = row_lo + r0 + rr;
                        let krow = &mut kbuf[rr * n..(rr + 1) * n];
                        match r2_panel {
                            Some(panel) => stationary_apply(sp, tf, panel.row(i), krow),
                            None => {
                                let r2row = &mut r2buf[rr * n..(rr + 1) * n];
                                squared_dists_row(x, xt, xnorm, i, r2row);
                                stationary_apply(sp, tf, r2row, krow);
                            }
                        }
                    }
                    gemm::gemm_into(
                        &kbuf[..g * n],
                        mdata,
                        &mut chunk[r0 * t..(r0 + g) * t],
                        g,
                        n,
                        t,
                    );
                    r0 += g;
                }
            });
        });
    }

    /// Mixed-precision twin of [`KernelCovOp::stationary_tiles_into`]:
    /// kernel rows are evaluated into **f32** tiles (double SIMD lane
    /// width, half the tile bandwidth) and contracted against an f32 copy
    /// of `M` through [`gemm::gemm_mixed_into`], which accumulates into
    /// the f64 output at `KB`-block granularity. Distances stay f64 (the
    /// r² panel is shared with the f64 path). The f32 copy of `M` is
    /// staged once per product in the operator's grow-only buffer.
    fn stationary_tiles_into_mixed(
        &self,
        sp: &StationaryParams,
        tf: TileFn,
        m: &Mat,
        out: &mut Mat,
        r2_panel: Option<&Mat>,
    ) {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        assert_eq!(out.shape(), (n, t), "stationary_tiles_into_mixed: output shape");
        let x: &Mat = &self.x;
        let xt: &Mat = &self.xt;
        let xnorm: &[f64] = &self.xnorm;
        // stage M → f32 once per product (grow-only; warm products are
        // allocation-free). Taken out of the lock so the parallel region
        // below can share it immutably.
        let mut m32 = std::mem::take(&mut *self.m32_staging.lock().unwrap());
        m32.clear();
        m32.extend(m.data().iter().map(|&v| v as f32));
        let m32_ref: &[f32] = &m32;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            chunk.iter_mut().for_each(|v| *v = 0.0);
            let rows_here = chunk.len() / t.max(1);
            scratch::with(GROUP * n, |r2buf| {
                scratch::with_f32(GROUP * n, |kbuf| {
                    let mut r0 = 0;
                    while r0 < rows_here {
                        let g = GROUP.min(rows_here - r0);
                        for rr in 0..g {
                            let i = row_lo + r0 + rr;
                            let krow = &mut kbuf[rr * n..(rr + 1) * n];
                            match r2_panel {
                                Some(panel) => stationary_apply_f32(sp, tf, panel.row(i), krow),
                                None => {
                                    let r2row = &mut r2buf[rr * n..(rr + 1) * n];
                                    squared_dists_row(x, xt, xnorm, i, r2row);
                                    stationary_apply_f32(sp, tf, r2row, krow);
                                }
                            }
                        }
                        gemm::gemm_mixed_into(
                            &kbuf[..g * n],
                            m32_ref,
                            &mut chunk[r0 * t..(r0 + g) * t],
                            g,
                            n,
                            t,
                        );
                        r0 += g;
                    }
                });
            });
        });
        *self.m32_staging.lock().unwrap() = m32;
    }

    /// Generic-kernel tile path: build TILE rows by virtual evaluation,
    /// contract through the GEMM micro-kernel.
    fn generic_tiles_into(&self, m: &Mat, out: &mut Mat) {
        let n = self.x.rows();
        let t = m.cols();
        let kern = self.kernel.as_ref();
        let x: &Mat = &self.x;
        let mdata = m.data();
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            chunk.iter_mut().for_each(|v| *v = 0.0);
            let rows_here = chunk.len() / t.max(1);
            scratch::with(TILE * n, |ktile| {
                let mut r0 = 0;
                while r0 < rows_here {
                    let rt = TILE.min(rows_here - r0);
                    for rr in 0..rt {
                        let xi = x.row(row_lo + r0 + rr);
                        let krow = &mut ktile[rr * n..(rr + 1) * n];
                        for (j, kv) in krow.iter_mut().enumerate() {
                            *kv = kern.eval(xi, x.row(j));
                        }
                    }
                    gemm::gemm_into(
                        &ktile[..rt * n],
                        mdata,
                        &mut chunk[r0 * t..(r0 + rt) * t],
                        rt,
                        n,
                        t,
                    );
                    r0 += rt;
                }
            });
        });
    }
}

/// Cross-kernel matrix `K(A, B)` for any kernel — stationary fast path
/// when available, generic parallel eval otherwise. Shared by the dense
/// and sharded operators.
pub(crate) fn cross_kernel(kernel: &dyn Kernel, a: &Mat, b: &Mat) -> Mat {
    if let Some(sp) = kernel.stationary() {
        return cross_stationary(&sp, a, b);
    }
    let mut out = Mat::zeros(a.rows(), b.rows());
    let bref = &b;
    par::parallel_rows_mut(out.data_mut(), a.rows(), b.rows(), |row_lo, chunk| {
        for (ri, orow) in chunk.chunks_mut(b.rows()).enumerate() {
            let xa = a.row(row_lo + ri);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = kernel.eval(xa, bref.row(j));
            }
        }
    });
    out
}

/// Vectorised stationary cross-covariance `K(A, B)`.
fn cross_stationary(sp: &StationaryParams, a: &Mat, b: &Mat) -> Mat {
    let (na, nb, d) = (a.rows(), b.rows(), a.cols());
    assert_eq!(b.cols(), d);
    let bt = b.transpose();
    let bnorm: Vec<f64> = (0..nb).map(|j| b.row(j).iter().map(|v| v * v).sum()).collect();
    let mut out = Mat::zeros(na, nb);
    let (bt_ref, bnorm_ref) = (&bt, &bnorm);
    par::parallel_rows_mut(out.data_mut(), na, nb, |row_lo, chunk| {
        let mut r2 = vec![0.0f64; nb];
        for (ri, orow) in chunk.chunks_mut(nb).enumerate() {
            let xa = a.row(row_lo + ri);
            let anorm: f64 = xa.iter().map(|v| v * v).sum();
            r2.iter_mut().for_each(|v| *v = 0.0);
            for dd in 0..d {
                let xv = xa[dd];
                if xv == 0.0 {
                    continue;
                }
                let btrow = bt_ref.row(dd);
                for j in 0..nb {
                    r2[j] += xv * btrow[j];
                }
            }
            for j in 0..nb {
                r2[j] = (anorm + bnorm_ref[j] - 2.0 * r2[j]).max(0.0);
            }
            stationary_apply(sp, TileFn::Value, &r2, orow);
        }
    });
    out
}

/// Tile size (rows of K produced at once). 64 rows × n cols of f64 stays in
/// L2 for n up to ~8k while amortising the tile's kernel evaluations.
const TILE: usize = 64;

impl LinearOp for KernelCovOp {
    fn shape(&self) -> (usize, usize) {
        (self.x.rows(), self.x.rows())
    }

    fn n_params(&self) -> usize {
        self.kernel.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = Mat::zeros(self.x.rows(), m.cols());
        self.matmul_into(m, &mut out);
        out
    }

    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        assert_eq!(out.shape(), (n, m.cols()), "matmul_into: output shape");
        if self.plan == MmmPlan::MaterializeK {
            // K built once per hyperparameter setting; the product is one
            // register-blocked GEMM
            return self.k_panel().matmul_into(m, out);
        }
        if let Some(sp) = self.kernel.stationary() {
            let panel = (self.plan == MmmPlan::CachedDistances).then(|| self.r2_panel());
            if self.mixed_active() {
                return self.stationary_tiles_into_mixed(&sp, TileFn::Value, m, out, panel);
            }
            return self.stationary_tiles_into(&sp, TileFn::Value, m, out, panel);
        }
        // CachedDistances has no meaning without stationary structure:
        // stream (the plan degrades, it never lies). The same degradation
        // applies to Mixed precision — the generic path computes in f64.
        self.generic_tiles_into(m, out);
    }

    fn prepare(&self) {
        match self.plan {
            MmmPlan::Stream => {}
            MmmPlan::CachedDistances => {
                if self.kernel.stationary().is_some() {
                    let _ = self.r2_panel();
                }
            }
            MmmPlan::MaterializeK => {
                let _ = self.k_panel();
            }
        }
    }

    fn mmm_tag(&self) -> u64 {
        // plan in the low byte, precision above it — a precision switch
        // re-fingerprints the operator just like a plan switch, so
        // SolvePlanCache never serves a plan built at the other precision
        self.plan.tag() | (self.precision.tag() << 8)
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        let nk = self.kernel.n_params();
        assert!(param < nk);
        if let Some(sp) = self.kernel.stationary() {
            // stationary layout: param 0 = log ℓ, param 1 = log s;
            // ∂K/∂log s = K. Derivative tiles derive from the SAME cached
            // r² panel as value tiles (one distance pass per training step
            // instead of 1 + n_params); MaterializeK caches only K, so its
            // derivative products stream.
            let tf = if param == 0 {
                TileFn::DLogLengthscale
            } else {
                TileFn::Value
            };
            let mut out = Mat::zeros(n, t);
            let panel = (self.plan == MmmPlan::CachedDistances).then(|| self.r2_panel());
            // mixed_active (not a raw precision check): under MaterializeK
            // the value products are bit-exact f64 GEMMs against the cached
            // panel, so the streamed derivative products must stay f64 too —
            // a gradient computed at lower precision than its objective
            // would silently skew training
            if self.mixed_active() {
                self.stationary_tiles_into_mixed(&sp, tf, m, &mut out, panel);
            } else {
                self.stationary_tiles_into(&sp, tf, m, &mut out, panel);
            }
            return out;
        }
        let mut out = Mat::zeros(n, t);
        let kern = self.kernel.as_ref();
        let x: &Mat = &self.x;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            let rows_here = chunk.len() / t.max(1);
            let mut grad = vec![0.0f64; nk];
            for r in 0..rows_here {
                let xi = x.row(row_lo + r);
                let orow = &mut chunk[r * t..(r + 1) * t];
                for j in 0..n {
                    kern.eval_grad(xi, x.row(j), &mut grad);
                    let g = grad[param];
                    if g == 0.0 {
                        continue;
                    }
                    let mrow = m.row(j);
                    for c in 0..t {
                        orow[c] += g * mrow[c];
                    }
                }
            }
        });
        out
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.x.rows())
            .map(|i| self.kernel.eval(self.x.row(i), self.x.row(i)))
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let xi = self.x.row(i);
        (0..self.x.rows())
            .map(|j| self.kernel.eval(xi, self.x.row(j)))
            .collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.x.row(i), self.x.row(j))
    }

    fn dense(&self) -> Mat {
        cross_kernel(self.kernel.as_ref(), &self.x, &self.x)
    }
}

impl KernelCov for KernelCovOp {
    fn x(&self) -> &Mat {
        &self.x
    }

    fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    fn set_kernel_params(&mut self, raw: &[f64]) {
        self.kernel.set_params(raw);
        // the materialised K is for the OLD parameters; the r² panel is
        // parameter-free and survives
        *self.kmat.get_mut().unwrap() = None;
    }
}

/// Exact training operator `K̂ = K(X,X) + σ²I` — a named wrapper over the
/// composition `AddedDiagOp(KernelCovOp)`. Raw parameter layout:
/// `[kernel params…, log σ²]`.
pub struct DenseKernelOp {
    op: AddedDiagOp<KernelCovOp>,
}

impl DenseKernelOp {
    /// Compose `K(X,X) + noise·I`.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        DenseKernelOp {
            op: AddedDiagOp::new(KernelCovOp::new(x, kernel), noise),
        }
    }

    /// Builder override of the covariance tile precision (see
    /// [`KernelCovOp::with_precision`]; the default is the process-wide
    /// [`mmm::default_precision`]).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.op.inner_mut().set_precision(precision);
        self
    }

    /// Training inputs.
    pub fn x(&self) -> &Mat {
        self.op.inner().x()
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.op.inner().kernel()
    }

    /// The noise-free covariance part of the composition.
    pub fn cov(&self) -> &KernelCovOp {
        self.op.inner()
    }

    /// Full raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel().params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite all raw parameters.
    pub fn set_params(&mut self, raw: &[f64]) {
        assert_eq!(raw.len(), LinearOp::n_params(self));
        let nk = self.kernel().n_params();
        self.op.inner_mut().set_kernel_params(&raw[..nk]);
        self.op.set_raw_value(raw[nk]);
    }

    /// Cross-kernel matrix `K(A, B)` for arbitrary point sets (predictions).
    pub fn cross(&self, a: &Mat, b: &Mat) -> Mat {
        self.op.inner().cross(a, b)
    }
}

impl LinearOp for DenseKernelOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.op.n_params()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.op.dmatmul(param, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::stationary::{Matern52, Rbf};
    use crate::util::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.2)), 0.1)
    }

    #[test]
    fn matmul_matches_dense_materialisation() {
        let op = setup(50, 3, 1);
        let kdense = op.dense();
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(50, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = kdense.matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn full_operator_semantics_include_noise_on_diagonal() {
        let op = setup(10, 2, 3);
        let kd = op.dense();
        // full-operator row/diag include σ²; the noise-free part is
        // reachable through the composition's noise_split
        let krow = op.row(0);
        assert!((kd.get(0, 0) - krow[0]).abs() < 1e-12);
        assert!((kd.get(0, 1) - krow[1]).abs() < 1e-12);
        let (cov, sigma2) = op.noise_split().unwrap();
        assert!((sigma2 - 0.1).abs() < 1e-12);
        assert!((cov.row(0)[0] + sigma2 - krow[0]).abs() < 1e-12);
        assert!((op.diag()[0] - krow[0]).abs() < 1e-12);
        assert!((op.noise() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn dmatmul_matches_finite_differences() {
        let n = 25;
        let mut op = setup(n, 2, 4);
        let mut rng = Rng::new(5);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..LinearOp::n_params(&op) {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 1e-4,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }

    #[test]
    fn matern_operator_consistent() {
        let mut rng = Rng::new(6);
        let x = Mat::from_fn(30, 2, |_, _| rng.uniform());
        let op = DenseKernelOp::new(x, Box::new(Matern52::new(0.4, 0.9)), 0.05);
        let m = Mat::from_fn(30, 3, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn cross_kernel_matches_eval() {
        let op = setup(8, 2, 7);
        let mut rng = Rng::new(8);
        let xs = Mat::from_fn(5, 2, |_, _| rng.uniform());
        let c = op.cross(&xs, op.x());
        for i in 0..5 {
            for j in 0..8 {
                let want = op.kernel().eval(xs.row(i), op.x().row(j));
                // fast path uses the |a|²+|b|²−2ab expansion + fast_exp:
                // agreement to ~1e-10, not bitwise
                assert!((c.get(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn mixed_precision_tracks_f64_per_plan() {
        let mut rng = Rng::new(11);
        let x = Mat::from_fn(70, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(70, 4, |_, _| rng.normal());
        for plan in [MmmPlan::Stream, MmmPlan::CachedDistances] {
            let op64 = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2))).with_plan(plan);
            let opmx = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)))
                .with_plan(plan)
                .with_precision(Precision::Mixed);
            assert!(opmx.mixed_active());
            assert_ne!(op64.mmm_tag(), opmx.mmm_tag(), "precision must re-tag");
            let want = op64.matmul(&m);
            let got = opmx.matmul(&m);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "{}: mixed vs f64 diff {}",
                plan.name(),
                got.max_abs_diff(&want)
            );
            // derivative tiles ride the same mixed path
            let dwant = op64.dmatmul(0, &m);
            let dgot = opmx.dmatmul(0, &m);
            assert!(dgot.max_abs_diff(&dwant) < 1e-3, "{}: dmatmul", plan.name());
        }
        // Matern exercises the sqrt/u epilogues
        let op64 = KernelCovOp::new(x.clone(), Box::new(Matern52::new(0.4, 0.9)));
        let opmx = KernelCovOp::new(x.clone(), Box::new(Matern52::new(0.4, 0.9)))
            .with_precision(Precision::Mixed);
        assert!(opmx.matmul(&m).max_abs_diff(&op64.matmul(&m)) < 1e-3);
    }

    #[test]
    fn mixed_precision_degrades_to_f64_when_it_cannot_apply() {
        let mut rng = Rng::new(12);
        let x = Mat::from_fn(24, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(24, 2, |_, _| rng.normal());
        // MaterializeK has no f32 tile path: Mixed must be bit-identical
        let op64 = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)))
            .with_plan(MmmPlan::MaterializeK);
        let opmx = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)))
            .with_plan(MmmPlan::MaterializeK)
            .with_precision(Precision::Mixed);
        assert!(!opmx.mixed_active());
        assert_eq!(opmx.matmul(&m).max_abs_diff(&op64.matmul(&m)), 0.0);
        // derivative products stream under MaterializeK — they must degrade
        // to f64 with the value products, not run mixed on their own
        assert_eq!(opmx.dmatmul(0, &m).max_abs_diff(&op64.dmatmul(0, &m)), 0.0);
        // …but the tag still distinguishes them (plans must not be shared)
        assert_ne!(op64.mmm_tag(), opmx.mmm_tag());
    }

    #[test]
    fn tile_boundaries_are_exact() {
        // n larger than TILE exercises multiple tiles per thread chunk
        let op = setup(3 * super::TILE + 7, 2, 9);
        let n = op.n();
        let mut rng = Rng::new(10);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }
}
