//! Exact-GP covariance operators: the fused noise-free [`KernelCovOp`]
//! (`K(X,X)·M` without ever materialising the n×n matrix) and the model
//! composition [`DenseKernelOp`] = `AddedDiagOp(KernelCovOp)` = `K + σ²I`.
//!
//! The fused matmul is the Rust analogue of the L1 Pallas kernel
//! (`python/compile/kernels/kernel_matmul.py`): rows of K are produced one
//! cache-tile at a time and immediately contracted against `M`, so peak
//! memory is O(n·t + tile·n) instead of O(n²). Parallel over row tiles.

use super::{Kernel, KernelCov, StationaryFamily, StationaryParams};
use crate::linalg::op::{AddedDiagOp, LinearOp};
use crate::tensor::Mat;
use crate::util::fastmath::fast_exp;
use crate::util::par;

/// Which function of r² a stationary tile evaluates (shared with the
/// sharded operator in [`super::sharded`]).
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum TileFn {
    /// k(r)
    Value,
    /// ∂k/∂log ℓ
    DLogLengthscale,
}

/// Vectorised stationary-kernel row: given squared distances `r2`, write
/// `out[j] = f(r2[j])` for the family/derivative requested. This is the
/// scalar-free inner loop of the fused mat-mul fast path — everything here
/// autovectorizes (fast_exp is branch-free, sqrt is an instruction).
pub(crate) fn stationary_apply(sp: &StationaryParams, tf: TileFn, r2: &[f64], out: &mut [f64]) {
    let s = sp.outputscale;
    let ls = sp.lengthscale;
    match (sp.family, tf) {
        (StationaryFamily::Rbf, TileFn::Value) => {
            let a = 1.0 / (2.0 * ls * ls);
            for j in 0..r2.len() {
                out[j] = s * fast_exp(-a * r2[j]);
            }
        }
        (StationaryFamily::Rbf, TileFn::DLogLengthscale) => {
            let a = 1.0 / (2.0 * ls * ls);
            let b = 1.0 / (ls * ls);
            for j in 0..r2.len() {
                out[j] = s * fast_exp(-a * r2[j]) * (b * r2[j]);
            }
        }
        (StationaryFamily::Matern12, TileFn::Value) => {
            let c = 1.0 / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * fast_exp(-u);
            }
        }
        (StationaryFamily::Matern12, TileFn::DLogLengthscale) => {
            let c = 1.0 / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * fast_exp(-u) * u;
            }
        }
        (StationaryFamily::Matern32, TileFn::Value) => {
            let c = 3f64.sqrt() / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * (1.0 + u) * fast_exp(-u);
            }
        }
        (StationaryFamily::Matern32, TileFn::DLogLengthscale) => {
            let c = 3f64.sqrt() / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * u * u * fast_exp(-u);
            }
        }
        (StationaryFamily::Matern52, TileFn::Value) => {
            let c = 5f64.sqrt() / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * (1.0 + u + u * u / 3.0) * fast_exp(-u);
            }
        }
        (StationaryFamily::Matern52, TileFn::DLogLengthscale) => {
            let c = 5f64.sqrt() / ls;
            for j in 0..r2.len() {
                let u = c * r2[j].sqrt();
                out[j] = s * fast_exp(-u) * u * u * (1.0 + u) / 3.0;
            }
        }
    }
}

/// `r2[j] = |xᵢ|² + |xⱼ|² − 2·xᵢᵀxⱼ` for row `i` against the cached
/// transpose `xt (d×n)` and per-row norms, clamped at 0 against rounding —
/// the distance pass shared by the fused stationary operators (dense and
/// [`super::sharded`]). d vectorised axpy passes, streaming over j.
pub(crate) fn squared_dists_row(x: &Mat, xt: &Mat, xnorm: &[f64], i: usize, r2: &mut [f64]) {
    let n = x.rows();
    let d = x.cols();
    let xi = x.row(i);
    r2.iter_mut().for_each(|v| *v = 0.0);
    for dd in 0..d {
        let xv = xi[dd];
        if xv == 0.0 {
            continue;
        }
        let xtrow = xt.row(dd);
        for j in 0..n {
            r2[j] += xv * xtrow[j];
        }
    }
    let xin = xnorm[i];
    for j in 0..n {
        r2[j] = (xin + xnorm[j] - 2.0 * r2[j]).max(0.0);
    }
}

/// Noise-free exact covariance operator `K(X, X)` over a training set
/// `X (n×d)` — the fused stationary fast path lives here; composing with
/// [`AddedDiagOp`] yields the training operator `K̂ = K + σ²I`.
pub struct KernelCovOp {
    x: Mat,
    kernel: Box<dyn Kernel>,
    /// cached Xᵀ (d×n): the distance pass streams over j
    xt: Mat,
    /// cached per-row squared norms |xᵢ|²
    xnorm: Vec<f64>,
}

impl KernelCovOp {
    /// Build over training inputs and a covariance function.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>) -> Self {
        let xt = x.transpose();
        let xnorm: Vec<f64> = (0..x.rows())
            .map(|i| x.row(i).iter().map(|v| v * v).sum())
            .collect();
        KernelCovOp {
            x,
            kernel,
            xt,
            xnorm,
        }
    }

    /// Fused stationary mat-mul: `K·M` or `(∂K/∂log ℓ)·M`, with r² blocks
    /// built by vectorised rank-d updates (no virtual calls, no K).
    fn stationary_matmul(&self, sp: &StationaryParams, m: &Mat, tf: TileFn) -> Mat {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        let x = &self.x;
        let mt = m.transpose(); // t×n: contraction becomes length-n dots
        let mut out = Mat::zeros(n, t);
        let xnorm_ref = &self.xnorm;
        let xt_ref = &self.xt;
        let mt_ref = &mt;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            let mut dots = vec![0.0f64; n];
            let mut krow = vec![0.0f64; n];
            for (ri, orow) in chunk.chunks_mut(t).enumerate() {
                let i = row_lo + ri;
                squared_dists_row(x, xt_ref, xnorm_ref, i, &mut dots);
                stationary_apply(sp, tf, &dots, &mut krow);
                // orow[c] = ⟨krow, Mᵀ[c]⟩ — t fully-vectorised n-dots
                for (c, o) in orow.iter_mut().enumerate() {
                    let mtrow = mt_ref.row(c);
                    let mut acc = 0.0;
                    for j in 0..n {
                        acc += krow[j] * mtrow[j];
                    }
                    *o = acc;
                }
            }
        });
        out
    }
}

/// Cross-kernel matrix `K(A, B)` for any kernel — stationary fast path
/// when available, generic parallel eval otherwise. Shared by the dense
/// and sharded operators.
pub(crate) fn cross_kernel(kernel: &dyn Kernel, a: &Mat, b: &Mat) -> Mat {
    if let Some(sp) = kernel.stationary() {
        return cross_stationary(&sp, a, b);
    }
    let mut out = Mat::zeros(a.rows(), b.rows());
    let bref = &b;
    par::parallel_rows_mut(out.data_mut(), a.rows(), b.rows(), |row_lo, chunk| {
        for (ri, orow) in chunk.chunks_mut(b.rows()).enumerate() {
            let xa = a.row(row_lo + ri);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = kernel.eval(xa, bref.row(j));
            }
        }
    });
    out
}

/// Vectorised stationary cross-covariance `K(A, B)`.
fn cross_stationary(sp: &StationaryParams, a: &Mat, b: &Mat) -> Mat {
    let (na, nb, d) = (a.rows(), b.rows(), a.cols());
    assert_eq!(b.cols(), d);
    let bt = b.transpose();
    let bnorm: Vec<f64> = (0..nb).map(|j| b.row(j).iter().map(|v| v * v).sum()).collect();
    let mut out = Mat::zeros(na, nb);
    let (bt_ref, bnorm_ref) = (&bt, &bnorm);
    par::parallel_rows_mut(out.data_mut(), na, nb, |row_lo, chunk| {
        let mut r2 = vec![0.0f64; nb];
        for (ri, orow) in chunk.chunks_mut(nb).enumerate() {
            let xa = a.row(row_lo + ri);
            let anorm: f64 = xa.iter().map(|v| v * v).sum();
            r2.iter_mut().for_each(|v| *v = 0.0);
            for dd in 0..d {
                let xv = xa[dd];
                if xv == 0.0 {
                    continue;
                }
                let btrow = bt_ref.row(dd);
                for j in 0..nb {
                    r2[j] += xv * btrow[j];
                }
            }
            for j in 0..nb {
                r2[j] = (anorm + bnorm_ref[j] - 2.0 * r2[j]).max(0.0);
            }
            stationary_apply(sp, TileFn::Value, &r2, orow);
        }
    });
    out
}

/// Tile size (rows of K produced at once). 64 rows × n cols of f64 stays in
/// L2 for n up to ~8k while amortising the tile's kernel evaluations.
const TILE: usize = 64;

impl LinearOp for KernelCovOp {
    fn shape(&self) -> (usize, usize) {
        (self.x.rows(), self.x.rows())
    }

    fn n_params(&self) -> usize {
        self.kernel.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        if let Some(sp) = self.kernel.stationary() {
            return self.stationary_matmul(&sp, m, TileFn::Value);
        }
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        let mut out = Mat::zeros(n, t);
        let kern = self.kernel.as_ref();
        let x = &self.x;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            let rows_here = chunk.len() / t;
            // process TILE rows at a time: build K-tile, contract against M
            let mut ktile = vec![0.0f64; TILE * n];
            let mut r0 = 0;
            while r0 < rows_here {
                let rt = TILE.min(rows_here - r0);
                for rr in 0..rt {
                    let xi = x.row(row_lo + r0 + rr);
                    let krow = &mut ktile[rr * n..(rr + 1) * n];
                    for (j, kv) in krow.iter_mut().enumerate() {
                        *kv = kern.eval(xi, x.row(j));
                    }
                }
                // contract: out[r, :] = K[r, :] · M
                for rr in 0..rt {
                    let krow = &ktile[rr * n..(rr + 1) * n];
                    let orow = &mut chunk[(r0 + rr) * t..(r0 + rr + 1) * t];
                    for (j, &kv) in krow.iter().enumerate() {
                        let mrow = m.row(j);
                        for c in 0..t {
                            orow[c] += kv * mrow[c];
                        }
                    }
                }
                r0 += rt;
            }
        });
        out
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let n = self.x.rows();
        assert_eq!(m.rows(), n);
        let t = m.cols();
        let nk = self.kernel.n_params();
        assert!(param < nk);
        if let Some(sp) = self.kernel.stationary() {
            // stationary layout: param 0 = log ℓ, param 1 = log s;
            // ∂K/∂log s = K
            let tf = if param == 0 {
                TileFn::DLogLengthscale
            } else {
                TileFn::Value
            };
            return self.stationary_matmul(&sp, m, tf);
        }
        let mut out = Mat::zeros(n, t);
        let kern = self.kernel.as_ref();
        let x = &self.x;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            let rows_here = chunk.len() / t;
            let mut grad = vec![0.0f64; nk];
            for r in 0..rows_here {
                let xi = x.row(row_lo + r);
                let orow = &mut chunk[r * t..(r + 1) * t];
                for j in 0..n {
                    kern.eval_grad(xi, x.row(j), &mut grad);
                    let g = grad[param];
                    if g == 0.0 {
                        continue;
                    }
                    let mrow = m.row(j);
                    for c in 0..t {
                        orow[c] += g * mrow[c];
                    }
                }
            }
        });
        out
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.x.rows())
            .map(|i| self.kernel.eval(self.x.row(i), self.x.row(i)))
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let xi = self.x.row(i);
        (0..self.x.rows())
            .map(|j| self.kernel.eval(xi, self.x.row(j)))
            .collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.x.row(i), self.x.row(j))
    }

    fn dense(&self) -> Mat {
        cross_kernel(self.kernel.as_ref(), &self.x, &self.x)
    }
}

impl KernelCov for KernelCovOp {
    fn x(&self) -> &Mat {
        &self.x
    }

    fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    fn set_kernel_params(&mut self, raw: &[f64]) {
        self.kernel.set_params(raw);
    }
}

/// Exact training operator `K̂ = K(X,X) + σ²I` — a named wrapper over the
/// composition `AddedDiagOp(KernelCovOp)`. Raw parameter layout:
/// `[kernel params…, log σ²]`.
pub struct DenseKernelOp {
    op: AddedDiagOp<KernelCovOp>,
}

impl DenseKernelOp {
    /// Compose `K(X,X) + noise·I`.
    pub fn new(x: Mat, kernel: Box<dyn Kernel>, noise: f64) -> Self {
        DenseKernelOp {
            op: AddedDiagOp::new(KernelCovOp::new(x, kernel), noise),
        }
    }

    /// Training inputs.
    pub fn x(&self) -> &Mat {
        self.op.inner().x()
    }

    /// The covariance function.
    pub fn kernel(&self) -> &dyn Kernel {
        self.op.inner().kernel()
    }

    /// The noise-free covariance part of the composition.
    pub fn cov(&self) -> &KernelCovOp {
        self.op.inner()
    }

    /// Full raw parameter vector `[kernel params…, log σ²]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel().params();
        p.push(self.op.raw_value());
        p
    }

    /// Overwrite all raw parameters.
    pub fn set_params(&mut self, raw: &[f64]) {
        assert_eq!(raw.len(), LinearOp::n_params(self));
        let nk = self.kernel().n_params();
        self.op.inner_mut().set_kernel_params(&raw[..nk]);
        self.op.set_raw_value(raw[nk]);
    }

    /// Cross-kernel matrix `K(A, B)` for arbitrary point sets (predictions).
    pub fn cross(&self, a: &Mat, b: &Mat) -> Mat {
        self.op.inner().cross(a, b)
    }
}

impl LinearOp for DenseKernelOp {
    crate::linear_op_delegate!(op);

    fn n_params(&self) -> usize {
        self.op.n_params()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.op.dmatmul(param, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::stationary::{Matern52, Rbf};
    use crate::util::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.2)), 0.1)
    }

    #[test]
    fn matmul_matches_dense_materialisation() {
        let op = setup(50, 3, 1);
        let kdense = op.dense();
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(50, 4, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = kdense.matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn full_operator_semantics_include_noise_on_diagonal() {
        let op = setup(10, 2, 3);
        let kd = op.dense();
        // full-operator row/diag include σ²; the noise-free part is
        // reachable through the composition's noise_split
        let krow = op.row(0);
        assert!((kd.get(0, 0) - krow[0]).abs() < 1e-12);
        assert!((kd.get(0, 1) - krow[1]).abs() < 1e-12);
        let (cov, sigma2) = op.noise_split().unwrap();
        assert!((sigma2 - 0.1).abs() < 1e-12);
        assert!((cov.row(0)[0] + sigma2 - krow[0]).abs() < 1e-12);
        assert!((op.diag()[0] - krow[0]).abs() < 1e-12);
        assert!((op.noise() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn dmatmul_matches_finite_differences() {
        let n = 25;
        let mut op = setup(n, 2, 4);
        let mut rng = Rng::new(5);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let raw = op.params();
        let h = 1e-6;
        for p in 0..LinearOp::n_params(&op) {
            let analytic = op.dmatmul(p, &m);
            let mut plus = raw.clone();
            plus[p] += h;
            op.set_params(&plus);
            let fp = op.matmul(&m);
            let mut minus = raw.clone();
            minus[p] -= h;
            op.set_params(&minus);
            let fm = op.matmul(&m);
            op.set_params(&raw);
            let mut fd = fp.sub(&fm);
            fd.scale_assign(1.0 / (2.0 * h));
            assert!(
                analytic.max_abs_diff(&fd) < 1e-4,
                "param {p}: {}",
                analytic.max_abs_diff(&fd)
            );
        }
    }

    #[test]
    fn matern_operator_consistent() {
        let mut rng = Rng::new(6);
        let x = Mat::from_fn(30, 2, |_, _| rng.uniform());
        let op = DenseKernelOp::new(x, Box::new(Matern52::new(0.4, 0.9)), 0.05);
        let m = Mat::from_fn(30, 3, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn cross_kernel_matches_eval() {
        let op = setup(8, 2, 7);
        let mut rng = Rng::new(8);
        let xs = Mat::from_fn(5, 2, |_, _| rng.uniform());
        let c = op.cross(&xs, op.x());
        for i in 0..5 {
            for j in 0..8 {
                let want = op.kernel().eval(xs.row(i), op.x().row(j));
                // fast path uses the |a|²+|b|²−2ab expansion + fast_exp:
                // agreement to ~1e-10, not bitwise
                assert!((c.get(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn tile_boundaries_are_exact() {
        // n larger than TILE exercises multiple tiles per thread chunk
        let op = setup(3 * super::TILE + 7, 2, 9);
        let n = op.n();
        let mut rng = Rng::new(10);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let got = op.matmul(&m);
        let want = op.dense().matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }
}
