//! Row-major dense matrix with a parallel, register-blocked GEMM.

use super::gemm;
use super::scalar::Scalar;
use crate::util::par;

/// Row-major dense matrix over a [`Scalar`] (f32 or f64).
#[derive(Clone, PartialEq)]
pub struct Mat<T: Scalar = f64> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

impl<T: Scalar> std::fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat<{}x{}> [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for r in 0..show_r {
            write!(f, "  ")?;
            for c in 0..show_c {
                write!(f, "{:10.4} ", self.get(r, c).to_f64())?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl<T: Scalar> Mat<T> {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![T::ZERO; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, T::ONE);
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Reclaim the underlying row-major buffer (the allocation-free
    /// round-trip workspaces use: move a scratch `Vec` into a shaped `Mat`
    /// with [`Mat::from_vec`], compute, and take the buffer back).
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Overwrite from a same-shaped matrix without reallocating.
    pub fn copy_from(&mut self, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape(), "copy_from: shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_from_slice(v: &[T]) -> Self {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<T> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Overwrite column `c`.
    pub fn set_col(&mut self, c: usize, v: &[T]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self.set(r, c, v[r]);
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat<T> {
        let mut out = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Matrix product `self · other` — the BBMM hot path. Parallel over
    /// output-row chunks; each chunk runs the register-blocked
    /// [`gemm::gemm_into`] micro-kernel.
    pub fn matmul(&self, other: &Mat<T>) -> Mat<T> {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `self · other` written into a caller-owned output (overwritten) —
    /// the zero-allocation seam the solver workspaces use. `out` must be
    /// pre-shaped to `(self.rows, other.cols)`.
    pub fn matmul_into(&self, other: &Mat<T>, out: &mut Mat<T>) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(
            out.shape(),
            (self.rows, other.cols),
            "matmul_into: output shape mismatch"
        );
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let a = &self.data;
        let b = &other.data;
        par::parallel_rows_mut(&mut out.data, m, n, |row_lo, chunk| {
            chunk.iter_mut().for_each(|v| *v = T::ZERO);
            let rows_here = chunk.len() / n.max(1);
            gemm::gemm_into(&a[row_lo * k..(row_lo + rows_here) * k], b, chunk, rows_here, k, n);
        });
    }

    /// `selfᵀ · other` without materialising the transpose.
    pub fn t_matmul(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // out[i,j] = sum_r a[r,i] * b[r,j]: rank-1 updates over r, split
        // across threads with per-thread accumulators (summed at the end).
        let nt = par::num_threads().min(k).max(1);
        if nt <= 1 || m * n < 1024 {
            gemm::gemm_atb_into(&self.data, &other.data, &mut out.data, k, m, n);
            return out;
        }
        let chunk = k.div_ceil(nt);
        let n_parts = k.div_ceil(chunk);
        // per-thread partials as pseudo-rows of one flat buffer, so the
        // existing disjoint-rows parallel driver distributes them
        let mut partials = vec![T::ZERO; n_parts * m * n];
        let a = &self.data;
        let b = &other.data;
        par::parallel_rows_mut(&mut partials, n_parts, m * n, |part_lo, pchunk| {
            for (pi, acc) in pchunk.chunks_mut(m * n).enumerate() {
                let lo = (part_lo + pi) * chunk;
                let hi = (lo + chunk).min(k);
                gemm::gemm_atb_into(&a[lo * m..hi * m], &b[lo * n..hi * n], acc, hi - lo, m, n);
            }
        });
        for p in partials.chunks(m * n) {
            for (o, &v) in out.data.iter_mut().zip(p) {
                *o += v;
            }
        }
        out
    }

    /// `self · otherᵀ` without materialising the transpose.
    pub fn matmul_t(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        let a = &self.data;
        let b = &other.data;
        par::parallel_rows_mut(&mut out.data, m, n, |row_lo, chunk| {
            let rows_here = chunk.len() / n.max(1);
            let a_rows = &a[row_lo * k..(row_lo + rows_here) * k];
            gemm::gemm_abt_into(a_rows, b, chunk, rows_here, k, n);
        });
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[T]) -> Vec<T> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        let mut out = vec![T::ZERO; self.rows];
        par::parallel_rows_mut(&mut out, self.rows, 1, |row_lo, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = gemm::dot(self.row(row_lo + i), v);
            }
        });
        out
    }

    /// self += other
    pub fn add_assign(&mut self, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    /// self -= other
    pub fn sub_assign(&mut self, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= *b;
        }
    }

    /// self *= alpha
    pub fn scale_assign(&mut self, alpha: T) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// self + other
    pub fn add(&self, other: &Mat<T>) -> Mat<T> {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    /// self - other
    pub fn sub(&self, other: &Mat<T>) -> Mat<T> {
        let mut out = self.clone();
        out.sub_assign(other);
        out
    }

    /// Add `alpha` to the diagonal in place (the paper's `K̂ = K + σ²I`).
    pub fn add_diag(&mut self, alpha: T) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += alpha;
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data
            .iter()
            .map(|v| v.to_f64() * v.to_f64())
            .sum::<f64>()
            .sqrt()
    }

    /// Max |entry| difference against another matrix.
    pub fn max_abs_diff(&self, other: &Mat<T>) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }

    /// Convert precision.
    pub fn cast<U: Scalar>(&self) -> Mat<U> {
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|v| U::from_f64(v.to_f64())).collect(),
        )
    }

    /// Columns `lo..hi` as a new matrix.
    pub fn cols_range(&self, lo: usize, hi: usize) -> Mat<T> {
        assert!(lo <= hi && hi <= self.cols);
        Mat::from_fn(self.rows, hi - lo, |r, c| self.get(r, lo + c))
    }

    /// Symmetrise in place: self = (self + selfᵀ)/2 (guards drift in kernels).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        let half = T::from_f64(0.5);
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                let v = (self.get(r, c) + self.get(c, r)) * half;
                self.set(r, c, v);
                self.set(c, r, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                out.set(i, j, s);
            }
        }
        out
    }

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = crate::util::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64), (130, 70, 33)] {
            let a = rand_mat(m, k, 1);
            let b = rand_mat(k, n, 2);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = rand_mat(40, 7, 3);
        let b = rand_mat(40, 11, 4);
        let got = a.t_matmul(&b);
        let want = naive_matmul(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn t_matmul_parallel_path() {
        // large enough to trigger the threaded branch
        let a = rand_mat(300, 50, 5);
        let b = rand_mat(300, 60, 6);
        let got = a.t_matmul(&b);
        let want = naive_matmul(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matmul_t_matches() {
        let a = rand_mat(13, 21, 7);
        let b = rand_mat(17, 21, 8);
        let got = a.matmul_t(&b);
        let want = naive_matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = rand_mat(30, 20, 9);
        let v: Vec<f64> = (0..20).map(|i| i as f64 * 0.1 - 1.0).collect();
        let got = a.matvec(&v);
        let want = a.matmul(&Mat::col_from_slice(&v));
        for i in 0..30 {
            assert!((got[i] - want.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let a = rand_mat(15, 15, 10);
        let i = Mat::eye(15);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn transpose_involution() {
        let a = rand_mat(9, 14, 11);
        assert!(a.transpose().transpose().max_abs_diff(&a) == 0.0);
    }

    #[test]
    fn add_diag_and_symmetrize() {
        let mut a = rand_mat(6, 6, 12);
        let before = a.get(2, 2);
        a.add_diag(0.5);
        assert!((a.get(2, 2) - before - 0.5).abs() < 1e-15);
        a.symmetrize();
        for r in 0..6 {
            for c in 0..6 {
                assert_eq!(a.get(r, c), a.get(c, r));
            }
        }
    }

    #[test]
    fn f32_matmul_works() {
        let a: Mat<f32> = rand_mat(20, 20, 13).cast();
        let b: Mat<f32> = rand_mat(20, 20, 14).cast();
        let got = a.matmul(&b);
        let want64 = rand_mat(20, 20, 13).matmul(&rand_mat(20, 20, 14));
        assert!(got.cast::<f64>().max_abs_diff(&want64) < 1e-3);
    }

    #[test]
    fn cols_range_extracts() {
        let a = rand_mat(5, 8, 15);
        let sub = a.cols_range(2, 5);
        assert_eq!(sub.shape(), (5, 3));
        for r in 0..5 {
            for c in 0..3 {
                assert_eq!(sub.get(r, c), a.get(r, c + 2));
            }
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::<f64>::zeros(2, 3);
        let b = Mat::<f64>::zeros(2, 3);
        a.matmul(&b);
    }
}
