//! Explicit SIMD lanes behind **runtime dispatch** — the compute arms the
//! streaming kernel-MMM engine selects from at process start.
//!
//! LLVM autovectorises the portable kernels in [`super::gemm`] well enough
//! on a good day, but the mBCG hot path cannot depend on a good day: this
//! module pins the 4×8 GEMM register tile, the mixed-precision
//! f32-compute/f64-accumulate tile, and the batched `exp()` used by
//! stationary kernel rows to explicit AVX2/FMA (x86_64) or NEON (aarch64)
//! intrinsics. The scalar fallback is **always compiled** and always
//! correct; the SIMD arms are selected once per process:
//!
//! - `BBMM_FORCE_SCALAR=1` forces the scalar arm (the CI leg and the
//!   debugging knob),
//! - on x86_64, `is_x86_feature_detected!("avx2")` + `"fma"` selects
//!   [`Dispatch::Avx2Fma`] (4 × f64 / 8 × f32 lanes),
//! - on aarch64, NEON is part of the baseline ABI, so [`Dispatch::Neon`]
//!   (2 × f64 / 4 × f32 lanes) is selected unconditionally.
//!
//! Every public entry point is **safe**: it checks [`active`] itself and
//! reports (via `bool`/prefix-length returns) when the caller must run the
//! portable fallback instead. The `#[target_feature]` internals are only
//! reachable after detection confirmed the features, which is exactly the
//! soundness contract those functions require.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which lane implementation the process selected at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Portable scalar/autovectorised fallback (always compiled; forced
    /// by `BBMM_FORCE_SCALAR`).
    Scalar,
    /// AVX2 + FMA 256-bit lanes (x86_64, detected at runtime).
    Avx2Fma,
    /// NEON 128-bit lanes (aarch64 baseline).
    Neon,
}

impl Dispatch {
    /// Short name for logs, bench tables, and the serve banner.
    pub fn name(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2Fma => "avx2+fma",
            Dispatch::Neon => "neon",
        }
    }

    /// f64 elements per vector register under this arm.
    pub fn lanes_f64(self) -> usize {
        match self {
            Dispatch::Scalar => 1,
            Dispatch::Avx2Fma => 4,
            Dispatch::Neon => 2,
        }
    }

    /// f32 elements per vector register under this arm (twice the f64
    /// width — the reason Mixed precision exists).
    pub fn lanes_f32(self) -> usize {
        match self {
            Dispatch::Scalar => 1,
            Dispatch::Avx2Fma => 8,
            Dispatch::Neon => 4,
        }
    }
}

const UNSET: u8 = 0;
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);

fn encode(d: Dispatch) -> u8 {
    match d {
        Dispatch::Scalar => 1,
        Dispatch::Avx2Fma => 2,
        Dispatch::Neon => 3,
    }
}

fn decode(v: u8) -> Option<Dispatch> {
    match v {
        1 => Some(Dispatch::Scalar),
        2 => Some(Dispatch::Avx2Fma),
        3 => Some(Dispatch::Neon),
        _ => None,
    }
}

/// The active dispatch arm (detected on first call, then cached — one
/// relaxed atomic load per query, so hot loops may hoist but need not).
pub fn active() -> Dispatch {
    match decode(ACTIVE.load(Ordering::Relaxed)) {
        Some(d) => d,
        None => {
            let d = detect();
            ACTIVE.store(encode(d), Ordering::Relaxed);
            d
        }
    }
}

/// `BBMM_FORCE_SCALAR` set to anything but `""`/`"0"` forces the scalar
/// arm — the debugging/CI knob documented in the README.
fn forced_scalar_env() -> bool {
    std::env::var("BBMM_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

fn detect() -> Dispatch {
    if forced_scalar_env() {
        return Dispatch::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Dispatch::Avx2Fma;
        }
    }
    if cfg!(target_arch = "aarch64") {
        // NEON is mandatory in the aarch64 baseline ABI — no runtime probe
        return Dispatch::Neon;
    }
    Dispatch::Scalar
}

/// Test/debug hook: force the scalar arm (`true`) or re-run detection
/// (`false`, which still honours `BBMM_FORCE_SCALAR`). Takes effect for
/// every subsequent [`active`] query process-wide.
pub fn set_forced_scalar(forced: bool) {
    let d = if forced { Dispatch::Scalar } else { detect() };
    ACTIVE.store(encode(d), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Safe dispatched entry points
// ---------------------------------------------------------------------------

/// `out (m×n) += A (m×k) · B (k×n)` in f64 through the active SIMD arm.
/// Returns `false` under scalar dispatch — the caller runs the portable
/// kernel in [`super::gemm`] instead.
#[inline]
pub fn gemm_f64(a: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) -> bool {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => {
            unsafe { avx2::gemm_f64(a, b, out, m, k, n) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => {
            unsafe { neon::gemm_f64(a, b, out, m, k, n) };
            true
        }
        _ => {
            let _ = (&a, &b, &out, m, k, n);
            false
        }
    }
}

/// `out (m×n) += A (m×k) · B (k×n)` in f32 through the active SIMD arm
/// (double the lane count of [`gemm_f64`]). Returns `false` under scalar
/// dispatch.
#[inline]
pub fn gemm_f32(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) -> bool {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => {
            unsafe { avx2::gemm_f32(a, b, out, m, k, n) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => {
            unsafe { neon::gemm_f32(a, b, out, m, k, n) };
            true
        }
        _ => {
            let _ = (&a, &b, &out, m, k, n);
            false
        }
    }
}

/// Mixed-precision tile: `out (m×n, f64) += A (m×k, f32) · B (k×n, f32)`,
/// products and register accumulation in f32 (full lane count), widened
/// into the f64 output once per `KB`-sized k-block — the compute mode of
/// [`crate::linalg::op::mmm::Precision::Mixed`]. Returns `false` under
/// scalar dispatch.
#[inline]
pub fn gemm_mixed(a: &[f32], b: &[f32], out: &mut [f64], m: usize, k: usize, n: usize) -> bool {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => {
            unsafe { avx2::gemm_mixed(a, b, out, m, k, n) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => {
            unsafe { neon::gemm_mixed(a, b, out, m, k, n) };
            true
        }
        _ => {
            let _ = (&a, &b, &out, m, k, n);
            false
        }
    }
}

/// In-place `x[i] = e^{x[i]}` over the longest lane-aligned prefix of `x`
/// through the active SIMD arm. Returns the number of leading elements
/// processed (a multiple of the f64 lane width; `0` under scalar dispatch)
/// — the caller finishes the tail with the scalar `fast_exp`.
#[inline]
pub fn exp_f64_prefix(x: &mut [f64]) -> usize {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => unsafe { avx2::exp_f64(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => unsafe { neon::exp_f64(x) },
        _ => {
            let _ = &x;
            0
        }
    }
}

/// f32 twin of [`exp_f64_prefix`] (twice the lane width; ~1e-7 relative
/// accuracy — the Mixed tile path's batched exp).
#[inline]
pub fn exp_f32_prefix(x: &mut [f32]) -> usize {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => unsafe { avx2::exp_f32(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => unsafe { neon::exp_f32(x) },
        _ => {
            let _ = &x;
            0
        }
    }
}

/// Contiguous f64 dot product through the active SIMD arm (four FMA
/// accumulator chains, matching the portable kernel's latency hiding).
/// Returns `None` under scalar dispatch — the caller runs the portable
/// 4-accumulator kernel instead. The mBCG α/β reductions and
/// `vecops::dot` route through here.
#[inline]
pub fn dot_f64(a: &[f64], b: &[f64]) -> Option<f64> {
    debug_assert_eq!(a.len(), b.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => Some(unsafe { avx2::dot_f64(a, b) }),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => Some(unsafe { neon::dot_f64(a, b) }),
        _ => {
            let _ = (&a, &b);
            None
        }
    }
}

/// `y += α·x` in f64 through the active SIMD arm. Returns `false` under
/// scalar dispatch — the caller runs the portable unrolled loop.
#[inline]
pub fn axpy_f64(alpha: f64, x: &[f64], y: &mut [f64]) -> bool {
    debug_assert_eq!(x.len(), y.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU
        Dispatch::Avx2Fma => {
            unsafe { avx2::axpy_f64(alpha, x, y) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64
        Dispatch::Neon => {
            unsafe { neon::axpy_f64(alpha, x, y) };
            true
        }
        _ => {
            let _ = (alpha, &x, &y);
            false
        }
    }
}

/// Strided f64 dot: `Σₖ a[offset + k·stride] · b[offset + k·stride]` for
/// `k ∈ [0, count)` — one matrix column of a row-major `count×stride`
/// buffer. Vectorised only on AVX2 (lane-composed loads + FMA chains);
/// NEON has no gather and its 2-lane compose gains nothing over the
/// portable 4-accumulator kernel, so it returns `None` like scalar
/// dispatch. Never allocates — safe inside the mBCG zero-alloc loop.
#[inline]
pub fn dot_strided_f64(
    a: &[f64],
    b: &[f64],
    offset: usize,
    stride: usize,
    count: usize,
) -> Option<f64> {
    debug_assert!(stride > 0);
    debug_assert!(count == 0 || offset + (count - 1) * stride < a.len());
    debug_assert!(count == 0 || offset + (count - 1) * stride < b.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection confirmed avx2+fma on this CPU; bounds checked above
        Dispatch::Avx2Fma => Some(unsafe { avx2::dot_strided_f64(a, b, offset, stride, count) }),
        _ => {
            let _ = (&a, &b, offset, stride, count);
            None
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA arm (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use crate::tensor::gemm::{KB, MR, NR};
    use crate::util::fastmath::{
        EXP_HI_F32, EXP_HI_F64, EXP_LO_F32, EXP_LO_F64, EXP_POLY_F32, EXP_POLY_F64, LN2_HI_F32,
        LN2_HI_F64, LN2_LO_F32, LN2_LO_F64,
    };
    use core::arch::x86_64::*;

    /// `MR_×NR` f64 tile: two 4-lane accumulator vectors per row, FMA
    /// contraction over `kb`, added into `out` once at the end.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tile_f64<const MR_: usize>(
        a: *const f64,
        lda: usize,
        b: *const f64,
        ldb: usize,
        out: *mut f64,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc0 = [_mm256_setzero_pd(); MR_];
        let mut acc1 = [_mm256_setzero_pd(); MR_];
        for kk in 0..kb {
            let bp = b.add(kk * ldb);
            let b0 = _mm256_loadu_pd(bp);
            let b1 = _mm256_loadu_pd(bp.add(4));
            for i in 0..MR_ {
                let av = _mm256_set1_pd(*a.add(i * lda + kk));
                acc0[i] = _mm256_fmadd_pd(av, b0, acc0[i]);
                acc1[i] = _mm256_fmadd_pd(av, b1, acc1[i]);
            }
        }
        for i in 0..MR_ {
            let op = out.add(i * ldo);
            _mm256_storeu_pd(op, _mm256_add_pd(_mm256_loadu_pd(op), acc0[i]));
            _mm256_storeu_pd(op.add(4), _mm256_add_pd(_mm256_loadu_pd(op.add(4)), acc1[i]));
        }
    }

    /// `MR_×NR` f32 tile: one 8-lane accumulator vector per row.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tile_f32<const MR_: usize>(
        a: *const f32,
        lda: usize,
        b: *const f32,
        ldb: usize,
        out: *mut f32,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc = [_mm256_setzero_ps(); MR_];
        for kk in 0..kb {
            let bv = _mm256_loadu_ps(b.add(kk * ldb));
            for i in 0..MR_ {
                let av = _mm256_set1_ps(*a.add(i * lda + kk));
                acc[i] = _mm256_fmadd_ps(av, bv, acc[i]);
            }
        }
        for i in 0..MR_ {
            let op = out.add(i * ldo);
            _mm256_storeu_ps(op, _mm256_add_ps(_mm256_loadu_ps(op), acc[i]));
        }
    }

    /// Mixed tile: f32 FMA accumulation (8 lanes), both halves widened to
    /// f64 and added into the output — once per tile call, so the caller's
    /// `KB` blocking bounds the f32 accumulation length.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tile_mixed<const MR_: usize>(
        a: *const f32,
        lda: usize,
        b: *const f32,
        ldb: usize,
        out: *mut f64,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc = [_mm256_setzero_ps(); MR_];
        for kk in 0..kb {
            let bv = _mm256_loadu_ps(b.add(kk * ldb));
            for i in 0..MR_ {
                let av = _mm256_set1_ps(*a.add(i * lda + kk));
                acc[i] = _mm256_fmadd_ps(av, bv, acc[i]);
            }
        }
        for i in 0..MR_ {
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(acc[i]));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(acc[i]));
            let op = out.add(i * ldo);
            _mm256_storeu_pd(op, _mm256_add_pd(_mm256_loadu_pd(op), lo));
            _mm256_storeu_pd(op.add(4), _mm256_add_pd(_mm256_loadu_pd(op.add(4)), hi));
        }
    }

    /// The blocked f64 driver — the same `KB`/`MR`/`NR` walk as the
    /// portable `gemm_into`, with the micro-kernel pinned to FMA lanes.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_f64(a: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_f64::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_f64::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_f64::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_f64::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    // remainder columns (< NR): scalar, FMA-contracted by LLVM
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += av * bv;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// The blocked f32 driver (8 lanes per accumulator row).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_f32(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_f32::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_f32::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_f32::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_f32::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += av * bv;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// The blocked mixed driver: f32 inputs, f64 accumulation at `KB`
    /// granularity (error per entry ≤ KB·ε₃₂ ≈ 1.5e-5 · |row|·|col|).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_mixed(a: &[f32], b: &[f32], out: &mut [f64], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_mixed::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_mixed::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_mixed::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_mixed::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    // remainder columns: f32 products widened per element
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += (av * bv) as f64;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// 4-lane `e^x` over the aligned prefix of `x`, in place. Same range
    /// reduction + degree-9 Horner polynomial as the scalar `fast_exp`
    /// (shared coefficient tables), with round-to-nearest `k` extracted by
    /// the shift-add magic-number trick and `2^k` assembled in the
    /// exponent bits. Returns the prefix length processed.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_f64(x: &mut [f64]) -> usize {
        let len = x.len() - x.len() % 4;
        let lo = _mm256_set1_pd(EXP_LO_F64);
        let hi = _mm256_set1_pd(EXP_HI_F64);
        let log2e = _mm256_set1_pd(std::f64::consts::LOG2_E);
        let ln2_hi = _mm256_set1_pd(LN2_HI_F64);
        let ln2_lo = _mm256_set1_pd(LN2_LO_F64);
        // 1.5·2^52: adding it pushes the integer part of a small float
        // into the low mantissa bits (round-to-nearest), so the two's-
        // complement k is the bit difference from the magic constant
        let magic = _mm256_set1_pd(6755399441055744.0);
        let magic_bits = _mm256_set1_epi64x(0x4338000000000000u64 as i64);
        let bias = _mm256_set1_epi64x(1023);
        let mut i = 0;
        while i < len {
            let p = x.as_mut_ptr().add(i);
            let v = _mm256_min_pd(_mm256_max_pd(_mm256_loadu_pd(p), lo), hi);
            // k = round(x·log2 e) with matching float and integer forms
            let t = _mm256_add_pd(_mm256_mul_pd(v, log2e), magic);
            let ki = _mm256_sub_epi64(_mm256_castpd_si256(t), magic_bits);
            let kf = _mm256_sub_pd(t, magic);
            // r = x − k·ln 2 in two pieces
            let r = _mm256_fnmadd_pd(kf, ln2_hi, v);
            let r = _mm256_fnmadd_pd(kf, ln2_lo, r);
            // Horner over the shared coefficient table
            let mut poly = _mm256_set1_pd(EXP_POLY_F64[0]);
            for &c in &EXP_POLY_F64[1..] {
                poly = _mm256_fmadd_pd(poly, r, _mm256_set1_pd(c));
            }
            // 2^k through the exponent bits (k ∈ [−1022, 1023] after clamp)
            let scale =
                _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(ki, bias)));
            _mm256_storeu_pd(p, _mm256_mul_pd(poly, scale));
            i += 4;
        }
        len
    }

    /// 8-lane f32 `e^x` over the aligned prefix of `x`, in place
    /// (~1e-7 relative). Returns the prefix length processed.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_f32(x: &mut [f32]) -> usize {
        let len = x.len() - x.len() % 8;
        let lo = _mm256_set1_ps(EXP_LO_F32);
        let hi = _mm256_set1_ps(EXP_HI_F32);
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let ln2_hi = _mm256_set1_ps(LN2_HI_F32);
        let ln2_lo = _mm256_set1_ps(LN2_LO_F32);
        let bias = _mm256_set1_epi32(127);
        let mut i = 0;
        while i < len {
            let p = x.as_mut_ptr().add(i);
            let v = _mm256_min_ps(_mm256_max_ps(_mm256_loadu_ps(p), lo), hi);
            // cvtps_epi32 rounds to nearest under the default MXCSR mode
            let ki = _mm256_cvtps_epi32(_mm256_mul_ps(v, log2e));
            let kf = _mm256_cvtepi32_ps(ki);
            let r = _mm256_fnmadd_ps(kf, ln2_hi, v);
            let r = _mm256_fnmadd_ps(kf, ln2_lo, r);
            let mut poly = _mm256_set1_ps(EXP_POLY_F32[0]);
            for &c in &EXP_POLY_F32[1..] {
                poly = _mm256_fmadd_ps(poly, r, _mm256_set1_ps(c));
            }
            let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(ki, bias)));
            _mm256_storeu_ps(p, _mm256_mul_ps(poly, scale));
            i += 8;
        }
        len
    }

    /// Horizontal sum of a 4-lane f64 vector.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum_pd(v: __m256d) -> f64 {
        let hi = _mm256_extractf128_pd::<1>(v);
        let lo = _mm256_castpd256_pd128(v);
        let s = _mm_add_pd(lo, hi);
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    /// Contiguous dot with four 4-lane FMA chains (16 elements in flight).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        let mut i = 0;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 4)),
                _mm256_loadu_pd(pb.add(i + 4)),
                acc1,
            );
            acc2 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 8)),
                _mm256_loadu_pd(pb.add(i + 8)),
                acc2,
            );
            acc3 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 12)),
                _mm256_loadu_pd(pb.add(i + 12)),
                acc3,
            );
            i += 16;
        }
        while i + 4 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)), acc0);
            i += 4;
        }
        let mut s = hsum_pd(_mm256_add_pd(_mm256_add_pd(acc0, acc1), _mm256_add_pd(acc2, acc3)));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }

    /// `y += α·x`, two 4-lane FMA stores per step.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_f64(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        let av = _mm256_set1_pd(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let p0 = py.add(i);
            let p1 = py.add(i + 4);
            _mm256_storeu_pd(
                p0,
                _mm256_fmadd_pd(av, _mm256_loadu_pd(px.add(i)), _mm256_loadu_pd(p0)),
            );
            _mm256_storeu_pd(
                p1,
                _mm256_fmadd_pd(av, _mm256_loadu_pd(px.add(i + 4)), _mm256_loadu_pd(p1)),
            );
            i += 8;
        }
        while i + 4 <= n {
            let p0 = py.add(i);
            _mm256_storeu_pd(
                p0,
                _mm256_fmadd_pd(av, _mm256_loadu_pd(px.add(i)), _mm256_loadu_pd(p0)),
            );
            i += 4;
        }
        while i < n {
            *py.add(i) += alpha * *px.add(i);
            i += 1;
        }
    }

    /// Strided column dot: lane-composed loads (`set_pd` of four strided
    /// scalars — cheaper and safer than a gather on every µarch this
    /// targets) feeding two FMA chains.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_strided_f64(
        a: &[f64],
        b: &[f64],
        offset: usize,
        stride: usize,
        count: usize,
    ) -> f64 {
        let pa = a.as_ptr().add(offset);
        let pb = b.as_ptr().add(offset);
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut k = 0;
        while k + 8 <= count {
            let qa = pa.add(k * stride);
            let qb = pb.add(k * stride);
            // set_pd takes lanes high-to-low
            let va0 = _mm256_set_pd(*qa.add(3 * stride), *qa.add(2 * stride), *qa.add(stride), *qa);
            let vb0 = _mm256_set_pd(*qb.add(3 * stride), *qb.add(2 * stride), *qb.add(stride), *qb);
            let qa = qa.add(4 * stride);
            let qb = qb.add(4 * stride);
            let va1 = _mm256_set_pd(*qa.add(3 * stride), *qa.add(2 * stride), *qa.add(stride), *qa);
            let vb1 = _mm256_set_pd(*qb.add(3 * stride), *qb.add(2 * stride), *qb.add(stride), *qb);
            acc0 = _mm256_fmadd_pd(va0, vb0, acc0);
            acc1 = _mm256_fmadd_pd(va1, vb1, acc1);
            k += 8;
        }
        let mut s = hsum_pd(_mm256_add_pd(acc0, acc1));
        while k < count {
            s += *pa.add(k * stride) * *pb.add(k * stride);
            k += 1;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// NEON arm (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use crate::tensor::gemm::{KB, MR, NR};
    use crate::util::fastmath::{
        EXP_HI_F32, EXP_HI_F64, EXP_LO_F32, EXP_LO_F64, EXP_POLY_F32, EXP_POLY_F64, LN2_HI_F32,
        LN2_HI_F64, LN2_LO_F32, LN2_LO_F64,
    };
    use core::arch::aarch64::*;

    /// `MR_×NR` f64 tile: four 2-lane accumulator vectors per row.
    #[target_feature(enable = "neon")]
    unsafe fn tile_f64<const MR_: usize>(
        a: *const f64,
        lda: usize,
        b: *const f64,
        ldb: usize,
        out: *mut f64,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc = [[vdupq_n_f64(0.0); 4]; MR_];
        for kk in 0..kb {
            let bp = b.add(kk * ldb);
            let b0 = vld1q_f64(bp);
            let b1 = vld1q_f64(bp.add(2));
            let b2 = vld1q_f64(bp.add(4));
            let b3 = vld1q_f64(bp.add(6));
            for i in 0..MR_ {
                let av = vdupq_n_f64(*a.add(i * lda + kk));
                acc[i][0] = vfmaq_f64(acc[i][0], av, b0);
                acc[i][1] = vfmaq_f64(acc[i][1], av, b1);
                acc[i][2] = vfmaq_f64(acc[i][2], av, b2);
                acc[i][3] = vfmaq_f64(acc[i][3], av, b3);
            }
        }
        for i in 0..MR_ {
            let op = out.add(i * ldo);
            for v in 0..4 {
                let o = op.add(2 * v);
                vst1q_f64(o, vaddq_f64(vld1q_f64(o), acc[i][v]));
            }
        }
    }

    /// `MR_×NR` f32 tile: two 4-lane accumulator vectors per row.
    #[target_feature(enable = "neon")]
    unsafe fn tile_f32<const MR_: usize>(
        a: *const f32,
        lda: usize,
        b: *const f32,
        ldb: usize,
        out: *mut f32,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc = [[vdupq_n_f32(0.0); 2]; MR_];
        for kk in 0..kb {
            let bp = b.add(kk * ldb);
            let b0 = vld1q_f32(bp);
            let b1 = vld1q_f32(bp.add(4));
            for i in 0..MR_ {
                let av = vdupq_n_f32(*a.add(i * lda + kk));
                acc[i][0] = vfmaq_f32(acc[i][0], av, b0);
                acc[i][1] = vfmaq_f32(acc[i][1], av, b1);
            }
        }
        for i in 0..MR_ {
            let op = out.add(i * ldo);
            vst1q_f32(op, vaddq_f32(vld1q_f32(op), acc[i][0]));
            vst1q_f32(op.add(4), vaddq_f32(vld1q_f32(op.add(4)), acc[i][1]));
        }
    }

    /// Mixed tile: f32 accumulation, both halves of each vector widened
    /// to f64 and added into the output once per tile call.
    #[target_feature(enable = "neon")]
    unsafe fn tile_mixed<const MR_: usize>(
        a: *const f32,
        lda: usize,
        b: *const f32,
        ldb: usize,
        out: *mut f64,
        ldo: usize,
        kb: usize,
    ) {
        let mut acc = [[vdupq_n_f32(0.0); 2]; MR_];
        for kk in 0..kb {
            let bp = b.add(kk * ldb);
            let b0 = vld1q_f32(bp);
            let b1 = vld1q_f32(bp.add(4));
            for i in 0..MR_ {
                let av = vdupq_n_f32(*a.add(i * lda + kk));
                acc[i][0] = vfmaq_f32(acc[i][0], av, b0);
                acc[i][1] = vfmaq_f32(acc[i][1], av, b1);
            }
        }
        for i in 0..MR_ {
            let op = out.add(i * ldo);
            for v in 0..2 {
                let lo = vcvt_f64_f32(vget_low_f32(acc[i][v]));
                let hi = vcvt_high_f64_f32(acc[i][v]);
                let o = op.add(4 * v);
                vst1q_f64(o, vaddq_f64(vld1q_f64(o), lo));
                vst1q_f64(o.add(2), vaddq_f64(vld1q_f64(o.add(2)), hi));
            }
        }
    }

    /// The blocked f64 driver (see the AVX2 twin for the walk).
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_f64(a: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_f64::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_f64::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_f64::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_f64::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += av * bv;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// The blocked f32 driver.
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_f32(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_f32::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_f32::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_f32::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_f32::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += av * bv;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// The blocked mixed driver: f32 inputs, f64 accumulation at `KB`
    /// granularity.
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_mixed(a: &[f32], b: &[f32], out: &mut [f64], m: usize, k: usize, n: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut i0 = 0;
            while i0 < m {
                let mh = MR.min(m - i0);
                let mut j0 = 0;
                while j0 + NR <= n {
                    let ap = a.as_ptr().add(i0 * k + k0);
                    let bp = b.as_ptr().add(k0 * n + j0);
                    let op = out.as_mut_ptr().add(i0 * n + j0);
                    match mh {
                        4 => tile_mixed::<4>(ap, k, bp, n, op, n, kb),
                        3 => tile_mixed::<3>(ap, k, bp, n, op, n, kb),
                        2 => tile_mixed::<2>(ap, k, bp, n, op, n, kb),
                        _ => tile_mixed::<1>(ap, k, bp, n, op, n, kb),
                    }
                    j0 += NR;
                }
                if j0 < n {
                    for ii in 0..mh {
                        let r = i0 + ii;
                        for kk in 0..kb {
                            let av = a[r * k + k0 + kk];
                            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                            let orow = &mut out[r * n + j0..r * n + n];
                            for (o, &bv) in orow.iter_mut().zip(brow) {
                                *o += (av * bv) as f64;
                            }
                        }
                    }
                }
                i0 += mh;
            }
            k0 += kb;
        }
    }

    /// 2-lane f64 `e^x` over the aligned prefix of `x`, in place.
    #[target_feature(enable = "neon")]
    pub unsafe fn exp_f64(x: &mut [f64]) -> usize {
        let len = x.len() - x.len() % 2;
        let lo = vdupq_n_f64(EXP_LO_F64);
        let hi = vdupq_n_f64(EXP_HI_F64);
        let log2e = vdupq_n_f64(std::f64::consts::LOG2_E);
        let ln2_hi = vdupq_n_f64(LN2_HI_F64);
        let ln2_lo = vdupq_n_f64(LN2_LO_F64);
        let bias = vdupq_n_s64(1023);
        let mut i = 0;
        while i < len {
            let p = x.as_mut_ptr().add(i);
            let v = vminq_f64(vmaxq_f64(vld1q_f64(p), lo), hi);
            let ki = vcvtnq_s64_f64(vmulq_f64(v, log2e)); // round to nearest
            let kf = vcvtq_f64_s64(ki);
            let r = vfmsq_f64(v, kf, ln2_hi);
            let r = vfmsq_f64(r, kf, ln2_lo);
            let mut poly = vdupq_n_f64(EXP_POLY_F64[0]);
            for &c in &EXP_POLY_F64[1..] {
                poly = vfmaq_f64(vdupq_n_f64(c), poly, r);
            }
            let scale = vreinterpretq_f64_s64(vshlq_n_s64::<52>(vaddq_s64(ki, bias)));
            vst1q_f64(p, vmulq_f64(poly, scale));
            i += 2;
        }
        len
    }

    /// 4-lane f32 `e^x` over the aligned prefix of `x`, in place.
    #[target_feature(enable = "neon")]
    pub unsafe fn exp_f32(x: &mut [f32]) -> usize {
        let len = x.len() - x.len() % 4;
        let lo = vdupq_n_f32(EXP_LO_F32);
        let hi = vdupq_n_f32(EXP_HI_F32);
        let log2e = vdupq_n_f32(std::f32::consts::LOG2_E);
        let ln2_hi = vdupq_n_f32(LN2_HI_F32);
        let ln2_lo = vdupq_n_f32(LN2_LO_F32);
        let bias = vdupq_n_s32(127);
        let mut i = 0;
        while i < len {
            let p = x.as_mut_ptr().add(i);
            let v = vminq_f32(vmaxq_f32(vld1q_f32(p), lo), hi);
            let ki = vcvtnq_s32_f32(vmulq_f32(v, log2e));
            let kf = vcvtq_f32_s32(ki);
            let r = vfmsq_f32(v, kf, ln2_hi);
            let r = vfmsq_f32(r, kf, ln2_lo);
            let mut poly = vdupq_n_f32(EXP_POLY_F32[0]);
            for &c in &EXP_POLY_F32[1..] {
                poly = vfmaq_f32(vdupq_n_f32(c), poly, r);
            }
            let scale = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(ki, bias)));
            vst1q_f32(p, vmulq_f32(poly, scale));
            i += 4;
        }
        len
    }

    /// Contiguous dot with four 2-lane FMA chains (8 elements in flight).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = vdupq_n_f64(0.0);
        let mut acc1 = vdupq_n_f64(0.0);
        let mut acc2 = vdupq_n_f64(0.0);
        let mut acc3 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vfmaq_f64(acc0, vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i)));
            acc1 = vfmaq_f64(acc1, vld1q_f64(pa.add(i + 2)), vld1q_f64(pb.add(i + 2)));
            acc2 = vfmaq_f64(acc2, vld1q_f64(pa.add(i + 4)), vld1q_f64(pb.add(i + 4)));
            acc3 = vfmaq_f64(acc3, vld1q_f64(pa.add(i + 6)), vld1q_f64(pb.add(i + 6)));
            i += 8;
        }
        while i + 2 <= n {
            acc0 = vfmaq_f64(acc0, vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i)));
            i += 2;
        }
        let mut s = vaddvq_f64(vaddq_f64(vaddq_f64(acc0, acc1), vaddq_f64(acc2, acc3)));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }

    /// `y += α·x`, two 2-lane FMA stores per step.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f64(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        let av = vdupq_n_f64(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let p0 = py.add(i);
            let p1 = py.add(i + 2);
            vst1q_f64(p0, vfmaq_f64(vld1q_f64(p0), av, vld1q_f64(px.add(i))));
            vst1q_f64(p1, vfmaq_f64(vld1q_f64(p1), av, vld1q_f64(px.add(i + 2))));
            i += 4;
        }
        while i + 2 <= n {
            let p0 = py.add(i);
            vst1q_f64(p0, vfmaq_f64(vld1q_f64(p0), av, vld1q_f64(px.add(i))));
            i += 2;
        }
        while i < n {
            *py.add(i) += alpha * *px.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_f64(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    #[test]
    fn lane_widths_are_consistent() {
        assert_eq!(Dispatch::Scalar.lanes_f64(), 1);
        assert_eq!(Dispatch::Scalar.lanes_f32(), 1);
        assert_eq!(Dispatch::Avx2Fma.lanes_f64(), 4);
        assert_eq!(Dispatch::Avx2Fma.lanes_f32(), 8);
        assert_eq!(Dispatch::Neon.lanes_f64(), 2);
        assert_eq!(Dispatch::Neon.lanes_f32(), 4);
        for d in [Dispatch::Scalar, Dispatch::Avx2Fma, Dispatch::Neon] {
            assert_eq!(d.lanes_f32(), 2 * d.lanes_f64(), "{}", d.name());
        }
    }

    #[test]
    fn forced_scalar_toggle_roundtrips() {
        let before = active();
        set_forced_scalar(true);
        assert_eq!(active(), Dispatch::Scalar);
        set_forced_scalar(false);
        assert_eq!(active(), before, "un-forcing must restore detection");
    }

    #[test]
    fn simd_gemm_f64_matches_naive_tightly() {
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 9, 7), (9, 300, 15), (12, 257, 17)] {
            let a = rand_f64(m * k, 1 + (m * k) as u64);
            let b = rand_f64(k * n, 2 + (k * n) as u64);
            let mut out = vec![0.0; m * n];
            if !gemm_f64(&a, &b, &mut out, m, k, n) {
                return; // scalar dispatch: nothing to compare against
            }
            let want = naive(&a, &b, m, k, n);
            for i in 0..m * n {
                // FMA vs mul+add differ only at rounding level
                assert!(
                    (out[i] - want[i]).abs() < 1e-12 * (1.0 + want[i].abs()),
                    "({m},{k},{n}) entry {i}: {} vs {}",
                    out[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn simd_gemm_f32_and_mixed_track_f64() {
        let (m, k, n) = (7, 257, 11);
        let a = rand_f64(m * k, 31);
        let b = rand_f64(k * n, 32);
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let want = naive(&a, &b, m, k, n);
        let mut out32 = vec![0.0f32; m * n];
        if gemm_f32(&a32, &b32, &mut out32, m, k, n) {
            for i in 0..m * n {
                assert!((out32[i] as f64 - want[i]).abs() < 5e-4 * (1.0 + want[i].abs()));
            }
        }
        let mut outm = vec![0.0f64; m * n];
        if gemm_mixed(&a32, &b32, &mut outm, m, k, n) {
            for i in 0..m * n {
                assert!(
                    (outm[i] - want[i]).abs() < 5e-4 * (1.0 + want[i].abs()),
                    "mixed entry {i}: {} vs {}",
                    outm[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn simd_exp_matches_libm() {
        let mut xs: Vec<f64> = Vec::new();
        let mut v = -60.0;
        while v <= 4.0 {
            xs.push(v);
            v += 0.173;
        }
        let want: Vec<f64> = xs.iter().map(|&x| x.exp()).collect();
        let done = exp_f64_prefix(&mut xs);
        assert_eq!(done % active().lanes_f64().max(1), 0);
        for i in 0..done {
            let rel = (xs[i] - want[i]).abs() / want[i];
            assert!(rel < 5e-10, "exp_f64[{i}] rel err {rel}");
        }
        let mut xs32: Vec<f32> = (0..257).map(|i| -40.0 + 0.17 * i as f32).collect();
        let want32: Vec<f32> = xs32.iter().map(|&x| x.exp()).collect();
        let done = exp_f32_prefix(&mut xs32);
        for i in 0..done {
            let rel = ((xs32[i] - want32[i]) / want32[i]).abs();
            assert!(rel < 3e-7, "exp_f32[{i}] rel err {rel}");
        }
    }

    #[test]
    fn simd_dot_matches_scalar() {
        for &n in &[0usize, 1, 3, 4, 15, 16, 17, 64, 257] {
            let a = rand_f64(n, 100 + n as u64);
            let b = rand_f64(n, 200 + n as u64);
            let Some(got) = dot_f64(&a, &b) else {
                return; // scalar dispatch: nothing to compare against
            };
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (got - want).abs() < 1e-12 * (1.0 + want.abs()),
                "dot n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn simd_axpy_matches_scalar() {
        for &n in &[0usize, 1, 5, 8, 9, 64, 131] {
            let x = rand_f64(n, 300 + n as u64);
            let y0 = rand_f64(n, 400 + n as u64);
            let mut y = y0.clone();
            if !axpy_f64(0.37, &x, &mut y) {
                return; // scalar dispatch
            }
            for i in 0..n {
                let want = y0[i] + 0.37 * x[i];
                assert!(
                    (y[i] - want).abs() < 1e-14 * (1.0 + want.abs()),
                    "axpy n={n} i={i}"
                );
            }
        }
    }

    #[test]
    fn simd_strided_dot_matches_scalar() {
        for &(count, stride, offset) in
            &[(1usize, 3usize, 0usize), (7, 1, 0), (8, 5, 2), (33, 4, 1), (50, 7, 3)]
        {
            let len = offset + (count - 1) * stride + 1;
            let a = rand_f64(len, 500 + len as u64);
            let b = rand_f64(len, 600 + len as u64);
            let Some(got) = dot_strided_f64(&a, &b, offset, stride, count) else {
                return; // scalar or NEON dispatch: no strided kernel
            };
            let want: f64 = (0..count)
                .map(|k| a[offset + k * stride] * b[offset + k * stride])
                .sum();
            assert!(
                (got - want).abs() < 1e-12 * (1.0 + want.abs()),
                "strided ({count},{stride},{offset}): {got} vs {want}"
            );
        }
    }

    /// The dispatched vector ops must agree with the portable kernels under
    /// the `BBMM_FORCE_SCALAR` toggle — the same guarantee the CI
    /// forced-scalar job checks for the whole suite.
    #[test]
    fn forced_scalar_disables_vector_ops() {
        let a = rand_f64(40, 900);
        let b = rand_f64(40, 901);
        set_forced_scalar(true);
        assert!(dot_f64(&a, &b).is_none());
        assert!(dot_strided_f64(&a, &b, 0, 2, 20).is_none());
        let mut y = b.clone();
        assert!(!axpy_f64(1.5, &a, &mut y));
        assert_eq!(y, b, "scalar-dispatch axpy must not touch y");
        set_forced_scalar(false);
    }
}
