//! Minimal floating-point abstraction so the numerical core (GEMM, Cholesky,
//! CG, mBCG) can run in both f32 and f64 — Figure 1 of the paper compares
//! solve error across precisions, so the precision must be a parameter.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Floating-point scalar with exactly the operations the BBMM core needs.
pub trait Scalar:
    Copy
    + PartialOrd
    + Debug
    + Display
    + Default
    + Send
    + Sync
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon for this precision.
    const EPS: Self;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn ln(self) -> Self;
    fn exp(self) -> Self;
    fn max_s(self, other: Self) -> Self;
    fn min_s(self, other: Self) -> Self;
    fn is_finite(self) -> bool;
    /// Fused multiply-add `self * a + b` with a single rounding — generic
    /// code can now express FMA chains explicitly instead of hoping LLVM
    /// contracts `a * b + c` (it may not, and contraction is not
    /// guaranteed to be stable across versions).
    fn mul_add(self, a: Self, b: Self) -> Self;
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const EPS: f64 = f64::EPSILON;

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> f64 {
        f64::abs(self)
    }
    #[inline]
    fn sqrt(self) -> f64 {
        f64::sqrt(self)
    }
    #[inline]
    fn ln(self) -> f64 {
        f64::ln(self)
    }
    #[inline]
    fn exp(self) -> f64 {
        f64::exp(self)
    }
    #[inline]
    fn max_s(self, other: f64) -> f64 {
        f64::max(self, other)
    }
    #[inline]
    fn min_s(self, other: f64) -> f64 {
        f64::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
    #[inline]
    fn mul_add(self, a: f64, b: f64) -> f64 {
        f64::mul_add(self, a, b)
    }
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const EPS: f32 = f32::EPSILON;

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> f32 {
        f32::abs(self)
    }
    #[inline]
    fn sqrt(self) -> f32 {
        f32::sqrt(self)
    }
    #[inline]
    fn ln(self) -> f32 {
        f32::ln(self)
    }
    #[inline]
    fn exp(self) -> f32 {
        f32::exp(self)
    }
    #[inline]
    fn max_s(self, other: f32) -> f32 {
        f32::max(self, other)
    }
    #[inline]
    fn min_s(self, other: f32) -> f32 {
        f32::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline]
    fn mul_add(self, a: f32, b: f32) -> f32 {
        f32::mul_add(self, a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Scalar>() {
        assert_eq!(T::from_f64(2.0).to_f64(), 2.0);
        assert_eq!(T::from_f64(4.0).sqrt().to_f64(), 2.0);
        assert!((T::from_f64(1.0).exp().to_f64() - std::f64::consts::E).abs() < 1e-6);
        assert!(T::from_f64(-3.0).abs().to_f64() == 3.0);
        assert!(T::from_f64(f64::NAN).is_finite() == false);
        assert_eq!(
            T::from_f64(2.0).mul_add(T::from_f64(3.0), T::from_f64(1.0)).to_f64(),
            7.0
        );
    }

    #[test]
    fn scalar_f32_f64() {
        roundtrip::<f32>();
        roundtrip::<f64>();
    }
}
