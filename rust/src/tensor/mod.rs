//! Dense-matrix substrate.
//!
//! Row-major matrices generic over [`Scalar`] (f32 / f64). The only hot
//! routine that matters for BBMM is [`Mat::matmul`] — a cache-blocked,
//! thread-parallel GEMM — because every mBCG iteration is one kernel
//! mat-mul plus O(nt) vector work (paper App. B).

pub mod mat;
pub mod scalar;

pub use mat::Mat;
pub use scalar::Scalar;

/// Column-stacked vector helpers over flat `Vec<f64>`s.
pub mod vecops {
    /// dot product
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0;
        for i in 0..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// y += alpha * x
    #[inline]
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        for i in 0..x.len() {
            y[i] += alpha * x[i];
        }
    }

    /// Euclidean norm.
    #[inline]
    pub fn norm2(a: &[f64]) -> f64 {
        dot(a, a).sqrt()
    }

    /// elementwise scale in place
    #[inline]
    pub fn scale(alpha: f64, x: &mut [f64]) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::vecops::*;

    #[test]
    fn vecops_basics() {
        let a = vec![1.0, 2.0, 3.0];
        let mut b = vec![1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 6.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, vec![3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut c = vec![1.0, -2.0];
        scale(3.0, &mut c);
        assert_eq!(c, vec![3.0, -6.0]);
    }
}
