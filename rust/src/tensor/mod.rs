//! Dense-matrix substrate.
//!
//! Row-major matrices generic over [`Scalar`] (f32 / f64). The only hot
//! routine that matters for BBMM is [`Mat::matmul`] — a cache-blocked,
//! thread-parallel GEMM — because every mBCG iteration is one kernel
//! mat-mul plus O(nt) vector work (paper App. B).

pub mod gemm;
pub mod mat;
pub mod scalar;
pub mod simd;

pub use mat::Mat;
pub use scalar::Scalar;

/// Column-stacked vector helpers over flat `Vec<f64>`s, dispatched through
/// [`super::simd`] (AVX2/NEON FMA chains) with a four-accumulator portable
/// fallback — the mBCG α/β reductions are exactly these calls.
pub mod vecops {
    /// dot product — SIMD when the dispatcher has an arm, else the
    /// four-accumulator unroll in [`crate::tensor::gemm::dot`]
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        match super::simd::dot_f64(a, b) {
            Some(s) => s,
            None => super::gemm::dot(a, b),
        }
    }

    /// y += alpha * x — SIMD FMA stores when dispatched, else four
    /// independent update streams per pass
    #[inline]
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        // equal lengths are the contract; a mismatch must fail loudly (the
        // indexing below panics), never silently truncate the update
        debug_assert_eq!(x.len(), y.len());
        if super::simd::axpy_f64(alpha, x, y) {
            return;
        }
        let n = x.len();
        let end = n - n % 4;
        let mut i = 0;
        while i < end {
            y[i] += alpha * x[i];
            y[i + 1] += alpha * x[i + 1];
            y[i + 2] += alpha * x[i + 2];
            y[i + 3] += alpha * x[i + 3];
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// Euclidean norm.
    #[inline]
    pub fn norm2(a: &[f64]) -> f64 {
        dot(a, a).sqrt()
    }

    /// elementwise scale in place
    #[inline]
    pub fn scale(alpha: f64, x: &mut [f64]) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::vecops::*;

    #[test]
    fn vecops_basics() {
        let a = vec![1.0, 2.0, 3.0];
        let mut b = vec![1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 6.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, vec![3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut c = vec![1.0, -2.0];
        scale(3.0, &mut c);
        assert_eq!(c, vec![3.0, -6.0]);
    }
}
