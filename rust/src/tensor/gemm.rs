//! Register-blocked GEMM micro-kernel — the shared compute core under
//! [`super::Mat`]'s products and the fused kernel-tile contractions.
//!
//! BBMM's cost model is "one matrix-matrix product per mBCG iteration"
//! (paper App. B), so the per-entry cost of that product is the whole
//! ballgame. The seed implementation was a scalar triple loop; this module
//! replaces it with a classic register-tiled kernel:
//!
//! - the output is walked in `MR×NR` (4×8) tiles whose 32 accumulators
//!   live in registers for the entire k-sweep — the multi-accumulator
//!   unroll removes the loop-carried dependence so LLVM autovectorises
//!   the inner loop into wide FMA lanes,
//! - `k` is blocked (`KB` = 256) so the `B` panel stays L2-resident,
//! - everything is generic over [`Scalar`] (f32 doubles the lane count),
//! - for the concrete f32/f64 instantiations, [`gemm_into`] routes through
//!   the explicit-SIMD arms in [`super::simd`] (AVX2/FMA or NEON, runtime
//!   dispatched) instead of relying on autovectorisation; the generic
//!   portable kernel below remains the always-compiled fallback. The
//!   mixed-precision [`gemm_mixed_into`] (f32 storage/compute, f64
//!   accumulation) lives here too — it is the compute mode behind
//!   [`crate::linalg::op::mmm::Precision::Mixed`].
//!
//! All entry points are **serial** and write into caller-owned buffers
//! (`out += …`); thread-level parallelism is layered above by splitting
//! output rows across the [`crate::util::par`] worker pool, and the
//! zero-allocation solve paths call these directly with workspace slices.

use super::scalar::Scalar;
use super::simd;
use std::any::TypeId;

/// Register-tile rows (independent accumulator rows per micro-kernel call).
pub const MR: usize = 4;
/// Register-tile columns (contiguous lanes per accumulator row).
pub const NR: usize = 8;
/// k-blocking: `KB × NR` of `B` stays cache-resident across a row sweep.
/// Public because the SIMD arms reuse the same walk, and because `KB` is
/// the f32 accumulation length that bounds mixed-precision error.
pub const KB: usize = 256;

/// Identity slice cast used by the TypeId-dispatched SIMD fast paths.
///
/// # Safety
/// Caller must ensure `T` and `U` are the same type (checked by the
/// `TypeId` guard at every call site) — then this is a no-op transmute.
pub(crate) unsafe fn cast_slice<T: 'static, U: 'static>(s: &[T]) -> &[U] {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<U>());
    std::slice::from_raw_parts(s.as_ptr() as *const U, s.len())
}

/// Mutable twin of [`cast_slice`].
///
/// # Safety
/// Same contract: `T` and `U` must be the same type.
unsafe fn cast_slice_mut<T: 'static, U: 'static>(s: &mut [T]) -> &mut [U] {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<U>());
    std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut U, s.len())
}

/// The `MRxNR` micro-kernel: `out[0..MR_, 0..NR] += A[0..MR_, 0..k] ·
/// B[0..k, 0..NR]` with row strides `lda`/`ldb`/`ldo`. `MR_` is a const
/// generic so every variant keeps its accumulators in registers.
#[inline(always)]
fn kernel<const MR_: usize, T: Scalar>(
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    out: &mut [T],
    ldo: usize,
    k: usize,
) {
    let mut acc = [[T::ZERO; NR]; MR_];
    for kk in 0..k {
        let brow = &b[kk * ldb..kk * ldb + NR];
        for i in 0..MR_ {
            let av = a[i * lda + kk];
            let acc_i = &mut acc[i];
            for j in 0..NR {
                acc_i[j] += av * brow[j];
            }
        }
    }
    for (i, acc_i) in acc.iter().enumerate() {
        let orow = &mut out[i * ldo..i * ldo + NR];
        for j in 0..NR {
            orow[j] += acc_i[j];
        }
    }
}

/// `out (m×n) += A (m×k) · B (k×n)`, all row-major. Serial; the caller
/// owns (and has zeroed, if `=` semantics are wanted) the output buffer.
pub fn gemm_into<T: Scalar>(a: &[T], b: &[T], out: &mut [T], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k, "gemm_into: A buffer too small");
    debug_assert!(b.len() >= k * n, "gemm_into: B buffer too small");
    debug_assert!(out.len() >= m * n, "gemm_into: out buffer too small");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Explicit-SIMD fast path: `Scalar` is `'static`, so the concrete
    // element type is recoverable here and the casts are identity.
    if TypeId::of::<T>() == TypeId::of::<f64>() {
        // SAFETY: T == f64, just checked
        let done = unsafe {
            simd::gemm_f64(cast_slice(a), cast_slice(b), cast_slice_mut(out), m, k, n)
        };
        if done {
            return;
        }
    } else if TypeId::of::<T>() == TypeId::of::<f32>() {
        // SAFETY: T == f32, just checked
        let done = unsafe {
            simd::gemm_f32(cast_slice(a), cast_slice(b), cast_slice_mut(out), m, k, n)
        };
        if done {
            return;
        }
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KB.min(k - k0);
        let mut i0 = 0;
        while i0 < m {
            let mh = MR.min(m - i0);
            let mut j0 = 0;
            while j0 + NR <= n {
                let a_sub = &a[i0 * k + k0..];
                let b_sub = &b[k0 * n + j0..];
                let o_sub = &mut out[i0 * n + j0..];
                match mh {
                    4 => kernel::<4, T>(a_sub, k, b_sub, n, o_sub, n, kb),
                    3 => kernel::<3, T>(a_sub, k, b_sub, n, o_sub, n, kb),
                    2 => kernel::<2, T>(a_sub, k, b_sub, n, o_sub, n, kb),
                    _ => kernel::<1, T>(a_sub, k, b_sub, n, o_sub, n, kb),
                }
                j0 += NR;
            }
            if j0 < n {
                // remainder columns (< NR): stream B rows, accumulate in out
                for ii in 0..mh {
                    let r = i0 + ii;
                    let arow = &a[r * k + k0..r * k + k0 + kb];
                    for (kk, &av) in arow.iter().enumerate() {
                        let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + n];
                        let orow = &mut out[r * n + j0..r * n + n];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            }
            i0 += mh;
        }
        k0 += kb;
    }
}

/// Mixed-precision GEMM: `out (m×n, f64) += A (m×k, f32) · B (k×n, f32)`.
///
/// Products run in f32 (full SIMD lane count — twice the f64 width), and
/// the accumulation is widened to f64 at [`KB`] granularity in the SIMD
/// arms (per element in the portable fallback), so per-entry error is
/// bounded by `KB · ε₃₂ ≈ 1.5e-5` relative to the f32-rounded inputs.
/// This is the tile contraction behind
/// [`crate::linalg::op::mmm::Precision::Mixed`].
pub fn gemm_mixed_into(a: &[f32], b: &[f32], out: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k, "gemm_mixed_into: A buffer too small");
    debug_assert!(b.len() >= k * n, "gemm_mixed_into: B buffer too small");
    debug_assert!(out.len() >= m * n, "gemm_mixed_into: out buffer too small");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if simd::gemm_mixed(a, b, out, m, k, n) {
        return;
    }
    // portable fallback: f32 products widened per element into the f64
    // accumulator (strictly more accurate than the KB-blocked SIMD arms)
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += (av * bv) as f64;
            }
        }
    }
}

/// Four-accumulator dot product — the unrolled reduction the mBCG α/β
/// steps and `A·Bᵀ` contractions ride on (a single-accumulator dot
/// serialises on the add latency; four independent chains let the FMA
/// pipeline fill).
#[inline]
pub fn dot<T: Scalar>(x: &[T], y: &[T]) -> T {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut a0 = T::ZERO;
    let mut a1 = T::ZERO;
    let mut a2 = T::ZERO;
    let mut a3 = T::ZERO;
    let end = n - n % 4;
    let mut i = 0;
    while i < end {
        a0 += x[i] * y[i];
        a1 += x[i + 1] * y[i + 1];
        a2 += x[i + 2] * y[i + 2];
        a3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = (a0 + a1) + (a2 + a3);
    while i < n {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

/// `out (m×n) += A (m×k) · Bᵀ` where `B` is `n×k` row-major — every output
/// entry is a length-k dot of two contiguous rows, computed with the
/// unrolled [`dot`].
pub fn gemm_abt_into<T: Scalar>(a: &[T], b: &[T], out: &mut [T], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && out.len() >= m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `out (m×n) += Aᵀ · B` where `A` is `k×m` and `B` is `k×n`, both
/// row-major — rank-1 updates streamed over the shared `k` axis, four at a
/// time so each output-row pass performs four independent FMA streams.
pub fn gemm_atb_into<T: Scalar>(a: &[T], b: &[T], out: &mut [T], k: usize, m: usize, n: usize) {
    debug_assert!(a.len() >= k * m && b.len() >= k * n && out.len() >= m * n);
    let end = k - k % 4;
    let mut r = 0;
    while r < end {
        let (a0, a1, a2, a3) = (
            &a[r * m..(r + 1) * m],
            &a[(r + 1) * m..(r + 2) * m],
            &a[(r + 2) * m..(r + 3) * m],
            &a[(r + 3) * m..(r + 4) * m],
        );
        let (b0, b1, b2, b3) = (
            &b[r * n..(r + 1) * n],
            &b[(r + 1) * n..(r + 2) * n],
            &b[(r + 2) * n..(r + 3) * n],
            &b[(r + 3) * n..(r + 4) * n],
        );
        for i in 0..m {
            let (v0, v1, v2, v3) = (a0[i], a1[i], a2[i], a3[i]);
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += v0 * b0[j] + v1 * b1[j] + v2 * b2[j] + v3 * b3[j];
            }
        }
        r += 4;
    }
    while r < k {
        let arow = &a[r * m..(r + 1) * m];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn rand_buf(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::util::Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn gemm_matches_naive_across_tile_boundaries() {
        for &(m, k, n) in &[
            (1, 1, 1),
            (4, 8, 8),
            (5, 7, 9),
            (3, 300, 17),
            (13, 257, 31),
            (17, 512, 8),
            (2, 2, 7),
        ] {
            let a = rand_buf(m * k, 1 + (m * k) as u64);
            let b = rand_buf(k * n, 2 + (k * n) as u64);
            let mut out = vec![0.0; m * n];
            gemm_into(&a, &b, &mut out, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for i in 0..m * n {
                assert!((out[i] - want[i]).abs() < 1e-10, "({m},{k},{n}) entry {i}");
            }
        }
    }

    #[test]
    fn gemm_accumulates_into_out() {
        let (m, k, n) = (3, 5, 11);
        let a = rand_buf(m * k, 3);
        let b = rand_buf(k * n, 4);
        let mut out = vec![1.0; m * n];
        gemm_into(&a, &b, &mut out, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for i in 0..m * n {
            assert!((out[i] - 1.0 - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_shapes_are_noops() {
        let a = [1.0f64; 4];
        let b = [1.0f64; 4];
        let mut out = [0.0f64; 4];
        gemm_into(&a, &b, &mut out, 0, 2, 2);
        gemm_into(&a, &b, &mut out, 2, 0, 2);
        gemm_into(&a, &b, &mut out, 2, 2, 0);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn abt_and_atb_match_naive() {
        let (m, k, n) = (6, 13, 9);
        let a = rand_buf(m * k, 5);
        let bt = rand_buf(n * k, 6); // B as n×k (transposed layout)
        // rebuild B row-major k×n for the reference
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for kk in 0..k {
                b[kk * n + j] = bt[j * k + kk];
            }
        }
        let mut out = vec![0.0; m * n];
        gemm_abt_into(&a, &bt, &mut out, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for i in 0..m * n {
            assert!((out[i] - want[i]).abs() < 1e-10);
        }
        // Aᵀ·B: A stored k×m
        let at = rand_buf(k * m, 7);
        let mut a_rm = vec![0.0; m * k];
        for r in 0..k {
            for i in 0..m {
                a_rm[i * k + r] = at[r * m + i];
            }
        }
        let mut out2 = vec![0.0; m * n];
        gemm_atb_into(&at, &b, &mut out2, k, m, n);
        let want2 = naive(&a_rm, &b, m, k, n);
        for i in 0..m * n {
            assert!((out2[i] - want2[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn dot_matches_reference_on_odd_lengths() {
        for &len in &[0usize, 1, 3, 4, 5, 63, 64, 65] {
            let x = rand_buf(len, 10 + len as u64);
            let y = rand_buf(len, 20 + len as u64);
            let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - want).abs() < 1e-10 * (1.0 + want.abs()), "len {len}");
        }
    }

    #[test]
    fn f32_gemm_tracks_f64() {
        let (m, k, n) = (9, 33, 12);
        let a = rand_buf(m * k, 8);
        let b = rand_buf(k * n, 9);
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let mut out32 = vec![0.0f32; m * n];
        gemm_into(&a32, &b32, &mut out32, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for i in 0..m * n {
            assert!((out32[i] as f64 - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()));
        }
    }

    #[test]
    fn mixed_gemm_tracks_f64_within_f32_bound() {
        // spans the KB boundary so the SIMD arms' blocked widening is hit
        for &(m, k, n) in &[(5usize, 33usize, 9usize), (7, 300, 12), (4, 257, 8)] {
            let a = rand_buf(m * k, 40 + k as u64);
            let b = rand_buf(k * n, 41 + k as u64);
            let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            let mut out = vec![0.0f64; m * n];
            gemm_mixed_into(&a32, &b32, &mut out, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for i in 0..m * n {
                assert!(
                    (out[i] - want[i]).abs() < 5e-4 * (1.0 + want[i].abs()),
                    "({m},{k},{n}) entry {i}: {} vs {}",
                    out[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn mixed_gemm_accumulates_into_out() {
        let (m, k, n) = (3, 5, 11);
        let a = rand_buf(m * k, 50);
        let b = rand_buf(k * n, 51);
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let mut out = vec![1.0f64; m * n];
        gemm_mixed_into(&a32, &b32, &mut out, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for i in 0..m * n {
            assert!((out[i] - 1.0 - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()));
        }
    }
}
