//! Explicit Lanczos tridiagonalization with full reorthogonalization.
//!
//! This is the subroutine the Dong et al. [13] baseline engine uses for its
//! log-determinant (the paper's SKI comparison in Figure 2 right). BBMM
//! deliberately *avoids* running this — it needs O(np) storage for Q and
//! loses orthogonality without the (expensive) reorthogonalization below —
//! recovering T̃ from CG coefficients instead. We keep the explicit
//! algorithm both as the baseline and as the correctness oracle for the
//! mBCG tridiagonal recovery.

use crate::linalg::mbcg::TriDiag;
use crate::tensor::Mat;

/// Run `p` Lanczos iterations on the operator `matvec` starting from probe
/// vector `z`. Returns the tridiagonal `T̃ (p×p)` and the orthonormal basis
/// `Q̃ (n×p)` whose first column is `z/‖z‖`.
///
/// Uses full reorthogonalization (two Gram–Schmidt passes) — the numerical
/// band-aid whose cost BBMM avoids.
pub fn lanczos_tridiag(
    matvec: impl Fn(&[f64]) -> Vec<f64>,
    z: &[f64],
    p: usize,
) -> (TriDiag, Mat) {
    let n = z.len();
    let p = p.min(n);
    let mut q = Mat::zeros(n, p);
    let znorm = z.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(znorm > 0.0, "lanczos probe must be nonzero");
    let mut qcur: Vec<f64> = z.iter().map(|v| v / znorm).collect();
    q.set_col(0, &qcur);
    let mut qprev = vec![0.0; n];
    let mut alphas = Vec::with_capacity(p);
    let mut betas: Vec<f64> = Vec::with_capacity(p.saturating_sub(1));
    let mut beta_prev = 0.0;

    for j in 0..p {
        let mut w = matvec(&qcur);
        let alpha = dot(&w, &qcur);
        alphas.push(alpha);
        for i in 0..n {
            w[i] -= alpha * qcur[i] + beta_prev * qprev[i];
        }
        // full reorthogonalization against all previous basis vectors (x2)
        for _pass in 0..2 {
            for k in 0..=j {
                let qk = q.col(k);
                let c = dot(&w, &qk);
                for i in 0..n {
                    w[i] -= c * qk[i];
                }
            }
        }
        if j + 1 == p {
            break;
        }
        let beta = dot(&w, &w).sqrt();
        if beta < 1e-13 {
            // invariant subspace found — truncate
            let t = TriDiag {
                diag: alphas,
                offdiag: betas,
            };
            let q_trunc = q.cols_range(0, j + 1);
            return (t, q_trunc);
        }
        betas.push(beta);
        qprev = qcur;
        qcur = w.iter().map(|v| v / beta).collect();
        q.set_col(j + 1, &qcur);
        beta_prev = beta;
    }

    (
        TriDiag {
            diag: alphas,
            offdiag: betas,
        },
        q,
    )
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64 * 0.3);
        a
    }

    #[test]
    fn q_is_orthonormal() {
        let n = 30;
        let a = spd(n, 1);
        let mut rng = Rng::new(2);
        let z = rng.normal_vec(n);
        let (_t, q) = lanczos_tridiag(|v| a.matvec(v), &z, 12);
        let qtq = q.t_matmul(&q);
        assert!(qtq.max_abs_diff(&Mat::eye(q.cols())) < 1e-10);
    }

    #[test]
    fn satisfies_three_term_recurrence() {
        // A·Q ≈ Q·T on the first p-1 columns
        let n = 25;
        let a = spd(n, 3);
        let mut rng = Rng::new(4);
        let z = rng.normal_vec(n);
        let p = 10;
        let (t, q) = lanczos_tridiag(|v| a.matvec(v), &z, p);
        let aq = a.matmul(&q);
        let qt = q.matmul(&t.to_dense());
        // last column differs by the residual term β_p q_{p+1}
        for c in 0..p - 1 {
            for r in 0..n {
                assert!(
                    (aq.get(r, c) - qt.get(r, c)).abs() < 1e-8,
                    "col {c} row {r}"
                );
            }
        }
    }

    #[test]
    fn full_run_reproduces_matrix_spectrum() {
        // p = n Lanczos: eigenvalues of T == eigenvalues of A
        let n = 12;
        let a = spd(n, 5);
        let mut rng = Rng::new(6);
        let z = rng.normal_vec(n);
        let (t, _q) = lanczos_tridiag(|v| a.matvec(v), &z, n);
        let eig_t = crate::linalg::tridiag::SymTridiagEig::new(&t.diag, &t.offdiag);
        // trace and logdet must match (full Krylov space)
        let tr_a: f64 = (0..n).map(|i| a.get(i, i)).sum();
        let tr_t: f64 = eig_t.eigenvalues.iter().sum();
        assert!((tr_a - tr_t).abs() / tr_a.abs() < 1e-8);
        let ld_a = crate::linalg::cholesky::Cholesky::new(&a).unwrap().logdet();
        let ld_t: f64 = eig_t.eigenvalues.iter().map(|l| l.ln()).sum();
        assert!((ld_a - ld_t).abs() / ld_a.abs() < 1e-8);
    }

    #[test]
    fn first_column_is_normalized_probe() {
        let n = 15;
        let a = spd(n, 7);
        let mut rng = Rng::new(8);
        let z = rng.normal_vec(n);
        let (_t, q) = lanczos_tridiag(|v| a.matvec(v), &z, 5);
        let znorm = z.iter().map(|v| v * v).sum::<f64>().sqrt();
        for i in 0..n {
            assert!((q.get(i, 0) - z[i] / znorm).abs() < 1e-12);
        }
    }

    #[test]
    fn invariant_subspace_truncates() {
        // identity matrix: Krylov space is 1-dimensional
        let n = 10;
        let eye = Mat::eye(n);
        let mut rng = Rng::new(9);
        let z = rng.normal_vec(n);
        let (t, q) = lanczos_tridiag(|v| eye.matvec(v), &z, 5);
        assert_eq!(t.n(), 1);
        assert_eq!(q.cols(), 1);
        assert!((t.diag[0] - 1.0).abs() < 1e-12);
    }
}
