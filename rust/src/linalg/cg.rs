//! Standard preconditioned conjugate gradients (paper App. A, Algorithm 1).
//!
//! Single right-hand side; used by the Dong et al. baseline engine and by
//! the Figure-4 experiments that trace residual vs iteration.

use crate::tensor::{vecops, Mat, Scalar};

/// Result of a (preconditioned) CG solve.
pub struct PcgResult<T: Scalar = f64> {
    /// approximate solution `A⁻¹ b`
    pub x: Vec<T>,
    /// iterations actually performed
    pub iterations: usize,
    /// relative residual ‖A x − b‖ / ‖b‖ after each iteration
    pub residual_history: Vec<f64>,
    /// CG coefficients (α_j, β_j) per iteration — enough to rebuild the
    /// Lanczos tridiagonal matrix (Observation 3 / Saad §6.7.3)
    pub alphas: Vec<f64>,
    pub betas: Vec<f64>,
}

/// Preconditioned CG: solves `A x = b` using only a mat-vec closure.
///
/// * `matvec` — computes `A·v`.
/// * `precond` — applies `P⁻¹·v` (pass identity for unpreconditioned CG).
/// * stops at `max_iters` or when relative residual < `tol`.
pub fn pcg<T: Scalar>(
    matvec: impl Fn(&[T]) -> Vec<T>,
    b: &[T],
    precond: impl Fn(&[T]) -> Vec<T>,
    max_iters: usize,
    tol: f64,
) -> PcgResult<T> {
    let n = b.len();
    let bnorm = b
        .iter()
        .map(|v| v.to_f64() * v.to_f64())
        .sum::<f64>()
        .sqrt();
    if bnorm == 0.0 {
        return PcgResult {
            x: vec![T::ZERO; n],
            iterations: 0,
            residual_history: vec![0.0],
            alphas: vec![],
            betas: vec![],
        };
    }
    let mut x = vec![T::ZERO; n];
    let mut r = b.to_vec(); // r = b - A·0
    let mut z = precond(&r);
    let mut d = z.clone();
    let mut rz_old: f64 = dot64(&r, &z);
    let mut history = Vec::with_capacity(max_iters);
    let mut alphas = Vec::new();
    let mut betas = Vec::new();

    let mut iters = 0;
    for _ in 0..max_iters {
        let v = matvec(&d);
        let dv = dot64(&d, &v);
        if dv.abs() < 1e-300 {
            break;
        }
        let alpha = rz_old / dv;
        for i in 0..n {
            x[i] += T::from_f64(alpha * d[i].to_f64());
            r[i] -= T::from_f64(alpha * v[i].to_f64());
        }
        iters += 1;
        alphas.push(alpha);
        let rnorm = r
            .iter()
            .map(|v| v.to_f64() * v.to_f64())
            .sum::<f64>()
            .sqrt();
        history.push(rnorm / bnorm);
        if rnorm / bnorm < tol {
            break;
        }
        z = precond(&r);
        let rz_new = dot64(&r, &z);
        let beta = rz_new / rz_old;
        betas.push(beta);
        rz_old = rz_new;
        for i in 0..n {
            d[i] = z[i] + T::from_f64(beta * d[i].to_f64());
        }
    }

    PcgResult {
        x,
        iterations: iters,
        residual_history: history,
        alphas,
        betas,
    }
}

fn dot64<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| x.to_f64() * y.to_f64())
        .sum()
}

/// Convenience: CG against a dense matrix (used heavily in tests/figures).
pub fn pcg_dense<T: Scalar>(a: &Mat<T>, b: &[T], max_iters: usize, tol: f64) -> PcgResult<T> {
    pcg(|v| a.matvec(v), b, |r| r.to_vec(), max_iters, tol)
}

/// Relative residual ‖A x − b‖₂/‖b‖₂ for a dense system (figure metric).
pub fn relative_residual(a: &Mat, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let mut diff = 0.0;
    for i in 0..b.len() {
        let d = ax[i] - b[i];
        diff += d * d;
    }
    diff.sqrt() / vecops::norm2(b).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn cg_solves_spd_system() {
        let n = 80;
        let a = spd(n, 1);
        let mut rng = Rng::new(2);
        let b = rng.normal_vec(n);
        let res = pcg_dense(&a, &b, n, 1e-12);
        assert!(relative_residual(&a, &res.x, &b) < 1e-10);
    }

    #[test]
    fn cg_exact_in_n_iterations() {
        // tiny well-conditioned system, no tolerance: converges in ≤ n iters
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let b = vec![1.0, 2.0];
        let res = pcg_dense(&a, &b, 2, 0.0);
        let x_true = vec![(3.0 - 2.0) / 11.0, (8.0 - 1.0) / 11.0];
        for i in 0..2 {
            assert!((res.x[i] - x_true[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn residual_history_decreases_overall() {
        let n = 60;
        let a = spd(n, 3);
        let mut rng = Rng::new(4);
        let b = rng.normal_vec(n);
        let res = pcg_dense(&a, &b, n, 1e-14);
        let first = res.residual_history[0];
        let last = *res.residual_history.last().unwrap();
        assert!(last < first * 1e-6);
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = spd(10, 5);
        let b = vec![0.0; 10];
        let res = pcg_dense(&a, &b, 10, 1e-10);
        assert!(res.x.iter().all(|&v| v == 0.0));
        assert_eq!(res.iterations, 0);
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations_on_scaled_system() {
        // badly scaled diagonal + small coupling: Jacobi helps a lot
        let n = 100;
        let mut rng = Rng::new(6);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 10f64.powi((i % 6) as i32));
        }
        for _ in 0..n {
            let i = rng.below(n);
            let j = rng.below(n);
            if i != j {
                let v = 0.01 * rng.normal();
                a.set(i, j, a.get(i, j) + v);
                a.set(j, i, a.get(j, i) + v);
            }
        }
        let b = rng.normal_vec(n);
        let plain = pcg(|v| a.matvec(v), &b, |r| r.to_vec(), 200, 1e-10);
        let diag: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
        let jacobi = pcg(
            |v| a.matvec(v),
            &b,
            |r| r.iter().zip(&diag).map(|(ri, di)| ri / di).collect(),
            200,
            1e-10,
        );
        assert!(
            jacobi.iterations < plain.iterations,
            "jacobi {} !< plain {}",
            jacobi.iterations,
            plain.iterations
        );
    }

    #[test]
    fn f32_cg_converges_to_f32_accuracy() {
        let n = 50;
        let a64 = spd(n, 7);
        let a: Mat<f32> = a64.cast();
        let mut rng = Rng::new(8);
        let b64 = rng.normal_vec(n);
        let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
        let res = pcg_dense(&a, &b, 200, 1e-6);
        // residual achievable in f32 is ~1e-6 relative
        let x64: Vec<f64> = res.x.iter().map(|&v| v as f64).collect();
        assert!(relative_residual(&a64, &x64, &b64) < 1e-4);
    }
}
