//! The paper's preconditioner (§4.1): `P̂_k = L_k L_kᵀ + σ²I` where `L_k` is
//! a rank-k pivoted Cholesky factor.
//!
//! All three required operations are O(nk²) (App. C.1):
//! * solves, via the Woodbury identity
//!   `P̂⁻¹ = σ⁻²I − σ⁻²L (I + σ⁻²LᵀL)⁻¹ Lᵀ σ⁻²`,
//! * `log|P̂| = log|I + σ⁻²LᵀL| + n·log σ²` (matrix determinant lemma),
//! * sampling `z ~ N(0, P̂)` as `z = L g₁ + σ g₂` — the probe distribution
//!   the preconditioned SLQ log-det estimator needs (§4.1 / Thm 2 setup).

use crate::linalg::cholesky::Cholesky;
use crate::tensor::Mat;
use crate::util::Rng;

/// Application of `P̂⁻¹` to vectors/matrices plus the preconditioner's exact
/// log-determinant. Implemented by the identity (no preconditioning) and the
/// pivoted-Cholesky preconditioner.
pub trait Preconditioner: Sync {
    /// `P̂⁻¹ · M`
    fn solve_mat(&self, m: &Mat) -> Mat;
    /// `P̂⁻¹ · M` written into a caller-owned, same-shaped output — the
    /// zero-allocation seam the solver workspaces drive. The default
    /// delegates to [`Preconditioner::solve_mat`] (which allocates) and
    /// copies; the identity overrides it with a pure copy.
    fn solve_mat_into(&self, m: &Mat, out: &mut Mat) {
        let r = self.solve_mat(m);
        assert_eq!(out.shape(), r.shape(), "solve_mat_into: output shape mismatch");
        out.copy_from(&r);
    }
    /// `P̂⁻¹ · v`
    fn solve_vec(&self, v: &[f64]) -> Vec<f64> {
        let m = Mat::col_from_slice(v);
        self.solve_mat(&m).col(0)
    }
    /// `log|P̂|`
    fn logdet(&self) -> f64;
    /// Draw a probe matrix `Z (n×t)` with columns `zᵢ ~ N(0, P̂)` (identity
    /// preconditioner draws Rademacher probes with `E[zzᵀ] = I` instead, as
    /// the paper does when unpreconditioned).
    fn sample_probes(&self, n: usize, t: usize, rng: &mut Rng) -> Mat;
    /// rank k of the low-rank part (0 for identity)
    fn rank(&self) -> usize;
}

/// No preconditioning: `P̂ = I`.
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn solve_mat(&self, m: &Mat) -> Mat {
        m.clone()
    }
    fn solve_mat_into(&self, m: &Mat, out: &mut Mat) {
        out.copy_from(m);
    }
    fn logdet(&self) -> f64 {
        0.0
    }
    fn sample_probes(&self, n: usize, t: usize, rng: &mut Rng) -> Mat {
        // Rademacher probes (paper §6)
        Mat::from_fn(n, t, |_, _| rng.rademacher())
    }
    fn rank(&self) -> usize {
        0
    }
}

/// `P̂ = L Lᵀ + σ²I` with L an `n×k` pivoted-Cholesky factor.
pub struct PartialCholPrecond {
    l: Mat,
    sigma2: f64,
    /// Cholesky factor of the k×k capacitance `C = I + σ⁻² LᵀL`
    cap: Cholesky,
    logdet: f64,
}

impl PartialCholPrecond {
    /// Build from a low-rank factor and the likelihood noise σ².
    pub fn new(l: Mat, sigma2: f64) -> Self {
        assert!(sigma2 > 0.0, "noise must be positive");
        let k = l.cols();
        let mut cap_mat = l.t_matmul(&l); // LᵀL (k×k)
        cap_mat.scale_assign(1.0 / sigma2);
        cap_mat.add_diag(1.0);
        cap_mat.symmetrize();
        let cap = Cholesky::new_with_jitter(&cap_mat)
            .expect("capacitance matrix must be PD (it is I + PSD)");
        let n = l.rows();
        let logdet = cap.logdet() + n as f64 * sigma2.ln();
        let _ = k;
        PartialCholPrecond {
            l,
            sigma2,
            cap,
            logdet,
        }
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }

    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }
}

impl Preconditioner for PartialCholPrecond {
    /// Woodbury: `P̂⁻¹M = M/σ² − L C⁻¹ (LᵀM) / σ⁴`.
    fn solve_mat(&self, m: &Mat) -> Mat {
        let ltm = self.l.t_matmul(m); // k×t
        let cinv = self.cap.solve_mat(&ltm); // k×t
        let correction = self.l.matmul(&cinv); // n×t
        let mut out = m.clone();
        out.scale_assign(1.0 / self.sigma2);
        let mut corr = correction;
        corr.scale_assign(1.0 / (self.sigma2 * self.sigma2));
        out.sub_assign(&corr);
        out
    }

    fn logdet(&self) -> f64 {
        self.logdet
    }

    /// `z = L g₁ + σ g₂ ~ N(0, L Lᵀ + σ²I)`.
    fn sample_probes(&self, n: usize, t: usize, rng: &mut Rng) -> Mat {
        assert_eq!(n, self.l.rows());
        let k = self.l.cols();
        let g1 = Mat::from_fn(k, t, |_, _| rng.normal());
        let mut z = self.l.matmul(&g1);
        let sigma = self.sigma2.sqrt();
        for i in 0..n {
            for c in 0..t {
                let v = z.get(i, c) + sigma * rng.normal();
                z.set(i, c, v);
            }
        }
        z
    }

    fn rank(&self) -> usize {
        self.l.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::pivoted_cholesky::pivoted_cholesky_dense;
    use crate::util::Rng;

    fn low_rank(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, k, |_, _| rng.normal())
    }

    fn dense_phat(l: &Mat, sigma2: f64) -> Mat {
        let mut p = l.matmul_t(l);
        p.add_diag(sigma2);
        p
    }

    #[test]
    fn woodbury_solve_matches_dense() {
        let l = low_rank(30, 4, 1);
        let sigma2 = 0.3;
        let pre = PartialCholPrecond::new(l.clone(), sigma2);
        let phat = dense_phat(&l, sigma2);
        let ch = Cholesky::new(&phat).unwrap();
        let mut rng = Rng::new(2);
        let b = Mat::from_fn(30, 3, |_, _| rng.normal());
        let got = pre.solve_mat(&b);
        let want = ch.solve_mat(&b);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn logdet_matches_dense() {
        let l = low_rank(25, 5, 3);
        let sigma2 = 0.7;
        let pre = PartialCholPrecond::new(l.clone(), sigma2);
        let want = Cholesky::new(&dense_phat(&l, sigma2)).unwrap().logdet();
        assert!((pre.logdet() - want).abs() < 1e-9);
    }

    #[test]
    fn probe_covariance_is_phat() {
        let l = low_rank(10, 2, 4);
        let sigma2 = 0.5;
        let pre = PartialCholPrecond::new(l.clone(), sigma2);
        let mut rng = Rng::new(5);
        let t = 40_000;
        let z = pre.sample_probes(10, t, &mut rng);
        // empirical covariance Z Zᵀ / t
        let mut cov = z.matmul_t(&z);
        cov.scale_assign(1.0 / t as f64);
        let want = dense_phat(&l, sigma2);
        assert!(
            cov.max_abs_diff(&want) < 0.15 * want.fro_norm() / 10.0 + 0.1,
            "diff {}",
            cov.max_abs_diff(&want)
        );
    }

    #[test]
    fn identity_preconditioner_is_noop() {
        let pre = IdentityPrecond;
        let mut rng = Rng::new(6);
        let m = Mat::from_fn(8, 3, |_, _| rng.normal());
        assert_eq!(pre.solve_mat(&m), m);
        assert_eq!(pre.logdet(), 0.0);
        let z = pre.sample_probes(8, 5, &mut rng);
        for v in z.data() {
            assert!(*v == 1.0 || *v == -1.0);
        }
    }

    #[test]
    fn preconditioner_accelerates_cg_on_rbf() {
        // The paper's Figure 4 in miniature: rank-5 pivoted-Cholesky
        // preconditioner cuts CG iterations on an RBF system.
        let n = 120;
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mut k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / (2.0 * 0.04)).exp()
        });
        let sigma2 = 1e-2;
        k.add_diag(sigma2);
        let b = rng.normal_vec(n);

        let plain = crate::linalg::cg::pcg(|v| k.matvec(v), &b, |r| r.to_vec(), 400, 1e-8);
        // build preconditioner from K (without noise), as the paper does
        let mut k_noiseless = k.clone();
        k_noiseless.add_diag(-sigma2);
        let pc = pivoted_cholesky_dense(&k_noiseless, 5, 0.0);
        let pre = PartialCholPrecond::new(pc.l, sigma2);
        let precond = crate::linalg::cg::pcg(|v| k.matvec(v), &b, |r| pre.solve_vec(r), 400, 1e-8);
        assert!(
            precond.iterations * 2 < plain.iterations,
            "precond {} vs plain {}",
            precond.iterations,
            plain.iterations
        );
    }
}
