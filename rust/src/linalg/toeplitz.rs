//! Symmetric Toeplitz operator with O(m log m) mat-vecs via circulant
//! embedding — the algebraic structure KISS-GP [50] gives `K_UU` when the
//! inducing points sit on a regular 1-D grid (paper §5).

use crate::linalg::fft::{fft_inplace, next_pow2, Cplx};
use crate::tensor::Mat;
use crate::util::par;

/// Symmetric Toeplitz matrix `T[i,j] = c[|i−j|]`, applied via FFT.
#[derive(Clone)]
pub struct ToeplitzOp {
    /// first column (length m)
    col: Vec<f64>,
    /// FFT length (≥ 2m, power of two)
    len: usize,
    /// precomputed FFT of the embedded circulant's first column
    spec: Vec<Cplx>,
}

impl ToeplitzOp {
    /// Build from the first column `c` of the (symmetric) Toeplitz matrix.
    pub fn new(col: Vec<f64>) -> Self {
        let m = col.len();
        assert!(m > 0);
        let len = next_pow2((2 * m).max(2));
        // circulant first column: [c₀ c₁ … c_{m−1} 0 … 0 c_{m−1} … c₁]
        let mut circ = vec![Cplx::ZERO; len];
        for (i, &v) in col.iter().enumerate() {
            circ[i] = Cplx::new(v, 0.0);
        }
        for i in 1..m {
            circ[len - i] = Cplx::new(col[i], 0.0);
        }
        fft_inplace(&mut circ, false);
        ToeplitzOp {
            col,
            len,
            spec: circ,
        }
    }

    pub fn m(&self) -> usize {
        self.col.len()
    }

    pub fn first_column(&self) -> &[f64] {
        &self.col
    }

    /// Dense form (tests, small m).
    pub fn to_dense(&self) -> Mat {
        let m = self.m();
        Mat::from_fn(m, m, |i, j| self.col[i.abs_diff(j)])
    }

    /// O(m log m) matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let m = self.m();
        assert_eq!(v.len(), m);
        let mut buf = vec![Cplx::ZERO; self.len];
        for (i, &x) in v.iter().enumerate() {
            buf[i] = Cplx::new(x, 0.0);
        }
        fft_inplace(&mut buf, false);
        for i in 0..self.len {
            buf[i] = buf[i].mul(self.spec[i]);
        }
        fft_inplace(&mut buf, true);
        buf[..m].iter().map(|c| c.re).collect()
    }

    /// Matrix-matrix product `T · M` (column-parallel FFT applies).
    pub fn matmul(&self, mat: &Mat) -> Mat {
        let m = self.m();
        assert_eq!(mat.rows(), m);
        let t = mat.cols();
        let mut out = Mat::zeros(m, t);
        let cols: Vec<Vec<f64>> = (0..t).map(|c| mat.col(c)).collect();
        let results: Vec<std::sync::Mutex<Vec<f64>>> =
            (0..t).map(|_| std::sync::Mutex::new(Vec::new())).collect();
        par::parallel_for(t, |c| {
            *results[c].lock().unwrap() = self.matvec(&cols[c]);
        });
        for (c, cell) in results.into_iter().enumerate() {
            out.set_col(c, &cell.into_inner().unwrap());
        }
        out
    }

    /// diagonal entry (constant: c₀)
    pub fn diag_value(&self) -> f64 {
        self.col[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_col(m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        // decaying column keeps the dense comparison well-scaled
        (0..m).map(|i| rng.normal() / (1.0 + i as f64)).collect()
    }

    #[test]
    fn matvec_matches_dense() {
        for &m in &[1usize, 2, 3, 8, 17, 100] {
            let op = ToeplitzOp::new(rand_col(m, m as u64));
            let dense = op.to_dense();
            let mut rng = Rng::new(77);
            let v = rng.normal_vec(m);
            let got = op.matvec(&v);
            let want = dense.matvec(&v);
            for i in 0..m {
                assert!((got[i] - want[i]).abs() < 1e-9, "m={m} i={i}");
            }
        }
    }

    #[test]
    fn matmul_matches_dense() {
        let m = 33;
        let op = ToeplitzOp::new(rand_col(m, 5));
        let mut rng = Rng::new(6);
        let mat = Mat::from_fn(m, 4, |_, _| rng.normal());
        let got = op.matmul(&mat);
        let want = op.to_dense().matmul(&mat);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn rbf_grid_kernel_is_symmetric_toeplitz() {
        // RBF kernel on a regular grid: K[i,j] depends on |i−j| only
        let m = 50;
        let h = 0.05;
        let col: Vec<f64> = (0..m)
            .map(|i| (-((i as f64 * h).powi(2)) / (2.0 * 0.1)).exp())
            .collect();
        let op = ToeplitzOp::new(col);
        let dense = op.to_dense();
        for i in 0..m {
            for j in 0..m {
                assert_eq!(dense.get(i, j), dense.get(j, i));
            }
        }
        let mut rng = Rng::new(9);
        let v = rng.normal_vec(m);
        let got = op.matvec(&v);
        let want = dense.matvec(&v);
        for i in 0..m {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn identity_toeplitz() {
        let mut col = vec![0.0; 10];
        col[0] = 1.0;
        let op = ToeplitzOp::new(col);
        let mut rng = Rng::new(10);
        let v = rng.normal_vec(10);
        let got = op.matvec(&v);
        for i in 0..10 {
            assert!((got[i] - v[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn large_m_runs_fast_enough() {
        // smoke: 2^15 grid matvec should be well under a second
        let m = 1 << 15;
        let col: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 * 1e-3).powi(2)).exp()).collect();
        let op = ToeplitzOp::new(col);
        let v = vec![1.0; m];
        let t = crate::util::Timer::start();
        let out = op.matvec(&v);
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(t.elapsed_s() < 1.0);
    }
}
