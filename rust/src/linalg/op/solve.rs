//! The solve-strategy dispatcher: one generic `K̂⁻¹·B` entry point that
//! picks **direct** (dense Cholesky, Woodbury, circulant FFT) or
//! **iterative** (preconditioned mBCG) from the operator's declared
//! structure.
//!
//! This is the single path exact, SGPR, SKI, sharded, and multitask
//! models all solve through — `predict`, the serving coordinator, and the
//! engines dispatch here instead of hand-matching on model types:
//!
//! - [`SolveHint::Woodbury`] + an extractable `L·Lᵀ + σ²I` split → exact
//!   Woodbury solve in O(nk² + k³) (the SGPR direct path, no CG at all),
//! - [`SolveHint::DenseCholesky`] → materialise + factor (small/dense),
//! - [`SolveHint::CirculantFft`] + an extractable circulant column → exact
//!   FFT diagonalisation solve in O(n log n) — the branch a SKI-style
//!   grid covariance `K_UU` takes when solved *directly* (a Toeplitz
//!   operator, or AddedDiag/Scaled/Sum over one, whose column is an exact
//!   circulant; the full SKI sandwich `W·K_UU·Wᵀ + σ²I` is not circulant
//!   and stays iterative),
//! - [`SolveHint::Iterative`] → mBCG with the §4.1 pivoted-Cholesky
//!   preconditioner built from the operator's [`LinearOp::noise_split`].
//!
//! The **batch axis** rides on the same dispatch: [`plan_batch`] /
//! [`solve_batch`] prepare and execute b systems at once through a
//! [`BatchOp`] — direct-structure elements solve directly, every
//! iterative element joins one `mbcg_batch` call — and [`solve_cached`]
//! reuses plans across calls through a [`super::SolvePlanCache`].

use super::batch::BatchOp;
use super::{LinearOp, SolveHint};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::fft::{fft_inplace, Cplx};
use crate::linalg::mbcg::{
    mbcg, mbcg_batch_hetero_ws, mbcg_batch_stats_ws, MbcgBatchStats, MbcgOptions, MbcgWorkspace,
};
use crate::linalg::pivoted_cholesky::pivoted_cholesky;
use crate::linalg::preconditioner::{IdentityPrecond, PartialCholPrecond, Preconditioner};
use crate::tensor::Mat;

/// Knobs for the generic solve path (the iterative branch; direct
/// branches are exact and ignore the CG fields).
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// maximum mBCG iterations
    pub max_iters: usize,
    /// relative-residual tolerance per RHS column
    pub tol: f64,
    /// pivoted-Cholesky preconditioner rank (0 disables)
    pub precond_rank: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 100,
            tol: 1e-10,
            precond_rank: 5,
        }
    }
}

/// `(L, σ²)` when the operator is exactly `L·Lᵀ + σ²I`.
fn woodbury_parts(op: &dyn LinearOp) -> Option<(&Mat, f64)> {
    let (inner, sigma2) = op.noise_split()?;
    let l = inner.low_rank_factor()?;
    Some((l, sigma2))
}

/// Resolve the operator's hint against the structure it actually exposes:
/// a `Woodbury` hint only holds when the `L·Lᵀ + σ²I` split is
/// extractable, a `CirculantFft` hint only when the circulant column is —
/// otherwise the dispatcher falls back to mBCG.
pub fn solve_strategy(op: &dyn LinearOp) -> SolveHint {
    match op.solve_hint() {
        SolveHint::Woodbury => {
            if woodbury_parts(op).is_some() {
                SolveHint::Woodbury
            } else {
                SolveHint::Iterative
            }
        }
        SolveHint::CirculantFft => {
            if op.circulant_column().is_some() {
                SolveHint::CirculantFft
            } else {
                SolveHint::Iterative
            }
        }
        h => h,
    }
}

/// Exact direct solver for a **circulant** SPD matrix: the FFT
/// diagonalises any circulant, so `C⁻¹·b = F⁻¹(F(b)/λ)` with
/// `λ = F(first column)` — O(n log n) per column, no iteration, no
/// preconditioner. Reached by operators advertising
/// [`LinearOp::circulant_column`]: a SKI-grid `K_UU` whose circulant
/// embedding is exact, solved as the operator itself (the interpolation
/// sandwich around it is not circulant and keeps the iterative path).
pub struct CirculantPlan {
    /// real eigenvalues of the symmetric circulant (FFT of its column)
    eigs: Vec<f64>,
}

impl CirculantPlan {
    /// Diagonalise the circulant with first column `col`. Returns `None`
    /// when the size is not a radix-2 FFT length or the spectrum is not
    /// strictly positive (not SPD — no exact direct solve).
    pub fn new(col: &[f64]) -> Option<Self> {
        let m = col.len();
        if m == 0 || !m.is_power_of_two() {
            return None;
        }
        let mut buf: Vec<Cplx> = col.iter().map(|&v| Cplx::new(v, 0.0)).collect();
        fft_inplace(&mut buf, false);
        let mut eigs = Vec::with_capacity(m);
        for c in &buf {
            // symmetric circulant ⇒ real spectrum; SPD ⇒ strictly positive
            if c.re <= 0.0 || !c.re.is_finite() {
                return None;
            }
            eigs.push(c.re);
        }
        Some(CirculantPlan { eigs })
    }

    /// Operator dimension.
    pub fn n(&self) -> usize {
        self.eigs.len()
    }

    /// `C⁻¹ · B` column-by-column via FFT.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let m = self.eigs.len();
        assert_eq!(b.rows(), m, "CirculantPlan: RHS row mismatch");
        let mut out = Mat::zeros(m, b.cols());
        for c in 0..b.cols() {
            let mut buf: Vec<Cplx> = (0..m).map(|i| Cplx::new(b.get(i, c), 0.0)).collect();
            fft_inplace(&mut buf, false);
            for (v, &lam) in buf.iter_mut().zip(self.eigs.iter()) {
                *v = Cplx::new(v.re / lam, v.im / lam);
            }
            fft_inplace(&mut buf, true);
            for i in 0..m {
                out.set(i, c, buf[i].re);
            }
        }
        out
    }

    /// `log|C| = Σ log λᵢ` — exact, from the pre-FFT'd spectrum.
    pub fn logdet(&self) -> f64 {
        self.eigs.iter().map(|&l| l.ln()).sum()
    }
}

/// Build the §4.1 preconditioner `P̂ = L_k·L_kᵀ + σ²I` for an operator of
/// the form `K + σ²I`: rank-`rank` pivoted Cholesky over the noise-free
/// part's `diag`/`row`. Operators without a noise split (or `rank == 0`)
/// get the identity.
pub fn build_preconditioner(op: &dyn LinearOp, rank: usize) -> Box<dyn Preconditioner + Send> {
    let Some((inner, sigma2)) = op.noise_split() else {
        return Box::new(IdentityPrecond);
    };
    if rank == 0 {
        return Box::new(IdentityPrecond);
    }
    let diag = inner.diag();
    let pc = pivoted_cholesky(&diag, |i| inner.row(i), rank, 0.0);
    if pc.l.cols() == 0 {
        return Box::new(IdentityPrecond);
    }
    Box::new(PartialCholPrecond::new(pc.l, sigma2))
}

/// Factorisation state prepared once and reused across solves against a
/// fixed operator — what a serving loop holds (through a
/// [`super::SolvePlanCache`]) instead of paying a refactorisation
/// (capacitance Cholesky, circulant spectrum, pivoted-Cholesky
/// preconditioner build) per request batch.
pub enum SolvePlan {
    /// direct dense Cholesky factor of the full operator
    Cholesky(Cholesky),
    /// direct Woodbury solve of `L·Lᵀ + σ²I` (capacitance prefactored)
    Woodbury(PartialCholPrecond),
    /// exact circulant direct solve (spectrum pre-FFT'd)
    Circulant(CirculantPlan),
    /// preconditioned mBCG with the §4.1 preconditioner prebuilt
    Mbcg(Box<dyn Preconditioner + Send>),
}

impl SolvePlan {
    /// True for plans that solve exactly without iteration.
    pub fn is_direct(&self) -> bool {
        !matches!(self, SolvePlan::Mbcg(_))
    }
}

/// Prepare the solver for an operator once (the expensive, structure-
/// dependent part of [`solve`]).
pub fn plan(op: &dyn LinearOp, opts: &SolveOptions) -> SolvePlan {
    match solve_strategy(op) {
        SolveHint::Woodbury => {
            // (LLᵀ + σ²I)⁻¹ is exactly the partial-Cholesky preconditioner's
            // Woodbury solve — reuse it as the direct solver
            let (l, sigma2) = woodbury_parts(op).expect("strategy guaranteed the split");
            SolvePlan::Woodbury(PartialCholPrecond::new(l.clone(), sigma2))
        }
        SolveHint::DenseCholesky => SolvePlan::Cholesky(
            Cholesky::new_with_jitter(&op.dense()).expect("operator not PD even with jitter"),
        ),
        SolveHint::CirculantFft => {
            let col = op.circulant_column().expect("strategy guaranteed the column");
            match CirculantPlan::new(&col) {
                Some(p) => SolvePlan::Circulant(p),
                // spectrum not strictly positive — no exact direct solve;
                // degrade to the iterative engine
                None => SolvePlan::Mbcg(build_preconditioner(op, opts.precond_rank)),
            }
        }
        SolveHint::Iterative => SolvePlan::Mbcg(build_preconditioner(op, opts.precond_rank)),
    }
}

/// Batched solve `op⁻¹ · b` through a prepared [`SolvePlan`] (the `op`
/// must be the one the plan was built for).
pub fn solve_with(plan: &SolvePlan, op: &dyn LinearOp, b: &Mat, opts: &SolveOptions) -> Mat {
    match plan {
        SolvePlan::Woodbury(direct) => direct.solve_mat(b),
        SolvePlan::Cholesky(ch) => ch.solve_mat(b),
        SolvePlan::Circulant(c) => c.solve_mat(b),
        SolvePlan::Mbcg(pre) => mbcg(
            |m| op.matmul(m),
            b,
            |m| pre.solve_mat(m),
            &MbcgOptions {
                max_iters: opts.max_iters,
                tol: opts.tol,
                n_solve_only: b.cols(), // tridiagonals unused here
            },
        )
        .solves,
    }
}

/// Generic batched solve `op⁻¹ · b`, dispatched on [`solve_strategy`].
/// One-shot convenience over [`plan`] + [`solve_with`]; callers solving
/// repeatedly against the same operator should hold the plan (or go
/// through [`solve_cached`]).
pub fn solve(op: &dyn LinearOp, b: &Mat, opts: &SolveOptions) -> Mat {
    solve_with(&plan(op, opts), op, b, opts)
}

/// Cache-aware [`solve`]: the plan is looked up in (or built into)
/// `cache` under slot `key`, so repeated solves against a fixed operator
/// pay the factorisation once and hyperparameter changes rebuild it
/// automatically (content fingerprinting — see [`super::SolvePlanCache`]).
pub fn solve_cached(
    cache: &super::SolvePlanCache,
    key: &str,
    op: &dyn LinearOp,
    b: &Mat,
    opts: &SolveOptions,
) -> Mat {
    let plan = cache.get_or_plan(key, op, opts);
    solve_with(&plan, op, b, opts)
}

/// Prepare plans for every element of a batch. On the shared-covariance
/// fast path with an iterative strategy, the rank-k pivoted-Cholesky
/// factor is computed **once** on the shared covariance and reused across
/// all b preconditioners (each with its own σ² capacitance) — the batched
/// analogue of [`build_preconditioner`].
pub fn plan_batch(batch: &BatchOp<'_>, opts: &SolveOptions) -> Vec<SolvePlan> {
    if batch.shared_parts().is_some() {
        let strategy = batch.with_element(0, solve_strategy);
        if strategy == SolveHint::Iterative {
            return build_preconditioner_batch(batch, opts.precond_rank)
                .into_iter()
                .map(SolvePlan::Mbcg)
                .collect();
        }
    }
    (0..batch.len())
        .map(|i| batch.with_element(i, |op| plan(op, opts)))
        .collect()
}

/// Batched preconditioner build: identity when `rank == 0`; on the
/// shared-covariance path one pivoted Cholesky serves every element.
pub fn build_preconditioner_batch(
    batch: &BatchOp<'_>,
    rank: usize,
) -> Vec<Box<dyn Preconditioner + Send>> {
    let b = batch.len();
    if rank == 0 {
        return (0..b)
            .map(|_| Box::new(IdentityPrecond) as Box<dyn Preconditioner + Send>)
            .collect();
    }
    if let Some((cov, sigma2s)) = batch.shared_parts() {
        let diag = cov.diag();
        let pc = pivoted_cholesky(&diag, |i| cov.row(i), rank, 0.0);
        if pc.l.cols() == 0 {
            return (0..b)
                .map(|_| Box::new(IdentityPrecond) as Box<dyn Preconditioner + Send>)
                .collect();
        }
        return sigma2s
            .iter()
            .map(|&s2| {
                Box::new(PartialCholPrecond::new(pc.l.clone(), s2))
                    as Box<dyn Preconditioner + Send>
            })
            .collect();
    }
    (0..b)
        .map(|i| batch.with_element(i, |op| build_preconditioner(op, rank)))
        .collect()
}

/// Batched dispatch: solve `bᵢ` against batch element `i` under its
/// prepared plan. Direct-structure elements (Cholesky / Woodbury /
/// circulant) solve immediately; **all** iterative elements run through a
/// single [`mbcg_batch`] call — one iteration loop, per-system early
/// stopping, and (on the shared-covariance path) one fused operator
/// product per iteration for the whole sub-batch.
pub fn solve_batch(
    batch: &BatchOp<'_>,
    plans: &[&SolvePlan],
    bs: &[&Mat],
    opts: &SolveOptions,
) -> Vec<Mat> {
    let mut ws = MbcgWorkspace::new();
    solve_batch_ws(batch, plans, bs, opts, &mut ws)
}

/// [`solve_batch`] against a caller-held [`MbcgWorkspace`]: the iterative
/// sub-batch runs through `mbcg_batch_stats_ws`, so callers solving
/// repeatedly against same-shaped batches (a serving loop answering every
/// tenant per tick) keep the solver's packing/product/residual buffers
/// warm across calls instead of re-allocating them per request batch.
pub fn solve_batch_ws(
    batch: &BatchOp<'_>,
    plans: &[&SolvePlan],
    bs: &[&Mat],
    opts: &SolveOptions,
    ws: &mut MbcgWorkspace,
) -> Vec<Mat> {
    let b = batch.len();
    assert_eq!(plans.len(), b, "solve_batch: plan count mismatch");
    assert_eq!(bs.len(), b, "solve_batch: RHS count mismatch");
    let mut out: Vec<Option<Mat>> = (0..b).map(|_| None).collect();
    let mut iter_idx = Vec::new();
    for i in 0..b {
        match plans[i] {
            SolvePlan::Mbcg(_) => iter_idx.push(i),
            direct => {
                out[i] = Some(batch.with_element(i, |op| solve_with(direct, op, bs[i], opts)));
            }
        }
    }
    if !iter_idx.is_empty() {
        let sub = batch.subset(&iter_idx);
        fn mbcg_precond(plan: &SolvePlan) -> &dyn Preconditioner {
            match plan {
                SolvePlan::Mbcg(pre) => pre.as_ref(),
                _ => unreachable!("iter_idx only holds Mbcg plans"),
            }
        }
        let preconds: Vec<&dyn Preconditioner> =
            iter_idx.iter().map(|&i| mbcg_precond(plans[i])).collect();
        let sub_bs: Vec<&Mat> = iter_idx.iter().map(|&i| bs[i]).collect();
        let (results, _stats) = mbcg_batch_stats_ws(
            &sub,
            &sub_bs,
            &preconds,
            &MbcgOptions {
                max_iters: opts.max_iters,
                tol: opts.tol,
                n_solve_only: usize::MAX, // clamped per system: no tridiags
            },
            ws,
        );
        for (k, res) in iter_idx.iter().zip(results) {
            out[*k] = Some(res.solves);
        }
    }
    out.into_iter()
        .map(|m| m.expect("every element solved"))
        .collect()
}

/// Any prepared [`SolvePlan`] viewed as a [`Preconditioner`] — the adapter
/// that lets **direct-planned** blocks (Cholesky / Woodbury / circulant)
/// join one fused mBCG loop alongside iterative blocks. A direct plan is
/// the operator's *exact* inverse, so the preconditioned initial guess
/// `z₀ = A⁻¹b` converges at the first α-step (`α = 1 + O(ε)`, residual at
/// rounding level) and the block drops out of the batched product
/// immediately — the fused heterogeneous tick pays it one iteration, not a
/// separate solve path. `Mbcg` plans pass their §4.1 preconditioner
/// through unchanged.
pub struct PlanPrecond<'a>(pub &'a SolvePlan);

impl Preconditioner for PlanPrecond<'_> {
    fn solve_mat(&self, m: &Mat) -> Mat {
        match self.0 {
            SolvePlan::Cholesky(ch) => ch.solve_mat(m),
            SolvePlan::Woodbury(direct) => direct.solve_mat(m),
            SolvePlan::Circulant(c) => c.solve_mat(m),
            SolvePlan::Mbcg(pre) => pre.solve_mat(m),
        }
    }

    fn logdet(&self) -> f64 {
        match self.0 {
            SolvePlan::Cholesky(ch) => ch.logdet(),
            SolvePlan::Woodbury(direct) => direct.logdet(),
            SolvePlan::Circulant(c) => c.logdet(),
            SolvePlan::Mbcg(pre) => pre.logdet(),
        }
    }

    fn sample_probes(&self, n: usize, t: usize, rng: &mut crate::util::Rng) -> Mat {
        match self.0 {
            // the solve path never draws probes through a direct plan;
            // Rademacher (E[zzᵀ] = I) is the unpreconditioned default
            SolvePlan::Cholesky(_) | SolvePlan::Woodbury(_) | SolvePlan::Circulant(_) => {
                Mat::from_fn(n, t, |_, _| rng.rademacher())
            }
            SolvePlan::Mbcg(pre) => pre.sample_probes(n, t, rng),
        }
    }

    fn rank(&self) -> usize {
        match self.0 {
            SolvePlan::Woodbury(direct) => direct.rank(),
            SolvePlan::Mbcg(pre) => pre.rank(),
            _ => 0,
        }
    }
}

/// **Heterogeneous fused batch solve** — the serving tick's hot path.
/// Solves `elsᵢ⁻¹ · bsᵢ` for blocks of **any mix of sizes and model
/// families** through exactly ONE [`mbcg_batch_hetero_ws`] iteration loop:
/// every block's plan becomes its preconditioner via [`PlanPrecond`], so
/// direct-planned blocks (exact/SGPR/grid tenants) converge at the first
/// α-step while iterative blocks run preconditioned mBCG to their own
/// per-block tolerance (`opts[i]`). Returns the per-block solves plus the
/// fused loop's [`MbcgBatchStats`] (batched-product and iteration
/// counters — what the serving metrics report as fused-tick occupancy).
///
/// Equivalent to b sequential [`solve_with`] calls to rounding level
/// (each block's α/β recurrence runs on its own residuals — block results
/// are independent of their co-batched neighbours).
pub fn solve_batch_hetero_ws(
    els: &[&dyn LinearOp],
    plans: &[&SolvePlan],
    bs: &[&Mat],
    opts: &[SolveOptions],
    ws: &mut MbcgWorkspace,
) -> (Vec<Mat>, MbcgBatchStats) {
    let b = els.len();
    assert_eq!(plans.len(), b, "solve_batch_hetero: plan count mismatch");
    assert_eq!(bs.len(), b, "solve_batch_hetero: RHS count mismatch");
    assert_eq!(opts.len(), b, "solve_batch_hetero: options count mismatch");
    let batch = BatchOp::hetero(els.to_vec());
    let preconds: Vec<PlanPrecond<'_>> = plans.iter().map(|p| PlanPrecond(p)).collect();
    let precond_refs: Vec<&dyn Preconditioner> =
        preconds.iter().map(|p| p as &dyn Preconditioner).collect();
    let mopts: Vec<MbcgOptions> = opts
        .iter()
        .map(|o| MbcgOptions {
            max_iters: o.max_iters,
            tol: o.tol,
            n_solve_only: usize::MAX, // clamped per system: no tridiags
        })
        .collect();
    let (results, stats) = mbcg_batch_hetero_ws(&batch, bs, &precond_refs, &mopts, ws);
    (results.into_iter().map(|r| r.solves).collect(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::{AddedDiagOp, DenseOp, LowRankOp};
    use crate::util::Rng;

    fn reference_solve(k: &Mat, b: &Mat) -> Mat {
        Cholesky::new_with_jitter(k).unwrap().solve_mat(b)
    }

    #[test]
    fn woodbury_branch_is_exact() {
        let mut rng = Rng::new(1);
        let l = Mat::from_fn(40, 5, |_, _| rng.normal());
        let op = AddedDiagOp::new(LowRankOp::new(l.clone()), 0.3);
        assert_eq!(solve_strategy(&op), SolveHint::Woodbury);
        let b = Mat::from_fn(40, 3, |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        let mut k = l.matmul_t(&l);
        k.add_diag(0.3);
        assert!(got.max_abs_diff(&reference_solve(&k, &b)) < 1e-9);
    }

    #[test]
    fn dense_branch_is_exact() {
        let mut rng = Rng::new(2);
        let g = Mat::from_fn(25, 25, |_, _| rng.normal());
        let mut k = g.t_matmul(&g);
        k.add_diag(1.0);
        let op = DenseOp::new(k.clone());
        assert_eq!(solve_strategy(&op), SolveHint::DenseCholesky);
        let b = Mat::from_fn(25, 2, |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        assert!(got.max_abs_diff(&reference_solve(&k, &b)) < 1e-9);
    }

    #[test]
    fn iterative_branch_converges_with_preconditioner() {
        // an AddedDiag over a dense *iterative-hinted* inner: wrap the
        // dense matrix in a matmul-only newtype so the hint stays Iterative
        struct MatmulOnly(Mat);
        impl crate::linalg::op::LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn diag(&self) -> Vec<f64> {
                (0..self.0.rows()).map(|i| self.0.get(i, i)).collect()
            }
            fn row(&self, i: usize) -> Vec<f64> {
                self.0.row(i).to_vec()
            }
        }
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..60).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(60, 60, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.05).exp()
        });
        let op = AddedDiagOp::new(MatmulOnly(k.clone()), 1e-2);
        assert_eq!(solve_strategy(&op), SolveHint::Iterative);
        let b = Mat::from_fn(60, 2, |_, _| rng.normal());
        let got = solve(
            &op,
            &b,
            &SolveOptions {
                max_iters: 200,
                tol: 1e-12,
                precond_rank: 6,
            },
        );
        let mut kn = k.clone();
        kn.add_diag(1e-2);
        assert!(got.max_abs_diff(&reference_solve(&kn, &b)) < 1e-6);
    }

    #[test]
    fn circulant_branch_is_exact() {
        use crate::linalg::op::ToeplitzLinOp;
        // periodic RBF-style column on a wrap-around pow2 grid: circulant
        let m = 64;
        let col: Vec<f64> = (0..m)
            .map(|k| {
                let d = k.min(m - k) as f64;
                (-0.05 * d * d).exp()
            })
            .collect();
        let op = AddedDiagOp::new(ToeplitzLinOp::new(col), 0.1);
        assert_eq!(solve_strategy(&op), SolveHint::CirculantFft);
        let built = plan(&op, &SolveOptions::default());
        assert!(built.is_direct());
        assert!(matches!(built, SolvePlan::Circulant(_)));
        let mut rng = Rng::new(11);
        let b = Mat::from_fn(m, 3, |_, _| rng.normal());
        let got = solve_with(&built, &op, &b, &SolveOptions::default());
        let want = reference_solve(&op.dense(), &b);
        assert!(got.max_abs_diff(&want) < 1e-9, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn non_circulant_toeplitz_stays_iterative() {
        use crate::linalg::op::ToeplitzLinOp;
        let m = 32;
        let col: Vec<f64> = (0..m).map(|k| (-0.1 * (k * k) as f64).exp()).collect();
        let op = AddedDiagOp::new(ToeplitzLinOp::new(col), 0.1);
        assert_eq!(solve_strategy(&op), SolveHint::Iterative);
    }

    #[test]
    fn indefinite_circulant_degrades_to_mbcg_plan() {
        use crate::linalg::op::ToeplitzLinOp;
        // strong off-diagonal mass drives an eigenvalue negative: the hint
        // still says circulant, but the plan must degrade to mBCG — which
        // then cannot be exact on an indefinite system, so only check the
        // plan shape
        let m = 8;
        let mut col = vec![0.0; m];
        col[0] = 1.0;
        col[1] = 10.0;
        col[m - 1] = 10.0;
        let op = ToeplitzLinOp::new(col);
        assert_eq!(solve_strategy(&op), SolveHint::CirculantFft);
        let built = plan(&op, &SolveOptions::default());
        assert!(matches!(built, SolvePlan::Mbcg(_)));
    }

    #[test]
    fn solve_batch_mixes_direct_and_iterative_plans() {
        use crate::linalg::op::BatchOp;
        let mut rng = Rng::new(21);
        let n = 40;
        // element 0: Woodbury-direct; element 1: iterative (matmul-only)
        struct MatmulOnly(Mat);
        impl crate::linalg::op::LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn diag(&self) -> Vec<f64> {
                (0..self.0.rows()).map(|i| self.0.get(i, i)).collect()
            }
            fn row(&self, i: usize) -> Vec<f64> {
                self.0.row(i).to_vec()
            }
        }
        let l = Mat::from_fn(n, 4, |_, _| rng.normal());
        let direct = AddedDiagOp::new(LowRankOp::new(l.clone()), 0.2);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.1).exp()
        });
        let iterative = AddedDiagOp::new(MatmulOnly(k.clone()), 0.05);
        let batch = BatchOp::new(vec![
            &direct as &dyn crate::linalg::op::LinearOp,
            &iterative as &dyn crate::linalg::op::LinearOp,
        ]);
        let opts = SolveOptions {
            max_iters: 300,
            tol: 1e-12,
            precond_rank: 6,
        };
        let plans = crate::linalg::op::plan_batch(&batch, &opts);
        assert!(plans[0].is_direct());
        assert!(!plans[1].is_direct());
        let b0 = Mat::from_fn(n, 2, |_, _| rng.normal());
        let b1 = Mat::from_fn(n, 3, |_, _| rng.normal());
        let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
        let got = crate::linalg::op::solve_batch(&batch, &plan_refs, &[&b0, &b1], &opts);
        let want0 = reference_solve(&direct.dense(), &b0);
        let want1 = reference_solve(&iterative.dense(), &b1);
        assert!(got[0].max_abs_diff(&want0) < 1e-8);
        assert!(got[1].max_abs_diff(&want1) < 1e-6);
    }

    #[test]
    fn shared_plan_batch_builds_one_pivoted_factor_per_sigma() {
        use crate::linalg::op::BatchOp;
        let mut rng = Rng::new(31);
        let n = 50;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.08).exp()
        });
        struct MatmulOnly(Mat);
        impl crate::linalg::op::LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn diag(&self) -> Vec<f64> {
                (0..self.0.rows()).map(|i| self.0.get(i, i)).collect()
            }
            fn row(&self, i: usize) -> Vec<f64> {
                self.0.row(i).to_vec()
            }
        }
        let cov = MatmulOnly(k.clone());
        let sigma2s = vec![0.05, 0.2, 0.8];
        let batch = BatchOp::shared(&cov, sigma2s.clone());
        let opts = SolveOptions {
            max_iters: 400,
            tol: 1e-12,
            precond_rank: 5,
        };
        let plans = crate::linalg::op::plan_batch(&batch, &opts);
        assert_eq!(plans.len(), 3);
        assert!(plans.iter().all(|p| !p.is_direct()));
        let bs: Vec<Mat> = (0..3).map(|_| Mat::from_fn(n, 2, |_, _| rng.normal())).collect();
        let b_refs: Vec<&Mat> = bs.iter().collect();
        let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
        let got = crate::linalg::op::solve_batch(&batch, &plan_refs, &b_refs, &opts);
        for (i, g) in got.iter().enumerate() {
            let mut kn = k.clone();
            kn.add_diag(sigma2s[i]);
            let want = reference_solve(&kn, &bs[i]);
            assert!(g.max_abs_diff(&want) < 1e-6, "element {i}: {}", g.max_abs_diff(&want));
        }
    }

    #[test]
    fn hetero_solve_batch_fuses_mixed_sizes_and_families_in_one_loop() {
        use crate::linalg::op::LinearOp;
        let mut rng = Rng::new(41);
        // three tenants, three sizes, three families: SGPR-style Woodbury
        // (n=40), dense-Cholesky exact (n=25), iterative RBF (n=55)
        struct MatmulOnly(Mat);
        impl crate::linalg::op::LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn diag(&self) -> Vec<f64> {
                (0..self.0.rows()).map(|i| self.0.get(i, i)).collect()
            }
            fn row(&self, i: usize) -> Vec<f64> {
                self.0.row(i).to_vec()
            }
        }
        let l = Mat::from_fn(40, 4, |_, _| rng.normal());
        let sgpr = AddedDiagOp::new(LowRankOp::new(l.clone()), 0.2);
        let g = Mat::from_fn(25, 25, |_, _| rng.normal());
        let mut kd = g.t_matmul(&g);
        kd.add_diag(1.0);
        let exact = DenseOp::new(kd);
        let xs: Vec<f64> = (0..55).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(55, 55, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.1).exp()
        });
        let iterative = AddedDiagOp::new(MatmulOnly(k), 0.05);

        let els: Vec<&dyn LinearOp> = vec![&sgpr, &exact, &iterative];
        let opts = SolveOptions {
            max_iters: 300,
            tol: 1e-12,
            precond_rank: 6,
        };
        let plans: Vec<SolvePlan> = els.iter().map(|&e| plan(e, &opts)).collect();
        assert!(plans[0].is_direct() && plans[1].is_direct() && !plans[2].is_direct());
        let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
        let bs: Vec<Mat> = els
            .iter()
            .map(|e| Mat::from_fn(e.n(), 2, |_, _| rng.normal()))
            .collect();
        let b_refs: Vec<&Mat> = bs.iter().collect();
        let per_opts = vec![opts; 3];
        let mut ws = MbcgWorkspace::new();
        let (got, stats) =
            solve_batch_hetero_ws(&els, &plan_refs, &b_refs, &per_opts, &mut ws);
        // the whole mixed batch ran one iteration loop; direct blocks
        // converge at the first α-step, so total iterations stay near the
        // iterative block's own count
        assert!(stats.batched_products > 0);
        // acceptance bar: per-block parity vs sequential solves, 1e-10 rel
        for (i, &e) in els.iter().enumerate() {
            let seq = solve_with(plan_refs[i], e, &bs[i], &opts);
            let denom = seq.fro_norm().max(1e-300);
            let rel = got[i].max_abs_diff(&seq) / denom;
            assert!(rel < 1e-10, "block {i}: rel diff {rel}");
        }
    }

    #[test]
    fn woodbury_hint_without_split_falls_back_to_iterative() {
        // a bare LowRankOp hints Iterative; force a misleading hint and
        // confirm the resolver downgrades it
        struct LyingOp(LowRankOp);
        impl crate::linalg::op::LinearOp for LyingOp {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn solve_hint(&self) -> SolveHint {
                SolveHint::Woodbury
            }
        }
        let mut rng = Rng::new(4);
        let l = Mat::from_fn(10, 2, |_, _| rng.normal());
        let op = LyingOp(LowRankOp::new(l));
        assert_eq!(solve_strategy(&op), SolveHint::Iterative);
    }
}
