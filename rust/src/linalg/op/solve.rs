//! The solve-strategy dispatcher: one generic `K̂⁻¹·B` entry point that
//! picks **direct** (dense Cholesky, Woodbury) or **iterative**
//! (preconditioned mBCG) from the operator's declared structure.
//!
//! This is the single path exact, SGPR, SKI, sharded, and multitask
//! models all solve through — `predict`, the serving coordinator, and the
//! engines dispatch here instead of hand-matching on model types:
//!
//! - [`SolveHint::Woodbury`] + an extractable `L·Lᵀ + σ²I` split → exact
//!   Woodbury solve in O(nk² + k³) (the SGPR direct path, no CG at all),
//! - [`SolveHint::DenseCholesky`] → materialise + factor (small/dense),
//! - [`SolveHint::Iterative`] → mBCG with the §4.1 pivoted-Cholesky
//!   preconditioner built from the operator's [`LinearOp::noise_split`].

use super::{LinearOp, SolveHint};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mbcg::{mbcg, MbcgOptions};
use crate::linalg::pivoted_cholesky::pivoted_cholesky;
use crate::linalg::preconditioner::{IdentityPrecond, PartialCholPrecond, Preconditioner};
use crate::tensor::Mat;

/// Knobs for the generic solve path (the iterative branch; direct
/// branches are exact and ignore the CG fields).
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// maximum mBCG iterations
    pub max_iters: usize,
    /// relative-residual tolerance per RHS column
    pub tol: f64,
    /// pivoted-Cholesky preconditioner rank (0 disables)
    pub precond_rank: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 100,
            tol: 1e-10,
            precond_rank: 5,
        }
    }
}

/// `(L, σ²)` when the operator is exactly `L·Lᵀ + σ²I`.
fn woodbury_parts(op: &dyn LinearOp) -> Option<(&Mat, f64)> {
    let (inner, sigma2) = op.noise_split()?;
    let l = inner.low_rank_factor()?;
    Some((l, sigma2))
}

/// Resolve the operator's hint against the structure it actually exposes:
/// a `Woodbury` hint only holds when the `L·Lᵀ + σ²I` split is
/// extractable, otherwise the dispatcher falls back to mBCG.
pub fn solve_strategy(op: &dyn LinearOp) -> SolveHint {
    match op.solve_hint() {
        SolveHint::Woodbury => {
            if woodbury_parts(op).is_some() {
                SolveHint::Woodbury
            } else {
                SolveHint::Iterative
            }
        }
        h => h,
    }
}

/// Build the §4.1 preconditioner `P̂ = L_k·L_kᵀ + σ²I` for an operator of
/// the form `K + σ²I`: rank-`rank` pivoted Cholesky over the noise-free
/// part's `diag`/`row`. Operators without a noise split (or `rank == 0`)
/// get the identity.
pub fn build_preconditioner(op: &dyn LinearOp, rank: usize) -> Box<dyn Preconditioner + Send> {
    let Some((inner, sigma2)) = op.noise_split() else {
        return Box::new(IdentityPrecond);
    };
    if rank == 0 {
        return Box::new(IdentityPrecond);
    }
    let diag = inner.diag();
    let pc = pivoted_cholesky(&diag, |i| inner.row(i), rank, 0.0);
    if pc.l.cols() == 0 {
        return Box::new(IdentityPrecond);
    }
    Box::new(PartialCholPrecond::new(pc.l, sigma2))
}

/// Factorisation state prepared once and reused across solves against a
/// fixed operator — what a serving loop should hold instead of paying a
/// refactorisation (capacitance Cholesky, pivoted-Cholesky preconditioner
/// build) per request batch.
pub enum SolvePlan {
    /// direct dense Cholesky factor of the full operator
    Cholesky(Cholesky),
    /// direct Woodbury solve of `L·Lᵀ + σ²I` (capacitance prefactored)
    Woodbury(PartialCholPrecond),
    /// preconditioned mBCG with the §4.1 preconditioner prebuilt
    Mbcg(Box<dyn Preconditioner + Send>),
}

/// Prepare the solver for an operator once (the expensive, structure-
/// dependent part of [`solve`]).
pub fn plan(op: &dyn LinearOp, opts: &SolveOptions) -> SolvePlan {
    match solve_strategy(op) {
        SolveHint::Woodbury => {
            // (LLᵀ + σ²I)⁻¹ is exactly the partial-Cholesky preconditioner's
            // Woodbury solve — reuse it as the direct solver
            let (l, sigma2) = woodbury_parts(op).expect("strategy guaranteed the split");
            SolvePlan::Woodbury(PartialCholPrecond::new(l.clone(), sigma2))
        }
        SolveHint::DenseCholesky => SolvePlan::Cholesky(
            Cholesky::new_with_jitter(&op.dense()).expect("operator not PD even with jitter"),
        ),
        SolveHint::Iterative => SolvePlan::Mbcg(build_preconditioner(op, opts.precond_rank)),
    }
}

/// Batched solve `op⁻¹ · b` through a prepared [`SolvePlan`] (the `op`
/// must be the one the plan was built for).
pub fn solve_with(plan: &SolvePlan, op: &dyn LinearOp, b: &Mat, opts: &SolveOptions) -> Mat {
    match plan {
        SolvePlan::Woodbury(direct) => direct.solve_mat(b),
        SolvePlan::Cholesky(ch) => ch.solve_mat(b),
        SolvePlan::Mbcg(pre) => mbcg(
            |m| op.matmul(m),
            b,
            |m| pre.solve_mat(m),
            &MbcgOptions {
                max_iters: opts.max_iters,
                tol: opts.tol,
                n_solve_only: b.cols(), // tridiagonals unused here
            },
        )
        .solves,
    }
}

/// Generic batched solve `op⁻¹ · b`, dispatched on [`solve_strategy`].
/// One-shot convenience over [`plan`] + [`solve_with`]; callers solving
/// repeatedly against the same operator should hold the plan.
pub fn solve(op: &dyn LinearOp, b: &Mat, opts: &SolveOptions) -> Mat {
    solve_with(&plan(op, opts), op, b, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::{AddedDiagOp, DenseOp, LowRankOp};
    use crate::util::Rng;

    fn reference_solve(k: &Mat, b: &Mat) -> Mat {
        Cholesky::new_with_jitter(k).unwrap().solve_mat(b)
    }

    #[test]
    fn woodbury_branch_is_exact() {
        let mut rng = Rng::new(1);
        let l = Mat::from_fn(40, 5, |_, _| rng.normal());
        let op = AddedDiagOp::new(LowRankOp::new(l.clone()), 0.3);
        assert_eq!(solve_strategy(&op), SolveHint::Woodbury);
        let b = Mat::from_fn(40, 3, |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        let mut k = l.matmul_t(&l);
        k.add_diag(0.3);
        assert!(got.max_abs_diff(&reference_solve(&k, &b)) < 1e-9);
    }

    #[test]
    fn dense_branch_is_exact() {
        let mut rng = Rng::new(2);
        let g = Mat::from_fn(25, 25, |_, _| rng.normal());
        let mut k = g.t_matmul(&g);
        k.add_diag(1.0);
        let op = DenseOp::new(k.clone());
        assert_eq!(solve_strategy(&op), SolveHint::DenseCholesky);
        let b = Mat::from_fn(25, 2, |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        assert!(got.max_abs_diff(&reference_solve(&k, &b)) < 1e-9);
    }

    #[test]
    fn iterative_branch_converges_with_preconditioner() {
        // an AddedDiag over a dense *iterative-hinted* inner: wrap the
        // dense matrix in a matmul-only newtype so the hint stays Iterative
        struct MatmulOnly(Mat);
        impl crate::linalg::op::LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn diag(&self) -> Vec<f64> {
                (0..self.0.rows()).map(|i| self.0.get(i, i)).collect()
            }
            fn row(&self, i: usize) -> Vec<f64> {
                self.0.row(i).to_vec()
            }
        }
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..60).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(60, 60, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.05).exp()
        });
        let op = AddedDiagOp::new(MatmulOnly(k.clone()), 1e-2);
        assert_eq!(solve_strategy(&op), SolveHint::Iterative);
        let b = Mat::from_fn(60, 2, |_, _| rng.normal());
        let got = solve(
            &op,
            &b,
            &SolveOptions {
                max_iters: 200,
                tol: 1e-12,
                precond_rank: 6,
            },
        );
        let mut kn = k.clone();
        kn.add_diag(1e-2);
        assert!(got.max_abs_diff(&reference_solve(&kn, &b)) < 1e-6);
    }

    #[test]
    fn woodbury_hint_without_split_falls_back_to_iterative() {
        // a bare LowRankOp hints Iterative; force a misleading hint and
        // confirm the resolver downgrades it
        struct LyingOp(LowRankOp);
        impl crate::linalg::op::LinearOp for LyingOp {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
            fn solve_hint(&self) -> SolveHint {
                SolveHint::Woodbury
            }
        }
        let mut rng = Rng::new(4);
        let l = Mat::from_fn(10, 2, |_, _| rng.normal());
        let op = LyingOp(LowRankOp::new(l));
        assert_eq!(solve_strategy(&op), SolveHint::Iterative);
    }
}
