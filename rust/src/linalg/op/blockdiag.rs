//! Block-diagonal stacking of **different-shape** operators — the
//! heterogeneous-serving seam.
//!
//! [`super::BatchOp`] fuses same-n systems; a realistic multi-tenant
//! deployment mixes tenants of different n (and different model families:
//! exact, SGPR, SKI). [`BlockDiagOp`] stacks b square operators
//! `A₁ … A_b` as one `Σnᵢ × Σnᵢ` operator
//!
//! ```text
//!   ⎡A₁        ⎤
//!   ⎢   A₂     ⎥      matmul partitions the RHS rows per block and
//!   ⎢      ⋱   ⎥      dispatches each block's own structured product —
//!   ⎣        A_b⎦      no n×n (let alone Σn×Σn) is ever materialised.
//! ```
//!
//! Structure composes per block: `diag`/`row`/`entry` index through the
//! block row partition, `fingerprint()` combines the per-block
//! fingerprints (order-sensitive), and `noise_split` lifts **uniform**
//! per-block noise (`Aᵢ = Bᵢ + σ²I` with one shared σ²) into
//! `blockdiag(B₁…B_b) + σ²I`. Mixed per-block noise does not split — the
//! heterogeneous solver path ([`super::solve::solve_batch_hetero_ws`])
//! preconditions each block independently instead, which is also why
//! [`BlockDiagOp::solve_hint`] is [`SolveHint::Iterative`].

use super::{LinearOp, SolveHint};
use crate::tensor::Mat;

/// Square operators stacked block-diagonally: shape = `(Σnᵢ, Σnᵢ)`.
pub struct BlockDiagOp<'a> {
    blocks: Vec<&'a dyn LinearOp>,
    /// Row offsets: `offsets[i]..offsets[i+1]` are block i's rows
    /// (len = blocks.len() + 1, last entry = Σnᵢ).
    offsets: Vec<usize>,
    /// Uniform-noise lift: when every block splits as `Bᵢ + σ²I` with the
    /// same σ², the stacked noise-free part and that σ².
    inner: Option<(Box<BlockDiagOp<'a>>, f64)>,
}

impl<'a> BlockDiagOp<'a> {
    /// Stack `blocks` block-diagonally. Each block must be square; shapes
    /// may differ freely (that is the point).
    pub fn new(blocks: Vec<&'a dyn LinearOp>) -> Self {
        assert!(!blocks.is_empty(), "BlockDiagOp: no blocks");
        let mut offsets = Vec::with_capacity(blocks.len() + 1);
        offsets.push(0);
        for b in &blocks {
            let (r, c) = b.shape();
            assert_eq!(r, c, "BlockDiagOp: blocks must be square");
            offsets.push(offsets.last().unwrap() + r);
        }
        // uniform-noise lift: all blocks split with one shared σ²
        let splits: Option<Vec<(&'a dyn LinearOp, f64)>> =
            blocks.iter().map(|b| b.noise_split()).collect();
        let inner = splits.and_then(|parts| {
            let s2 = parts[0].1;
            if parts.iter().all(|&(_, s)| s.to_bits() == s2.to_bits()) {
                let inners: Vec<&'a dyn LinearOp> = parts.iter().map(|&(b, _)| b).collect();
                Some((Box::new(BlockDiagOp::new(inners)), s2))
            } else {
                None
            }
        });
        BlockDiagOp {
            blocks,
            offsets,
            inner,
        }
    }

    /// Number of stacked blocks.
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    /// True when no blocks are stacked (unreachable via [`BlockDiagOp::new`]).
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// The stacked blocks, in row order.
    pub fn blocks(&self) -> &[&'a dyn LinearOp] {
        &self.blocks
    }

    /// Block i's global row range.
    pub fn block_range(&self, i: usize) -> std::ops::Range<usize> {
        self.offsets[i]..self.offsets[i + 1]
    }

    /// Which block global row `r` falls in.
    fn block_of(&self, r: usize) -> usize {
        debug_assert!(r < self.n());
        // offsets is sorted; partition_point gives the first offset > r
        self.offsets.partition_point(|&o| o <= r) - 1
    }
}

impl LinearOp for BlockDiagOp<'_> {
    fn shape(&self) -> (usize, usize) {
        let n = *self.offsets.last().unwrap();
        (n, n)
    }

    /// Partition the RHS rows per block and run each block's own fused
    /// product — b structured dispatches, zero dense materialisation.
    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = Mat::zeros(self.n(), m.cols());
        self.matmul_into(m, &mut out);
        out
    }

    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        assert_eq!(m.rows(), self.n(), "BlockDiagOp: rhs row mismatch");
        assert_eq!(out.shape(), (self.n(), m.cols()), "BlockDiagOp: out shape");
        let t = m.cols();
        for (i, block) in self.blocks.iter().enumerate() {
            let r = self.block_range(i);
            // row-major ⇒ a row range is one contiguous slice
            let sub = Mat::from_vec(r.len(), t, m.data()[r.start * t..r.end * t].to_vec());
            let mut prod = Mat::zeros(r.len(), t);
            block.matmul_into(&sub, &mut prod);
            out.data_mut()[r.start * t..r.end * t].copy_from_slice(prod.data());
        }
    }

    fn prepare(&self) {
        for b in &self.blocks {
            b.prepare();
        }
    }

    fn n_params(&self) -> usize {
        self.blocks.iter().map(|b| b.n_params()).sum()
    }

    fn mmm_tag(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        for b in &self.blocks {
            b.mmm_tag().hash(&mut h);
        }
        h.finish()
    }

    fn diag(&self) -> Vec<f64> {
        let mut d = Vec::with_capacity(self.n());
        for b in &self.blocks {
            d.extend(b.diag());
        }
        d
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let k = self.block_of(i);
        let r = self.block_range(k);
        let mut row = vec![0.0; self.n()];
        row[r.clone()].copy_from_slice(&self.blocks[k].row(i - r.start));
        row
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let k = self.block_of(i);
        let r = self.block_range(k);
        if r.contains(&j) {
            self.blocks[k].entry(i - r.start, j - r.start)
        } else {
            0.0
        }
    }

    fn solve_hint(&self) -> SolveHint {
        // blocks may each favour a different direct strategy; the stacked
        // operator itself only has a black-box product
        SolveHint::Iterative
    }

    fn noise_split(&self) -> Option<(&dyn LinearOp, f64)> {
        self.inner
            .as_ref()
            .map(|(op, s2)| (op.as_ref() as &dyn LinearOp, *s2))
    }

    /// Combine the per-block fingerprints (order-sensitive): any block's
    /// hyperparameter move re-fingerprints the stack, so cached plans for
    /// the stacked operator invalidate exactly when a tenant changes.
    fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.offsets.hash(&mut h);
        for b in &self.blocks {
            b.fingerprint().hash(&mut h);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::{AddedDiagOp, DenseOp, LowRankOp};
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut s = g.t_matmul(&g);
        s.add_diag(1.0);
        s.symmetrize();
        s
    }

    /// Dense reference: blocks placed on the diagonal of a Σn×Σn zero
    /// matrix.
    fn assemble(blocks: &[&Mat]) -> Mat {
        let n: usize = blocks.iter().map(|b| b.rows()).sum();
        let mut out = Mat::zeros(n, n);
        let mut o = 0;
        for b in blocks {
            for r in 0..b.rows() {
                for c in 0..b.cols() {
                    out.set(o + r, o + c, b.get(r, c));
                }
            }
            o += b.rows();
        }
        out
    }

    #[test]
    fn matmul_diag_row_entry_match_dense_assembly() {
        let (a, b, c) = (spd(7, 1), spd(12, 2), spd(5, 3));
        let (oa, ob, oc) = (DenseOp::new(a.clone()), DenseOp::new(b.clone()), DenseOp::new(c.clone()));
        let op = BlockDiagOp::new(vec![&oa, &ob, &oc]);
        let want = assemble(&[&a, &b, &c]);
        assert_eq!(op.shape(), (24, 24));
        assert_eq!(op.len(), 3);
        assert_eq!(op.block_range(1), 7..19);

        let mut rng = Rng::new(4);
        let m = Mat::from_fn(24, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-12);

        let d = op.diag();
        for i in 0..24 {
            assert_eq!(d[i], want.get(i, i));
            let row = op.row(i);
            for j in 0..24 {
                assert_eq!(row[j], want.get(i, j), "row ({i},{j})");
                assert_eq!(op.entry(i, j), want.get(i, j), "entry ({i},{j})");
            }
        }
        assert!(op.dense().max_abs_diff(&want) == 0.0);
        assert_eq!(op.solve_hint(), SolveHint::Iterative);
    }

    #[test]
    fn fingerprint_is_block_sensitive() {
        let (a, b) = (spd(6, 5), spd(9, 6));
        let (oa, ob) = (DenseOp::new(a.clone()), DenseOp::new(b));
        let fp = BlockDiagOp::new(vec![&oa, &ob]).fingerprint();
        // same stack again: deterministic
        assert_eq!(fp, BlockDiagOp::new(vec![&oa, &ob]).fingerprint());
        // perturb one block: fingerprint moves
        let mut a2 = a;
        a2.add_diag(0.125);
        let oa2 = DenseOp::new(a2);
        assert_ne!(fp, BlockDiagOp::new(vec![&oa2, &ob]).fingerprint());
        // swap order: fingerprint moves (offsets + order are hashed)
        assert_ne!(fp, BlockDiagOp::new(vec![&ob, &oa]).fingerprint());
    }

    #[test]
    fn uniform_noise_split_lifts_mixed_does_not() {
        let mut rng = Rng::new(7);
        let la = Mat::from_fn(8, 2, |_, _| rng.normal());
        let lb = Mat::from_fn(5, 3, |_, _| rng.normal());
        let (ka, kb) = (LowRankOp::new(la), LowRankOp::new(lb));
        let (na, nb) = (AddedDiagOp::new(&ka, 0.3), AddedDiagOp::new(&kb, 0.3));
        let op = BlockDiagOp::new(vec![&na, &nb]);
        let (inner, s2) = op.noise_split().expect("uniform σ² must lift");
        // σ² round-trips through log-space storage, so compare loosely
        assert!((s2 - 0.3).abs() < 1e-15);
        assert_eq!(inner.shape(), (13, 13));
        let want_inner = assemble(&[&ka.dense(), &kb.dense()]);
        assert!(inner.dense().max_abs_diff(&want_inner) < 1e-12);
        assert!((op.noise() - 0.3).abs() < 1e-15);

        let nb2 = AddedDiagOp::new(&kb, 0.4);
        let mixed = BlockDiagOp::new(vec![&na, &nb2]);
        assert!(mixed.noise_split().is_none());
    }
}
