//! Composable **linear-operator algebra** — the single abstraction every
//! inference path consumes, from mBCG training to the serving coordinator.
//!
//! The paper's programmability claim (§5) is that a GP model is nothing but
//! a structured matrix that knows how to multiply itself against a dense
//! block. This module makes that literal: [`LinearOp`] is the one trait,
//! and models are *compositions* of structured implementations —
//!
//! - [`DenseOp`] — an explicit matrix (tests, baselines, small blocks),
//! - [`AddedDiagOp`] — `A + σ²I` as a composition (noise is no longer baked
//!   into every operator),
//! - [`SumOp`] / [`ScaledOp`] / [`DiagOp`] — closure under `+` and `·c`,
//! - [`LowRankOp`] — `L·Lᵀ`, the Woodbury seam (SGPR, linear kernels),
//! - [`KroneckerOp`] / [`ToeplitzLinOp`] — structure wrappers over
//!   [`crate::linalg::kronecker`] and [`crate::linalg::toeplitz`],
//! - [`InterpOp`] — SKI's `W·A·Wᵀ` interpolation sandwich,
//! - [`ShardedOp`] — row-sharded partial products
//!   ([`crate::linalg::mbcg::ShardedMmm`]) as an operator.
//!
//! The [`solve()`] dispatcher routes a linear solve to the right strategy
//! — dense Cholesky, direct Woodbury, or preconditioned mBCG — from the
//! operator's declared structure ([`LinearOp::solve_hint`]), so exact,
//! SGPR, SKI, and sharded models all solve through one generic path.
//!
//! (The seed-era `kernels::KernelOperator` trait was folded into this one;
//! its deprecated re-export has been removed — import [`LinearOp`].)

pub mod batch;
pub mod blockdiag;
pub mod cache;
pub mod compose;
pub mod interp;
pub mod lowrank;
pub mod mmm;
pub mod sharded;
pub mod solve;
pub mod structured;

pub use batch::{lift_added_diag, lift_low_rank, lift_scaled, lift_sum, BatchOp};
pub use blockdiag::BlockDiagOp;
pub use cache::SolvePlanCache;
pub use compose::{AddedDiagOp, DiagOp, ScaledOp, SumOp};
pub use interp::{InterpOp, SparseInterp};
pub use lowrank::LowRankOp;
pub use mmm::{MmmPlan, Precision};
pub use sharded::ShardedOp;
pub use solve::{
    build_preconditioner, build_preconditioner_batch, plan, plan_batch, solve, solve_batch,
    solve_batch_hetero_ws, solve_batch_ws, solve_cached, solve_strategy, solve_with,
    CirculantPlan, PlanPrecond, SolveOptions, SolvePlan,
};
pub use structured::{KroneckerOp, ToeplitzLinOp};

use crate::tensor::Mat;

/// Which solve strategy an operator's structure makes optimal. The
/// dispatcher in [`solve()`] resolves this hint against what the operator
/// actually exposes ([`LinearOp::noise_split`], [`LinearOp::low_rank_factor`],
/// [`LinearOp::circulant_column`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveHint {
    /// Materialise and Cholesky-factor: right for explicitly dense
    /// operators where `matmul` is already O(n²) per column.
    DenseCholesky,
    /// Diagonal-plus-low-rank structure: exact Woodbury solve in
    /// O(nk² + k³) — the SGPR direct path.
    Woodbury,
    /// Circulant structure: exact direct solve by FFT diagonalisation in
    /// O(n log n) — taken by Toeplitz grid covariances (and their
    /// AddedDiag/Scaled/Sum compositions) whose column is an exact
    /// circulant.
    CirculantFft,
    /// Fast black-box `matmul`: iterative mBCG (the paper's engine).
    /// This is the default.
    Iterative,
}

/// Out-of-range raw-parameter index handed to a gradient accessor — the
/// non-panicking twin of the [`LinearOp::dmatmul`] contract violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamOutOfRange {
    /// how many raw parameters the operator has
    pub n_params: usize,
    /// the offending index
    pub param: usize,
}

impl std::fmt::Display for ParamOutOfRange {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "operator has {} parameters, asked for {}",
            self.n_params, self.param
        )
    }
}

impl std::error::Error for ParamOutOfRange {}

/// A symmetric positive-(semi)definite linear operator `A`, accessed only
/// through structured products — the blackbox every engine consumes.
///
/// Semantics: all accessors describe the **full composed matrix**. If an
/// operator is `K + σ²I` (an [`AddedDiagOp`]), its `diag`/`row`/`dense`
/// include the σ² term; the noise-free part is reachable through
/// [`LinearOp::noise_split`]. (The seed-era `KernelOperator` returned
/// noise-*less* `diag`/`row` — callers that need those now go through
/// `noise_split`.)
///
/// Parameter indexing: raw (log-space) structural parameters come first;
/// a learnable added diagonal (likelihood noise) is always **last** —
/// compositions concatenate their children's parameter blocks in order.
pub trait LinearOp: Sync {
    /// (rows, cols) of the implicit matrix.
    fn shape(&self) -> (usize, usize);

    /// Convenience: the operator dimension `n` (all current ops are square).
    fn n(&self) -> usize {
        self.shape().0
    }

    /// Number of raw (log-space) parameters `dmatmul` differentiates by.
    fn n_params(&self) -> usize {
        0
    }

    /// `A · M` — the hot path (one call per mBCG iteration).
    fn matmul(&self, m: &Mat) -> Mat;

    /// `A · M` written into a caller-owned, same-shaped output — the
    /// zero-allocation seam the solver workspaces drive. The default
    /// delegates to [`LinearOp::matmul`] (which allocates) and copies;
    /// hot-path operators override it to write `out` directly.
    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        let r = self.matmul(m);
        assert_eq!(out.shape(), r.shape(), "matmul_into: output shape mismatch");
        out.copy_from(&r);
    }

    /// Build any plan-dependent materialisations now (kernel panel, r²
    /// panel — see [`mmm::MmmPlan`]) so the per-iteration products, and
    /// any allocation accounting around them, start from a warm state.
    /// Idempotent; default is a no-op.
    fn prepare(&self) {}

    /// Discriminant of the operator's materialisation plan, mixed into the
    /// default [`LinearOp::fingerprint`] so a plan switch invalidates
    /// cached solve plans. Operators without a plan report 0; wrappers
    /// forward their inner operator's tag.
    fn mmm_tag(&self) -> u64 {
        0
    }

    /// `(∂A/∂raw_p) · M`. Operators with `n_params() == 0` never receive
    /// this call; the default makes a stray call loud.
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let _ = m;
        panic!(
            "LinearOp::dmatmul: operator has {} parameters, asked for {param}",
            self.n_params()
        )
    }

    /// Diagonal of the full operator. Default is O(n · row-cost); every
    /// structured implementation overrides it.
    fn diag(&self) -> Vec<f64> {
        (0..self.n()).map(|i| self.row(i)[i]).collect()
    }

    /// Row `i` of the full operator. The default computes `A·eᵢ` (one
    /// `matmul`), which equals row `i` for the symmetric operators this
    /// algebra models; structured implementations override with O(n) or
    /// better.
    fn row(&self, i: usize) -> Vec<f64> {
        let (_r, c) = self.shape();
        let mut e = Mat::zeros(c, 1);
        e.set(i, 0, 1.0);
        self.matmul(&e).col(0)
    }

    /// Single entry `A[i, j]`. Default goes through [`LinearOp::row`];
    /// Toeplitz/Kronecker/dense structures override with O(1) — the fast
    /// path [`InterpOp`]'s stencil diagonal rides on.
    fn entry(&self, i: usize, j: usize) -> f64 {
        self.row(i)[j]
    }

    /// Which solve strategy this operator's structure favours.
    fn solve_hint(&self) -> SolveHint {
        SolveHint::Iterative
    }

    /// If the operator has the form `A + σ²I`, the noise-free part and σ².
    /// The preconditioner builder (§4.1) pivots on this: the rank-k pivoted
    /// Cholesky runs on the returned inner operator's `diag`/`row`.
    fn noise_split(&self) -> Option<(&dyn LinearOp, f64)> {
        None
    }

    /// If the operator is exactly `L·Lᵀ`, its factor — the seam the direct
    /// Woodbury solve (and SGPR) runs through.
    fn low_rank_factor(&self) -> Option<&Mat> {
        None
    }

    /// If the operator is exactly a **circulant** matrix whose size admits
    /// the in-tree radix-2 FFT (power of two), its first column — the seam
    /// the exact O(n log n) FFT direct solve runs through.
    /// [`ToeplitzLinOp`] advertises this when its column is circulant-
    /// symmetric (`c[k] = c[m−k]`); `AddedDiag`/`Scaled`/`Sum` compositions
    /// lift it (circulant matrices are closed under all three).
    fn circulant_column(&self) -> Option<Vec<f64>> {
        None
    }

    /// Content fingerprint for solve-plan caching: a hash over the
    /// operator's shape, parameter count, and a deterministic **probe** of
    /// its entries. Two operators with the same fingerprint are treated as
    /// the same matrix by [`SolvePlanCache`], so a hyperparameter update —
    /// which moves the noise term, the diagonal, or off-diagonal mass
    /// globally — invalidates cached factorisations automatically. The
    /// probe is sampled (≈48 entries), not exhaustive: an edit confined to
    /// unprobed entries (e.g. rewriting one kernel row in place) can slip
    /// past it, so operators supporting *localized* mutation should
    /// override this with a version counter. Cost is O(n) (one `diag` plus
    /// a bounded number of `entry` probes) — negligible next to any
    /// factorisation or solve.
    fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        let (r, c) = self.shape();
        r.hash(&mut h);
        c.hash(&mut h);
        self.n_params().hash(&mut h);
        self.mmm_tag().hash(&mut h);
        self.noise().to_bits().hash(&mut h);
        let n = self.n();
        if n == 0 {
            return h.finish();
        }
        // strided diagonal probe (≤ ~16 samples)
        let d = self.diag();
        let stride = (n / 16).max(1);
        let mut i = 0;
        while i < n {
            d[i].to_bits().hash(&mut h);
            i += stride;
        }
        // off-diagonal probes on a few rows (lengthscale-style parameters
        // move off-diagonal mass without touching a stationary diagonal)
        for &i in &[0, n / 3, (2 * n) / 3, n - 1] {
            let step = (n / 8).max(1);
            for k in 0..8usize.min(n) {
                let j = (i + 1 + k * step) % n;
                self.entry(i, j).to_bits().hash(&mut h);
            }
        }
        h.finish()
    }

    /// σ² of the outermost added diagonal (0.0 when there is none). Shim
    /// for the seed-era `KernelOperator::noise` surface.
    fn noise(&self) -> f64 {
        self.noise_split().map_or(0.0, |(_, s)| s)
    }

    /// Dense materialisation of the full operator (tests + the Cholesky
    /// baseline engine). Default builds from rows.
    fn dense(&self) -> Mat {
        let (r, _c) = self.shape();
        let mut out = Mat::zeros(r, self.shape().1);
        for i in 0..r {
            let row = self.row(i);
            out.row_mut(i).copy_from_slice(&row);
        }
        out
    }

    /// Concrete-type escape hatch for engines with a specialised direct
    /// path (e.g. the SGPR Woodbury-Cholesky baseline). Operators that
    /// want to be downcastable override this with `Some(self)`.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Implements the non-gradient surface of [`LinearOp`] by delegating to a
/// struct field holding a composed operator — the boilerplate-free way to
/// write a model as a *named* wrapper over an algebra composition. Use
/// inside an `impl LinearOp for Model` block; the model then supplies (or
/// delegates) `n_params`/`dmatmul`/`as_any`, which is exactly the surface
/// custom gradient math lives on.
#[macro_export]
macro_rules! linear_op_delegate {
    ($field:ident) => {
        fn shape(&self) -> (usize, usize) {
            self.$field.shape()
        }
        fn matmul(&self, m: &$crate::tensor::Mat) -> $crate::tensor::Mat {
            self.$field.matmul(m)
        }
        fn matmul_into(&self, m: &$crate::tensor::Mat, out: &mut $crate::tensor::Mat) {
            self.$field.matmul_into(m, out)
        }
        fn prepare(&self) {
            self.$field.prepare()
        }
        fn mmm_tag(&self) -> u64 {
            self.$field.mmm_tag()
        }
        fn diag(&self) -> Vec<f64> {
            self.$field.diag()
        }
        fn row(&self, i: usize) -> Vec<f64> {
            self.$field.row(i)
        }
        fn entry(&self, i: usize, j: usize) -> f64 {
            self.$field.entry(i, j)
        }
        fn solve_hint(&self) -> $crate::linalg::op::SolveHint {
            self.$field.solve_hint()
        }
        fn noise_split(&self) -> Option<(&dyn $crate::linalg::op::LinearOp, f64)> {
            self.$field.noise_split()
        }
        fn low_rank_factor(&self) -> Option<&$crate::tensor::Mat> {
            self.$field.low_rank_factor()
        }
        fn circulant_column(&self) -> Option<Vec<f64>> {
            self.$field.circulant_column()
        }
        fn fingerprint(&self) -> u64 {
            self.$field.fingerprint()
        }
        fn noise(&self) -> f64 {
            self.$field.noise()
        }
        fn dense(&self) -> $crate::tensor::Mat {
            self.$field.dense()
        }
    };
}

macro_rules! forward_linear_op {
    () => {
        fn shape(&self) -> (usize, usize) {
            (**self).shape()
        }
        fn n(&self) -> usize {
            (**self).n()
        }
        fn n_params(&self) -> usize {
            (**self).n_params()
        }
        fn matmul(&self, m: &Mat) -> Mat {
            (**self).matmul(m)
        }
        fn matmul_into(&self, m: &Mat, out: &mut Mat) {
            (**self).matmul_into(m, out)
        }
        fn prepare(&self) {
            (**self).prepare()
        }
        fn mmm_tag(&self) -> u64 {
            (**self).mmm_tag()
        }
        fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
            (**self).dmatmul(param, m)
        }
        fn diag(&self) -> Vec<f64> {
            (**self).diag()
        }
        fn row(&self, i: usize) -> Vec<f64> {
            (**self).row(i)
        }
        fn entry(&self, i: usize, j: usize) -> f64 {
            (**self).entry(i, j)
        }
        fn solve_hint(&self) -> SolveHint {
            (**self).solve_hint()
        }
        fn noise_split(&self) -> Option<(&dyn LinearOp, f64)> {
            (**self).noise_split()
        }
        fn low_rank_factor(&self) -> Option<&Mat> {
            (**self).low_rank_factor()
        }
        fn circulant_column(&self) -> Option<Vec<f64>> {
            (**self).circulant_column()
        }
        fn fingerprint(&self) -> u64 {
            (**self).fingerprint()
        }
        fn noise(&self) -> f64 {
            (**self).noise()
        }
        fn dense(&self) -> Mat {
            (**self).dense()
        }
        fn as_any(&self) -> Option<&dyn std::any::Any> {
            (**self).as_any()
        }
    };
}

impl<T: LinearOp + ?Sized> LinearOp for &T {
    forward_linear_op!();
}

impl<T: LinearOp + ?Sized> LinearOp for Box<T> {
    forward_linear_op!();
}

/// An explicit dense matrix as a [`LinearOp`] — the reference
/// implementation every composed operator is property-tested against, and
/// the right representation when `n` is small enough that O(n²) storage is
/// free.
pub struct DenseOp {
    a: Mat,
}

impl DenseOp {
    /// Wrap an explicit (symmetric) matrix.
    pub fn new(a: Mat) -> Self {
        DenseOp { a }
    }

    /// The wrapped matrix.
    pub fn mat(&self) -> &Mat {
        &self.a
    }
}

impl LinearOp for DenseOp {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        self.a.matmul(m)
    }

    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        self.a.matmul_into(m, out)
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.a.rows().min(self.a.cols()))
            .map(|i| self.a.get(i, i))
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        self.a.row(i).to_vec()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.a.get(i, j)
    }

    fn solve_hint(&self) -> SolveHint {
        SolveHint::DenseCholesky
    }

    fn dense(&self) -> Mat {
        self.a.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_op_is_its_own_materialisation() {
        let mut rng = Rng::new(1);
        let a = {
            let g = Mat::from_fn(12, 12, |_, _| rng.normal());
            let mut s = g.t_matmul(&g);
            s.add_diag(1.0);
            s
        };
        let op = DenseOp::new(a.clone());
        assert_eq!(op.dense(), a);
        assert_eq!(op.shape(), (12, 12));
        assert_eq!(op.solve_hint(), SolveHint::DenseCholesky);
        let m = Mat::from_fn(12, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&a.matmul(&m)) == 0.0);
        for i in 0..12 {
            assert_eq!(op.row(i), a.row(i).to_vec());
            assert_eq!(op.entry(i, (i + 3) % 12), a.get(i, (i + 3) % 12));
        }
    }

    #[test]
    fn default_row_comes_from_matmul() {
        // an op that only implements matmul still yields correct rows
        struct MatmulOnly(Mat);
        impl LinearOp for MatmulOnly {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn matmul(&self, m: &Mat) -> Mat {
                self.0.matmul(m)
            }
        }
        let mut rng = Rng::new(2);
        let g = Mat::from_fn(8, 8, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        let op = MatmulOnly(a.clone());
        for i in [0usize, 3, 7] {
            let r = op.row(i);
            for j in 0..8 {
                assert!((r[j] - a.get(i, j)).abs() < 1e-12);
            }
        }
        assert!(op.dense().max_abs_diff(&a) < 1e-12);
        assert_eq!(op.noise(), 0.0);
        assert!(op.as_any().is_none());
    }
}
