//! Closure of the operator algebra under addition and scaling:
//! [`AddedDiagOp`] (`A + σ²I`), [`DiagOp`] (`diag(d)`), [`SumOp`]
//! (`A + B`), and [`ScaledOp`] (`c·A`).
//!
//! `AddedDiagOp` is the load-bearing one: likelihood noise is expressed as
//! a *composition* instead of being baked into every kernel operator, so
//! the preconditioner builder and the Woodbury dispatcher can split any
//! model into "structure + σ²I" generically ([`LinearOp::noise_split`]).

use super::{LinearOp, SolveHint};
use crate::tensor::Mat;

/// `A + σ²I` with a learnable diagonal value (`σ² = exp(raw)`, appended as
/// the **last** raw parameter — the crate-wide noise convention).
pub struct AddedDiagOp<A> {
    inner: A,
    /// raw log σ²
    raw: f64,
}

impl<A: LinearOp> AddedDiagOp<A> {
    /// Compose `inner + value·I` (`value` > 0; stored in log space).
    pub fn new(inner: A, value: f64) -> Self {
        assert!(value > 0.0, "added diagonal must be positive");
        AddedDiagOp {
            inner,
            raw: value.ln(),
        }
    }

    /// Compose `inner + exp(raw)·I` directly from the raw (log-space)
    /// parameter — the lossless path hyperparameter updates should use
    /// (`exp(raw)` can underflow to 0.0, which [`AddedDiagOp::new`]
    /// rejects; the raw value itself is always representable).
    pub fn from_raw(inner: A, raw: f64) -> Self {
        AddedDiagOp { inner, raw }
    }

    /// The noise-free inner operator.
    pub fn inner(&self) -> &A {
        &self.inner
    }

    /// Mutable access to the inner operator (hyperparameter updates).
    pub fn inner_mut(&mut self) -> &mut A {
        &mut self.inner
    }

    /// Current diagonal value σ².
    pub fn value(&self) -> f64 {
        self.raw.exp()
    }

    /// Raw (log-space) diagonal parameter.
    pub fn raw_value(&self) -> f64 {
        self.raw
    }

    /// Overwrite the raw (log-space) diagonal parameter.
    pub fn set_raw_value(&mut self, raw: f64) {
        self.raw = raw;
    }

    /// `out += σ²·M` — the composition's own contribution to a product.
    fn add_noise_term(&self, m: &Mat, out: &mut Mat) {
        let sigma2 = self.value();
        for r in 0..out.rows() {
            let mrow = m.row(r);
            let orow = out.row_mut(r);
            for c in 0..orow.len() {
                orow[c] += sigma2 * mrow[c];
            }
        }
    }
}

impl<A: LinearOp> LinearOp for AddedDiagOp<A> {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }

    fn n_params(&self) -> usize {
        self.inner.n_params() + 1
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = self.inner.matmul(m);
        self.add_noise_term(m, &mut out);
        out
    }

    fn matmul_into(&self, m: &Mat, out: &mut Mat) {
        self.inner.matmul_into(m, out);
        self.add_noise_term(m, out);
    }

    fn prepare(&self) {
        self.inner.prepare()
    }

    fn mmm_tag(&self) -> u64 {
        self.inner.mmm_tag()
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let nk = self.inner.n_params();
        if param == nk {
            // d(A + e^raw I)/draw = σ² I
            let mut out = m.clone();
            out.scale_assign(self.value());
            return out;
        }
        self.inner.dmatmul(param, m)
    }

    fn diag(&self) -> Vec<f64> {
        let sigma2 = self.value();
        let mut d = self.inner.diag();
        for v in &mut d {
            *v += sigma2;
        }
        d
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let mut r = self.inner.row(i);
        r[i] += self.value();
        r
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let base = self.inner.entry(i, j);
        if i == j {
            base + self.value()
        } else {
            base
        }
    }

    fn solve_hint(&self) -> SolveHint {
        if self.inner.low_rank_factor().is_some() {
            SolveHint::Woodbury
        } else {
            self.inner.solve_hint()
        }
    }

    fn noise_split(&self) -> Option<(&dyn LinearOp, f64)> {
        Some((&self.inner, self.value()))
    }

    fn circulant_column(&self) -> Option<Vec<f64>> {
        // circulant + σ²I is circulant: the diagonal shift lands on c₀
        let mut col = self.inner.circulant_column()?;
        col[0] += self.value();
        Some(col)
    }

    fn dense(&self) -> Mat {
        let mut k = self.inner.dense();
        k.add_diag(self.value());
        k
    }
}

/// A fixed diagonal matrix `diag(d)` — FITC's exact-diagonal correction,
/// heteroskedastic noise, etc.
pub struct DiagOp {
    d: Vec<f64>,
}

impl DiagOp {
    /// Wrap a diagonal vector.
    pub fn new(d: Vec<f64>) -> Self {
        DiagOp { d }
    }

    /// The diagonal entries.
    pub fn values(&self) -> &[f64] {
        &self.d
    }
}

impl LinearOp for DiagOp {
    fn shape(&self) -> (usize, usize) {
        (self.d.len(), self.d.len())
    }

    fn matmul(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows(), self.d.len());
        let mut out = m.clone();
        for r in 0..out.rows() {
            let s = self.d[r];
            for v in out.row_mut(r) {
                *v *= s;
            }
        }
        out
    }

    fn diag(&self) -> Vec<f64> {
        self.d.clone()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let mut r = vec![0.0; self.d.len()];
        r[i] = self.d[i];
        r
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        if i == j {
            self.d[i]
        } else {
            0.0
        }
    }
}

/// `A + B`. Parameter blocks concatenate: `A`'s raw parameters first,
/// then `B`'s.
pub struct SumOp<A, B> {
    a: A,
    b: B,
}

impl<A: LinearOp, B: LinearOp> SumOp<A, B> {
    /// Compose `a + b` (shapes must agree).
    pub fn new(a: A, b: B) -> Self {
        assert_eq!(a.shape(), b.shape(), "SumOp: shape mismatch");
        SumOp { a, b }
    }

    /// Left operand.
    pub fn a(&self) -> &A {
        &self.a
    }

    /// Right operand.
    pub fn b(&self) -> &B {
        &self.b
    }
}

impl<A: LinearOp, B: LinearOp> LinearOp for SumOp<A, B> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn n_params(&self) -> usize {
        self.a.n_params() + self.b.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = self.a.matmul(m);
        out.add_assign(&self.b.matmul(m));
        out
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let na = self.a.n_params();
        if param < na {
            self.a.dmatmul(param, m)
        } else {
            self.b.dmatmul(param - na, m)
        }
    }

    fn diag(&self) -> Vec<f64> {
        let mut d = self.a.diag();
        for (v, w) in d.iter_mut().zip(self.b.diag()) {
            *v += w;
        }
        d
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let mut r = self.a.row(i);
        for (v, w) in r.iter_mut().zip(self.b.row(i)) {
            *v += w;
        }
        r
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.a.entry(i, j) + self.b.entry(i, j)
    }

    fn circulant_column(&self) -> Option<Vec<f64>> {
        // circulant matrices are closed under addition
        let mut col = self.a.circulant_column()?;
        let other = self.b.circulant_column()?;
        for (v, w) in col.iter_mut().zip(other) {
            *v += w;
        }
        Some(col)
    }

    fn solve_hint(&self) -> SolveHint {
        if self.a.circulant_column().is_some() && self.b.circulant_column().is_some() {
            SolveHint::CirculantFft
        } else {
            SolveHint::Iterative
        }
    }
}

/// `c · A` with a fixed scale factor. (A *learnable* scale belongs to the
/// model layer — see `kernels::LinearKernelOp` for the worked example.)
pub struct ScaledOp<A> {
    a: A,
    c: f64,
}

impl<A: LinearOp> ScaledOp<A> {
    /// Compose `c · a`.
    pub fn new(a: A, c: f64) -> Self {
        ScaledOp { a, c }
    }

    /// The wrapped operator.
    pub fn inner(&self) -> &A {
        &self.a
    }

    /// Current scale factor.
    pub fn scale(&self) -> f64 {
        self.c
    }

    /// Overwrite the scale factor.
    pub fn set_scale(&mut self, c: f64) {
        self.c = c;
    }
}

impl<A: LinearOp> LinearOp for ScaledOp<A> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn n_params(&self) -> usize {
        self.a.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let mut out = self.a.matmul(m);
        out.scale_assign(self.c);
        out
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let mut out = self.a.dmatmul(param, m);
        out.scale_assign(self.c);
        out
    }

    fn diag(&self) -> Vec<f64> {
        let mut d = self.a.diag();
        for v in &mut d {
            *v *= self.c;
        }
        d
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let mut r = self.a.row(i);
        for v in &mut r {
            *v *= self.c;
        }
        r
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.c * self.a.entry(i, j)
    }

    fn circulant_column(&self) -> Option<Vec<f64>> {
        // circulant matrices are closed under scaling
        let mut col = self.a.circulant_column()?;
        for v in &mut col {
            *v *= self.c;
        }
        Some(col)
    }

    fn solve_hint(&self) -> SolveHint {
        if self.a.circulant_column().is_some() {
            SolveHint::CirculantFft
        } else {
            SolveHint::Iterative
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::DenseOp;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(0.5);
        a.symmetrize();
        a
    }

    #[test]
    fn added_diag_matches_dense() {
        let a = spd(20, 1);
        let op = AddedDiagOp::new(DenseOp::new(a.clone()), 0.3);
        let mut want = a.clone();
        want.add_diag(0.3);
        assert!(op.dense().max_abs_diff(&want) < 1e-15);
        let mut rng = Rng::new(2);
        let m = Mat::from_fn(20, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-12);
        for (i, d) in op.diag().iter().enumerate() {
            assert!((d - want.get(i, i)).abs() < 1e-15);
        }
        assert_eq!(op.row(4), want.row(4).to_vec());
        let (inner, s) = op.noise_split().unwrap();
        assert!((s - 0.3).abs() < 1e-15);
        assert!(inner.dense().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn added_diag_noise_gradient_is_sigma2_m() {
        let op = AddedDiagOp::new(DenseOp::new(spd(10, 3)), 0.25);
        let mut rng = Rng::new(4);
        let m = Mat::from_fn(10, 2, |_, _| rng.normal());
        // DenseOp has 0 params, so param 0 is the diagonal
        assert_eq!(op.n_params(), 1);
        let d = op.dmatmul(0, &m);
        let mut want = m.clone();
        want.scale_assign(0.25);
        assert!(d.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn circulant_structure_lifts_through_compositions() {
        use crate::linalg::op::ToeplitzLinOp;
        let m = 8;
        let col: Vec<f64> = (0..m)
            .map(|k| {
                let d = k.min(m - k) as f64;
                (-0.2 * d * d).exp()
            })
            .collect();
        let t1 = ToeplitzLinOp::new(col.clone());
        let t2 = ToeplitzLinOp::new(col.clone());
        let op = AddedDiagOp::new(ScaledOp::new(SumOp::new(t1, t2), 0.5), 0.3);
        let lifted = op.circulant_column().expect("circulant lift");
        // 0.5·(c + c) + σ²·e₀ = c with σ² on the head
        assert!((lifted[0] - (col[0] + 0.3)).abs() < 1e-14);
        for k in 1..m {
            assert!((lifted[k] - col[k]).abs() < 1e-14, "k={k}");
        }
        assert_eq!(op.solve_hint(), crate::linalg::op::SolveHint::CirculantFft);
        // a non-circulant partner blocks the sum lift
        let decaying: Vec<f64> = (0..m).map(|k| 1.0 / (1.0 + k as f64)).collect();
        let blocked = SumOp::new(ToeplitzLinOp::new(col), ToeplitzLinOp::new(decaying));
        assert!(blocked.circulant_column().is_none());
    }

    #[test]
    fn sum_scaled_diag_compose() {
        let a = spd(15, 5);
        let b = spd(15, 6);
        let mut rng = Rng::new(7);
        let d: Vec<f64> = (0..15).map(|_| rng.uniform() + 0.1).collect();
        let op = SumOp::new(
            ScaledOp::new(DenseOp::new(a.clone()), 2.5),
            SumOp::new(DenseOp::new(b.clone()), DiagOp::new(d.clone())),
        );
        let mut want = a.clone();
        want.scale_assign(2.5);
        want.add_assign(&b);
        for i in 0..15 {
            let v = want.get(i, i) + d[i];
            want.set(i, i, v);
        }
        assert!(op.dense().max_abs_diff(&want) < 1e-12);
        let m = Mat::from_fn(15, 4, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-11);
        for i in [0usize, 7, 14] {
            for j in 0..15 {
                assert!((op.entry(i, j) - want.get(i, j)).abs() < 1e-12);
            }
        }
    }
}
