//! **Solve-scoped kernel materialisation plans** — deciding, per operator
//! and memory budget, *how* the per-iteration `K·M` product is produced.
//!
//! BBMM streams kernel tiles so no n×n matrix is ever formed — the right
//! call at the memory ceiling, but wasteful below it: a 50-iteration mBCG
//! solve re-evaluates every squared distance and `exp()` fifty times.
//! Following Wang et al. 2019 (*Exact GPs on a Million Data Points*), the
//! choice to materialise or stream is made deliberately:
//!
//! - [`MmmPlan::MaterializeK`] — build `K` once, reuse it across **all**
//!   mBCG iterations (and across a batched sweep's per-step products);
//!   every later product is one register-blocked GEMM. Invalidated by a
//!   hyperparameter update.
//! - [`MmmPlan::CachedDistances`] — stationary kernels cache the r² panel
//!   once; both the value tile and the ∂/∂log ℓ tile (`matmul` *and*
//!   `dmatmul`) derive from the same cached r², so a training step pays
//!   **one** distance pass instead of `1 + n_params` — and, because r²
//!   depends only on `X`, the panel survives every hyperparameter update.
//! - [`MmmPlan::Stream`] — the tile path (the seed behaviour), for `n`
//!   over budget.
//!
//! The budget comes from `--mmm-budget-mb` / `BBMM_MMM_BUDGET_MB`
//! (default [`DEFAULT_BUDGET_MB`]); [`MmmPlan::auto`] picks the plan.
//! `KernelCovOp`, `ShardedCovOp`, and (through the shared covariance)
//! `BatchOp::shared` consume the plan; `SolvePlanCache` fingerprints
//! include it via [`super::LinearOp::mmm_tag`], so switching plans rebuilds
//! cached solve plans instead of silently mixing them. A device-aware
//! variant ("materialise on backend X") is the ROADMAP's multi-backend
//! seam.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// Arithmetic precision of the per-iteration kernel MMM.
///
/// mBCG's *reductions* (α/β dots, tridiagonal tracking, residual norms)
/// always run in f64 — what this knob selects is how the `Stream` /
/// `CachedDistances` **tiles** are computed and stored:
///
/// - [`Precision::F64`] — everything in f64 (the default; bit-identical
///   to the historical path).
/// - [`Precision::Mixed`] — kernel tiles and probe panels in f32
///   (double the SIMD lane count, half the panel memory), with the tile
///   contraction accumulating into f64 at `KB`-block granularity
///   ([`crate::tensor::gemm::gemm_mixed_into`]). Per-product error is
///   ~1e-6 relative, solve-level mean/variance error ~1e-5 relative —
///   the accuracy contract the precision-parity tests gate.
///
/// Mixed mode **degrades, never lies**: plans/operators that have no f32
/// tile path (`MaterializeK`, non-stationary kernels, cross-covariance
/// blocks) silently compute in f64. The precision is part of
/// `mmm_tag`/`fingerprint()`, so `SolvePlanCache` (and the LOVE posterior
/// cache) invalidate on a precision switch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 tiles (default).
    #[default]
    F64,
    /// f32 tile compute/storage, f64 accumulation.
    Mixed,
}

impl Precision {
    /// Stable discriminant mixed into operator fingerprints (shifted next
    /// to [`MmmPlan::tag`] by the operators).
    pub fn tag(self) -> u64 {
        match self {
            Precision::F64 => 0,
            Precision::Mixed => 1,
        }
    }

    /// Short name for logs, flags, and bench tables.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }

    /// Parse a `--precision` flag value (`f64` | `mixed`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Precision::F64),
            "mixed" | "f32" => Some(Precision::Mixed),
            _ => None,
        }
    }
}

/// How a kernel covariance operator produces its matrix-matrix products.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmmPlan {
    /// Stream kernel tiles per product (O(n·t) peak memory, the seed path).
    Stream,
    /// Cache the n×n squared-distance panel once (stationary kernels);
    /// value and derivative tiles both derive from it.
    CachedDistances,
    /// Materialise K once per hyperparameter setting; products are GEMMs.
    MaterializeK,
}

impl MmmPlan {
    /// Stable discriminant mixed into operator fingerprints.
    pub fn tag(self) -> u64 {
        match self {
            MmmPlan::Stream => 1,
            MmmPlan::CachedDistances => 2,
            MmmPlan::MaterializeK => 3,
        }
    }

    /// Short name for logs and bench tables.
    pub fn name(self) -> &'static str {
        match self {
            MmmPlan::Stream => "stream",
            MmmPlan::CachedDistances => "cached-r2",
            MmmPlan::MaterializeK => "materialize-k",
        }
    }

    /// Pick a plan for an n×n covariance under `budget_bytes` of panel
    /// memory: over budget streams; under budget, stationary kernels cache
    /// r² (derivatives ride the same panel and hyperparameter updates keep
    /// it), others materialise K (per-entry virtual evaluation is the cost
    /// worth amortising there).
    ///
    /// The budget bounds **each operator's** panel: b independent
    /// covariances (e.g. per-tenant datasets) can hold b panels, so size
    /// the budget for the deployment's operator count. Sweep candidates
    /// built through `KernelCovOp::share_cached` share one r² panel (and
    /// non-stationary siblings decline `MaterializeK`), so a sweep stays
    /// within one panel regardless of b.
    pub fn auto(n: usize, stationary: bool, budget_bytes: usize) -> MmmPlan {
        let panel = n
            .saturating_mul(n)
            .saturating_mul(std::mem::size_of::<f64>());
        if n == 0 || panel > budget_bytes {
            MmmPlan::Stream
        } else if stationary {
            MmmPlan::CachedDistances
        } else {
            MmmPlan::MaterializeK
        }
    }

    /// Device/worker-aware [`MmmPlan::auto`]: "materialise shard `s` on
    /// backend `b`". Plans for **one shard's** `shard_len × n` panel
    /// against that worker's own budget, so a
    /// [`crate::runtime::dist::ShardBackend`] with W workers shards the
    /// aggregate K storage W ways instead of replicating the single-process
    /// decision — each worker materialises (or streams) exactly its own
    /// row-block. Same plan preferences as [`MmmPlan::auto`].
    pub fn auto_sharded(
        shard_len: usize,
        n: usize,
        stationary: bool,
        budget_bytes: usize,
    ) -> MmmPlan {
        let panel = shard_len
            .saturating_mul(n)
            .saturating_mul(std::mem::size_of::<f64>());
        if n == 0 || shard_len == 0 || panel > budget_bytes {
            MmmPlan::Stream
        } else if stationary {
            MmmPlan::CachedDistances
        } else {
            MmmPlan::MaterializeK
        }
    }
}

/// Default materialisation budget when neither the flag nor the env var is
/// set: 1 GiB admits the panel up to n ≈ 11.5k.
pub const DEFAULT_BUDGET_MB: usize = 1024;

static BUDGET_MB: AtomicUsize = AtomicUsize::new(0);

/// The materialisation budget in bytes (cached after first read;
/// `BBMM_MMM_BUDGET_MB` overrides the default, [`set_budget_mb`] overrides
/// both).
pub fn budget_bytes() -> usize {
    budget_mb().saturating_mul(1024 * 1024)
}

fn budget_mb() -> usize {
    let cached = BUDGET_MB.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let mb = std::env::var("BBMM_MMM_BUDGET_MB")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&m| m > 0)
        .unwrap_or(DEFAULT_BUDGET_MB);
    BUDGET_MB.store(mb, Ordering::Relaxed);
    mb
}

/// Override the budget (the `--mmm-budget-mb` CLI flag). Affects operators
/// constructed after the call.
pub fn set_budget_mb(mb: usize) {
    if mb > 0 {
        BUDGET_MB.store(mb, Ordering::Relaxed);
    }
}

// 0 = unset (read env once), 1 = F64, 2 = Mixed — same caching pattern as
// BUDGET_MB so `--precision` and `BBMM_PRECISION` behave alike
static PRECISION: AtomicU8 = AtomicU8::new(0);

/// The process-default [`Precision`] (cached after first read;
/// `BBMM_PRECISION=f64|mixed` overrides the default,
/// [`set_default_precision`] overrides both). Operators constructed
/// without an explicit precision pick this up.
pub fn default_precision() -> Precision {
    match PRECISION.load(Ordering::Relaxed) {
        1 => Precision::F64,
        2 => Precision::Mixed,
        _ => {
            let p = std::env::var("BBMM_PRECISION")
                .ok()
                .and_then(|s| Precision::parse(&s))
                .unwrap_or(Precision::F64);
            PRECISION.store(if p == Precision::Mixed { 2 } else { 1 }, Ordering::Relaxed);
            p
        }
    }
}

/// Override the default precision (the `--precision` CLI flag). Affects
/// operators constructed after the call.
pub fn set_default_precision(p: Precision) {
    PRECISION.store(if p == Precision::Mixed { 2 } else { 1 }, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_respects_the_budget() {
        let mb = 8 * 1024 * 1024; // 8 MB → n up to 1024
        assert_eq!(MmmPlan::auto(1024, true, mb), MmmPlan::CachedDistances);
        assert_eq!(MmmPlan::auto(1024, false, mb), MmmPlan::MaterializeK);
        assert_eq!(MmmPlan::auto(1025, true, mb), MmmPlan::Stream);
        assert_eq!(MmmPlan::auto(0, true, mb), MmmPlan::Stream);
        // saturation guard: enormous n must not overflow the panel size
        assert_eq!(MmmPlan::auto(usize::MAX, true, mb), MmmPlan::Stream);
    }

    #[test]
    fn auto_sharded_plans_per_worker_panels() {
        let mb = 8 * 1024 * 1024; // admits shard_len·n up to 1024²
        // a full-row plan would stream at n = 4096, but a 256-row shard fits
        assert_eq!(MmmPlan::auto(4096, true, mb), MmmPlan::Stream);
        assert_eq!(
            MmmPlan::auto_sharded(256, 4096, true, mb),
            MmmPlan::CachedDistances
        );
        assert_eq!(
            MmmPlan::auto_sharded(256, 4096, false, mb),
            MmmPlan::MaterializeK
        );
        assert_eq!(MmmPlan::auto_sharded(512, 4096, true, mb), MmmPlan::Stream);
        assert_eq!(MmmPlan::auto_sharded(0, 4096, true, mb), MmmPlan::Stream);
        assert_eq!(
            MmmPlan::auto_sharded(usize::MAX, usize::MAX, true, mb),
            MmmPlan::Stream
        );
    }

    #[test]
    fn tags_are_distinct() {
        assert_ne!(MmmPlan::Stream.tag(), MmmPlan::CachedDistances.tag());
        assert_ne!(MmmPlan::CachedDistances.tag(), MmmPlan::MaterializeK.tag());
        assert_eq!(MmmPlan::Stream.name(), "stream");
    }

    #[test]
    fn budget_has_a_positive_default() {
        assert!(budget_bytes() > 0);
    }

    #[test]
    fn precision_tags_names_and_parsing() {
        assert_ne!(Precision::F64.tag(), Precision::Mixed.tag());
        assert_eq!(Precision::default(), Precision::F64);
        assert_eq!(Precision::parse("mixed"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("F64"), Some(Precision::F64));
        assert_eq!(Precision::parse("f32"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Precision::Mixed.name(), "mixed");
    }
}
