//! The interpolation sandwich `W·A·Wᵀ` — SKI/KISS-GP's structure
//! (paper §5, Wilson & Nickisch [50]) as an explicit composition.
//!
//! [`SparseInterp`] is the sparse cubic-convolution interpolation matrix
//! `W` (4 non-zeros per row); [`InterpOp`] sandwiches **any** inner
//! operator between `W` and `Wᵀ`, so `W·T_grid·Wᵀ` (classic SKI over a
//! Toeplitz grid kernel) and `W·(B ⊗ T)·Wᵀ` (multi-dim SKI) are the same
//! few lines of composition. A matmul costs `O(t·n)` for the two sparse
//! applies plus one inner matmul.

use super::LinearOp;
use crate::tensor::Mat;
use crate::util::par;

/// Keys cubic-convolution interpolation kernel (a = −1/2).
#[inline]
fn cubic_weight(s: f64) -> f64 {
    let s = s.abs();
    if s < 1.0 {
        (1.5 * s - 2.5) * s * s + 1.0
    } else if s < 2.0 {
        ((-0.5 * s + 2.5) * s - 4.0) * s + 2.0
    } else {
        0.0
    }
}

/// Sparse interpolation matrix: 4 non-zeros per row.
pub struct SparseInterp {
    /// grid indices per row (4 each)
    idx: Vec<[usize; 4]>,
    /// interpolation weights per row (4 each, summing to 1)
    w: Vec<[f64; 4]>,
    m: usize,
}

impl SparseInterp {
    /// Build cubic interpolation weights for points `z` (1-D features) onto
    /// a regular grid `[lo, hi]` with `m` nodes. Points are clamped to the
    /// interpolable interior.
    pub fn new(z: &[f64], lo: f64, hi: f64, m: usize) -> Self {
        assert!(m >= 4, "need at least 4 grid points for cubic interpolation");
        assert!(hi > lo);
        let h = (hi - lo) / (m - 1) as f64;
        let mut idx = Vec::with_capacity(z.len());
        let mut w = Vec::with_capacity(z.len());
        for &zi in z {
            // position in grid units, clamped so the 4-point stencil fits
            let p = ((zi - lo) / h).clamp(1.0, (m - 3) as f64 + 0.999_999);
            let j0 = p.floor() as usize;
            let u = p - j0 as f64;
            let ids = [j0 - 1, j0, j0 + 1, j0 + 2];
            let ws = [
                cubic_weight(1.0 + u),
                cubic_weight(u),
                cubic_weight(1.0 - u),
                cubic_weight(2.0 - u),
            ];
            idx.push(ids);
            w.push(ws);
        }
        SparseInterp { idx, w, m }
    }

    /// Number of interpolated points (rows of `W`).
    pub fn n(&self) -> usize {
        self.idx.len()
    }

    /// Number of grid nodes (columns of `W`).
    pub fn m(&self) -> usize {
        self.m
    }

    /// `W · M` — (n×m)·(m×t) in O(4·n·t).
    pub fn apply(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows(), self.m);
        let t = m.cols();
        let n = self.n();
        let mut out = Mat::zeros(n, t);
        let idx = &self.idx;
        let w = &self.w;
        par::parallel_rows_mut(out.data_mut(), n, t, |row_lo, chunk| {
            for (ri, orow) in chunk.chunks_mut(t).enumerate() {
                let r = row_lo + ri;
                for a in 0..4 {
                    let wa = w[r][a];
                    let mrow = m.row(idx[r][a]);
                    for c in 0..t {
                        orow[c] += wa * mrow[c];
                    }
                }
            }
        });
        out
    }

    /// `Wᵀ · M` — (m×n)·(n×t) in O(4·n·t).
    pub fn apply_t(&self, mat: &Mat) -> Mat {
        assert_eq!(mat.rows(), self.n());
        let t = mat.cols();
        let mut out = Mat::zeros(self.m, t);
        // scatter-add; serial over n (t is small) — could shard by target
        for r in 0..self.n() {
            let mrow = mat.row(r);
            for a in 0..4 {
                let target = self.idx[r][a];
                let wa = self.w[r][a];
                let orow = out.row_mut(target);
                for c in 0..t {
                    orow[c] += wa * mrow[c];
                }
            }
        }
        out
    }

    /// Weights/indices of row i (for O(1)-ish row access).
    pub fn row_stencil(&self, i: usize) -> (&[usize; 4], &[f64; 4]) {
        (&self.idx[i], &self.w[i])
    }

    /// Dense `W` (tests, small sizes).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.n(), self.m);
        for i in 0..self.n() {
            for a in 0..4 {
                let v = out.get(i, self.idx[i][a]) + self.w[i][a];
                out.set(i, self.idx[i][a], v);
            }
        }
        out
    }
}

/// `W · A · Wᵀ` for any inner operator `A` on the grid. Parameters pass
/// straight through to the inner operator (`d(WAWᵀ)/dθ = W(dA/dθ)Wᵀ`).
pub struct InterpOp<A> {
    w: SparseInterp,
    inner: A,
}

impl<A: LinearOp> InterpOp<A> {
    /// Sandwich `inner` between `w` and `wᵀ` (inner must be m×m).
    pub fn new(w: SparseInterp, inner: A) -> Self {
        assert_eq!(w.m(), inner.shape().0, "InterpOp: grid size mismatch");
        InterpOp { w, inner }
    }

    /// The interpolation matrix `W`.
    pub fn interp(&self) -> &SparseInterp {
        &self.w
    }

    /// The inner grid operator `A`.
    pub fn inner(&self) -> &A {
        &self.inner
    }

    /// Mutable inner operator (hyperparameter updates).
    pub fn inner_mut(&mut self) -> &mut A {
        &mut self.inner
    }
}

impl<A: LinearOp> LinearOp for InterpOp<A> {
    fn shape(&self) -> (usize, usize) {
        (self.w.n(), self.w.n())
    }

    fn n_params(&self) -> usize {
        self.inner.n_params()
    }

    fn matmul(&self, m: &Mat) -> Mat {
        let wtm = self.w.apply_t(m); // m×t
        let awtm = self.inner.matmul(&wtm); // m×t (structured)
        self.w.apply(&awtm) // n×t
    }

    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        let wtm = self.w.apply_t(m);
        let dawtm = self.inner.dmatmul(param, &wtm);
        self.w.apply(&dawtm)
    }

    fn diag(&self) -> Vec<f64> {
        // diag_i = wᵢᵀ A wᵢ over the 4-point stencil — O(16·n) inner-entry
        // lookups (O(1) each for Toeplitz/Kronecker/dense inners)
        (0..self.w.n())
            .map(|i| {
                let (ids, ws) = self.w.row_stencil(i);
                let mut s = 0.0;
                for a in 0..4 {
                    for b in 0..4 {
                        s += ws[a] * ws[b] * self.inner.entry(ids[a], ids[b]);
                    }
                }
                s
            })
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        // rowᵢ = (wᵢᵀ A) Wᵀ: one inner matmul against the 4-sparse stencil
        // column, then O(4·n) stencil dots
        let (ids, ws) = self.w.row_stencil(i);
        let m = self.w.m();
        let mut e = Mat::zeros(m, 1);
        for a in 0..4 {
            let v = e.get(ids[a], 0) + ws[a];
            e.set(ids[a], 0, v);
        }
        let u = self.inner.matmul(&e); // m×1
        (0..self.w.n())
            .map(|j| {
                let (jds, jws) = self.w.row_stencil(j);
                let mut s = 0.0;
                for b in 0..4 {
                    s += jws[b] * u.get(jds[b], 0);
                }
                s
            })
            .collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let (ids, ws) = self.w.row_stencil(i);
        let (jds, jws) = self.w.row_stencil(j);
        let mut s = 0.0;
        for a in 0..4 {
            for b in 0..4 {
                s += ws[a] * jws[b] * self.inner.entry(ids[a], jds[b]);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::{DenseOp, ToeplitzLinOp};
    use crate::util::Rng;

    fn interp(n: usize, m: usize, seed: u64) -> SparseInterp {
        let mut rng = Rng::new(seed);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        SparseInterp::new(&z, -1.2, 1.2, m)
    }

    #[test]
    fn weights_sum_to_one() {
        let w = interp(100, 40, 1);
        for i in 0..100 {
            let (_ids, ws) = w.row_stencil(i);
            let s: f64 = ws.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "row {i}: {s}");
        }
    }

    #[test]
    fn apply_matches_dense_w() {
        let w = interp(30, 20, 2);
        let wd = w.to_dense();
        let mut rng = Rng::new(3);
        let m = Mat::from_fn(20, 3, |_, _| rng.normal());
        assert!(w.apply(&m).max_abs_diff(&wd.matmul(&m)) < 1e-12);
        let v = Mat::from_fn(30, 2, |_, _| rng.normal());
        assert!(w.apply_t(&v).max_abs_diff(&wd.t_matmul(&v)) < 1e-12);
    }

    #[test]
    fn sandwich_matches_dense_w_a_wt() {
        let w = interp(25, 16, 4);
        let mut rng = Rng::new(5);
        let g = Mat::from_fn(16, 16, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        let wd = w.to_dense();
        let want = wd.matmul(&a).matmul_t(&wd);
        let op = InterpOp::new(w, DenseOp::new(a));
        assert!(op.dense().max_abs_diff(&want) < 1e-11);
        let m = Mat::from_fn(25, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-10);
        for (i, d) in op.diag().iter().enumerate() {
            assert!((d - want.get(i, i)).abs() < 1e-11, "diag {i}");
        }
        for i in [0usize, 12, 24] {
            let r = op.row(i);
            for j in 0..25 {
                assert!((r[j] - want.get(i, j)).abs() < 1e-11, "row {i} col {j}");
                assert!((op.entry(i, j) - want.get(i, j)).abs() < 1e-11);
            }
        }
    }

    #[test]
    fn toeplitz_inner_uses_o1_entries() {
        // the classic SKI shape: W·T·Wᵀ with T a grid RBF kernel
        let w = interp(40, 32, 6);
        let col: Vec<f64> = (0..32)
            .map(|i| (-0.5 * (i as f64 * 0.1).powi(2)).exp())
            .collect();
        let t = ToeplitzLinOp::new(col);
        let td = t.dense();
        let wd = w.to_dense();
        let want = wd.matmul(&td).matmul_t(&wd);
        let op = InterpOp::new(w, t);
        for (i, d) in op.diag().iter().enumerate() {
            assert!((d - want.get(i, i)).abs() < 1e-11, "diag {i}");
        }
        let mut rng = Rng::new(7);
        let m = Mat::from_fn(40, 2, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-9);
    }
}
