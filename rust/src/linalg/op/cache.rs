//! **`SolvePlanCache`** — factorisation state cached *across* predict
//! calls, keyed by deployment slot and invalidated by operator content.
//!
//! A serving loop (or a model holding a plan handle) asks the cache for a
//! [`SolvePlan`] under a stable slot key (the tenant name, `"default"`,
//! …). The cache compares the operator's content fingerprint
//! ([`LinearOp::fingerprint`]) against the cached entry:
//!
//! - **hit** — same fingerprint: the Cholesky/Woodbury factor, circulant
//!   spectrum, or pivoted-Cholesky preconditioner is reused as-is; a
//!   predict call pays zero factorisation cost.
//! - **invalidation** — the fingerprint changed (a hyperparameter update
//!   rewrote the operator's entries): the stale plan is dropped and
//!   rebuilt once.
//! - **miss** — first request for the slot: the plan is built and stored.
//!
//! Plans are handed out as `Arc`s, so concurrent request handlers share
//! one factorisation without copying; the map lock is held across a
//! rebuild (deliberately — racing handlers would otherwise factorise the
//! same operator twice).
//!
//! Long-lived deployments bound the cache with
//! [`SolvePlanCache::with_policy`]: an **LRU capacity** (slots beyond the
//! bound are evicted least-recently-used first) and/or an **idle TTL** —
//! slots idle longer than the TTL are swept out on the next *cold* cache
//! access (any miss/invalidation/expiry, where a plan rebuild dwarfs the
//! map walk), so a quiet tenant's factorisation memory is released by
//! ongoing traffic without taxing the hot hit path. Both are observable
//! through the [`SolvePlanCache::evictions`] /
//! [`SolvePlanCache::expirations`] counters; the unbounded default keeps
//! the original semantics.

use super::solve::{plan, SolveOptions, SolvePlan};
use super::LinearOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Slot {
    fingerprint: u64,
    precond_rank: usize,
    plan: Arc<SolvePlan>,
    /// last hit/build time (drives both LRU ordering and the idle TTL)
    last_used: Instant,
}

/// Cache of prepared [`SolvePlan`]s keyed by deployment slot; see the
/// module docs for hit/miss/invalidation/eviction semantics.
#[derive(Default)]
pub struct SolvePlanCache {
    slots: Mutex<HashMap<String, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
    evictions: AtomicU64,
    expirations: AtomicU64,
    /// maximum live slots (`None` = unbounded)
    capacity: Option<usize>,
    /// idle time after which a slot is rebuilt on next use (`None` = never)
    ttl: Option<Duration>,
}

impl SolvePlanCache {
    /// Empty cache, unbounded (no capacity limit, no TTL).
    pub fn new() -> Self {
        SolvePlanCache::default()
    }

    /// Empty cache with an eviction policy: keep at most `capacity` slots
    /// (least-recently-used evicted first) and/or drop slots idle longer
    /// than `ttl` (swept on any cache access; a swept key rebuilds as a
    /// miss on its next request). `None` disables the respective bound.
    pub fn with_policy(capacity: Option<usize>, ttl: Option<Duration>) -> Self {
        SolvePlanCache {
            capacity,
            ttl,
            ..SolvePlanCache::default()
        }
    }

    /// The plan for `op` under slot `key`, building (miss) or rebuilding
    /// (fingerprint/invalidations change) as needed. Recomputes the O(n)
    /// content fingerprint per call; callers holding an **immutable**
    /// operator (a serving deployment) should fingerprint once and use
    /// [`SolvePlanCache::get_or_plan_with_fingerprint`].
    pub fn get_or_plan(
        &self,
        key: &str,
        op: &dyn LinearOp,
        opts: &SolveOptions,
    ) -> Arc<SolvePlan> {
        self.get_or_plan_with_fingerprint(key, op.fingerprint(), op, opts)
    }

    /// [`SolvePlanCache::get_or_plan`] with a caller-computed fingerprint —
    /// the hit path does no operator probing at all, so a serving tick
    /// over frozen hyperparameters is O(1) in the cache.
    pub fn get_or_plan_with_fingerprint(
        &self,
        key: &str,
        fp: u64,
        op: &dyn LinearOp,
        opts: &SolveOptions,
    ) -> Arc<SolvePlan> {
        let now = Instant::now();
        let mut slots = self.slots.lock().unwrap();
        if let Some(slot) = slots.get_mut(key) {
            let expired = self
                .ttl
                .map_or(false, |ttl| now.duration_since(slot.last_used) > ttl);
            if !expired && slot.fingerprint == fp && slot.precond_rank == opts.precond_rank {
                self.hits.fetch_add(1, Ordering::Relaxed);
                slot.last_used = now;
                return Arc::clone(&slot.plan);
            }
            if expired {
                // stale by idle time: rebuilt below (counted separately
                // from content invalidations)
                self.expirations.fetch_add(1, Ordering::Relaxed);
            } else {
                self.invalidations.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        // Cold path only (miss / invalidation / expiry — a rebuild is about
        // to dwarf any map walk): sweep every OTHER expired slot so quiet
        // tenants' factorisation memory is released by ongoing traffic
        // without adding an O(slots) scan to the hot hit path. A pure-hit
        // steady state defers the sweep; pair with a capacity bound when a
        // hard memory ceiling is required.
        if let Some(ttl) = self.ttl {
            let expired: Vec<String> = slots
                .iter()
                .filter(|(k2, s)| k2.as_str() != key && now.duration_since(s.last_used) > ttl)
                .map(|(k2, _)| k2.clone())
                .collect();
            for k2 in expired {
                slots.remove(&k2);
                self.expirations.fetch_add(1, Ordering::Relaxed);
            }
        }
        let built = Arc::new(plan(op, opts));
        slots.insert(
            key.to_string(),
            Slot {
                fingerprint: fp,
                precond_rank: opts.precond_rank,
                plan: Arc::clone(&built),
                last_used: now,
            },
        );
        // LRU capacity bound: evict the least-recently-used *other* slots
        // until the cache fits (the slot just written is always kept).
        if let Some(cap) = self.capacity {
            while slots.len() > cap.max(1) {
                let lru = slots
                    .iter()
                    .filter(|(k, _)| k.as_str() != key)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, _)| k.clone());
                match lru {
                    Some(k) => {
                        slots.remove(&k);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            }
        }
        built
    }

    /// Cached slot count.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no slot is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (deployment reload).
    pub fn clear(&self) {
        self.slots.lock().unwrap().clear();
    }

    /// Requests answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// First-time slot builds.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Rebuilds forced by an operator-content (hyperparameter) change.
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// Slots dropped by the LRU capacity bound.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Rebuilds forced by the idle TTL.
    pub fn expirations(&self) -> u64 {
        self.expirations.load(Ordering::Relaxed)
    }

    /// One-line counter summary for serving logs.
    pub fn stats(&self) -> String {
        format!(
            "plans={} hits={} misses={} invalidations={} evictions={} expirations={}",
            self.len(),
            self.hits(),
            self.misses(),
            self.invalidations(),
            self.evictions(),
            self.expirations()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DenseKernelOp;
    use crate::kernels::Rbf;
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn model(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1)
    }

    #[test]
    fn miss_then_hit_shares_one_plan() {
        let cache = SolvePlanCache::new();
        let op = model(30, 1);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_plan("t", &op, &opts);
        let p2 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must reuse the plan");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 1, 0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hyperparameter_change_invalidates() {
        let cache = SolvePlanCache::new();
        let mut op = model(25, 2);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_plan("t", &op, &opts);
        let mut raw = op.params();
        raw[0] += 0.3; // lengthscale moves → entries change → new fingerprint
        op.set_params(&raw);
        let p2 = cache.get_or_plan("t", &op, &opts);
        assert!(!Arc::ptr_eq(&p1, &p2), "stale plan must be rebuilt");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 0, 1));
        // and the rebuilt plan is now stable
        let p3 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p2, &p3));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn noise_only_change_also_invalidates() {
        let cache = SolvePlanCache::new();
        let mut op = model(20, 3);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("t", &op, &opts);
        let mut raw = op.params();
        let last = raw.len() - 1;
        raw[last] += 0.5; // log σ² moves: diagonal-only change
        op.set_params(&raw);
        let _ = cache.get_or_plan("t", &op, &opts);
        assert_eq!(cache.invalidations(), 1);
    }

    #[test]
    fn slots_are_independent() {
        let cache = SolvePlanCache::new();
        let a = model(15, 4);
        let b = model(15, 5);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("a", &a, &opts);
        let _ = cache.get_or_plan("b", &b, &opts);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn lru_capacity_evicts_least_recently_used() {
        let cache = SolvePlanCache::with_policy(Some(2), None);
        let a = model(12, 10);
        let b = model(12, 11);
        let c = model(12, 12);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("a", &a, &opts);
        let _ = cache.get_or_plan("b", &b, &opts);
        // touch "a" so "b" becomes the LRU slot
        let _ = cache.get_or_plan("a", &a, &opts);
        let _ = cache.get_or_plan("c", &c, &opts);
        assert_eq!(cache.len(), 2, "capacity bound must hold");
        assert_eq!(cache.evictions(), 1);
        // "a" (recently used) and "c" (just built) survive; "b" was evicted
        let _ = cache.get_or_plan("a", &a, &opts);
        let _ = cache.get_or_plan("c", &c, &opts);
        assert_eq!(cache.hits(), 3);
        let _ = cache.get_or_plan("b", &b, &opts);
        assert_eq!(cache.misses(), 4, "evicted slot must rebuild as a miss");
        assert_eq!(cache.evictions(), 2, "reinserting b evicts the next LRU");
        assert!(cache.stats().contains("evictions=2"));
    }

    #[test]
    fn idle_ttl_expires_slots() {
        let cache = SolvePlanCache::with_policy(None, Some(Duration::from_millis(5)));
        let op = model(12, 13);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_plan("t", &op, &opts);
        let p2 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p1, &p2), "within the TTL the plan is reused");
        std::thread::sleep(Duration::from_millis(20));
        let p3 = cache.get_or_plan("t", &op, &opts);
        assert!(!Arc::ptr_eq(&p1, &p3), "idle slot must rebuild after TTL");
        assert_eq!(cache.expirations(), 1);
        assert_eq!(cache.invalidations(), 0, "TTL expiry is not an invalidation");
        // the rebuilt slot is fresh again
        let p4 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p3, &p4));
    }

    #[test]
    fn idle_ttl_sweep_releases_quiet_slots_on_cold_accesses() {
        // a quiet tenant's factorisation must be dropped by some OTHER
        // tenant's cold traffic (here: a new tenant's first request) — not
        // retained until the quiet one returns
        let cache = SolvePlanCache::with_policy(None, Some(Duration::from_millis(5)));
        let quiet = model(12, 14);
        let busy = model(12, 15);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("quiet", &quiet, &opts);
        std::thread::sleep(Duration::from_millis(20));
        let _ = cache.get_or_plan("busy", &busy, &opts);
        assert_eq!(cache.len(), 1, "quiet slot must be swept by busy traffic");
        assert_eq!(cache.expirations(), 1);
        // hot hits on the surviving slot do not sweep (and nothing to sweep)
        let _ = cache.get_or_plan("busy", &busy, &opts);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn unbounded_default_never_evicts() {
        let cache = SolvePlanCache::new();
        let opts = SolveOptions::default();
        let ops: Vec<DenseKernelOp> = (0..6).map(|i| model(10, 20 + i)).collect();
        for (i, op) in ops.iter().enumerate() {
            let _ = cache.get_or_plan(&format!("slot-{i}"), op, &opts);
        }
        assert_eq!(cache.len(), 6);
        assert_eq!(cache.evictions(), 0);
        assert_eq!(cache.expirations(), 0);
    }

    #[test]
    fn precond_rank_is_part_of_the_key() {
        let cache = SolvePlanCache::new();
        let op = model(18, 6);
        let mut opts = SolveOptions::default();
        let _ = cache.get_or_plan("t", &op, &opts);
        opts.precond_rank += 2;
        let _ = cache.get_or_plan("t", &op, &opts);
        assert_eq!(cache.invalidations(), 1);
        assert!(cache.stats().contains("invalidations=1"));
    }
}
