//! **`SolvePlanCache`** — factorisation state cached *across* predict
//! calls, keyed by deployment slot and invalidated by operator content.
//!
//! A serving loop (or a model holding a plan handle) asks the cache for a
//! [`SolvePlan`] under a stable slot key (the tenant name, `"default"`,
//! …). The cache compares the operator's content fingerprint
//! ([`LinearOp::fingerprint`]) against the cached entry:
//!
//! - **hit** — same fingerprint: the Cholesky/Woodbury factor, circulant
//!   spectrum, or pivoted-Cholesky preconditioner is reused as-is; a
//!   predict call pays zero factorisation cost.
//! - **invalidation** — the fingerprint changed (a hyperparameter update
//!   rewrote the operator's entries): the stale plan is dropped and
//!   rebuilt once.
//! - **miss** — first request for the slot: the plan is built and stored.
//!
//! Plans are handed out as `Arc`s, so concurrent request handlers share
//! one factorisation without copying; the map lock is held across a
//! rebuild (deliberately — racing handlers would otherwise factorise the
//! same operator twice).

use super::solve::{plan, SolveOptions, SolvePlan};
use super::LinearOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct Slot {
    fingerprint: u64,
    precond_rank: usize,
    plan: Arc<SolvePlan>,
}

/// Cache of prepared [`SolvePlan`]s keyed by deployment slot; see the
/// module docs for hit/miss/invalidation semantics.
#[derive(Default)]
pub struct SolvePlanCache {
    slots: Mutex<HashMap<String, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

impl SolvePlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        SolvePlanCache::default()
    }

    /// The plan for `op` under slot `key`, building (miss) or rebuilding
    /// (fingerprint/invalidations change) as needed. Recomputes the O(n)
    /// content fingerprint per call; callers holding an **immutable**
    /// operator (a serving deployment) should fingerprint once and use
    /// [`SolvePlanCache::get_or_plan_with_fingerprint`].
    pub fn get_or_plan(
        &self,
        key: &str,
        op: &dyn LinearOp,
        opts: &SolveOptions,
    ) -> Arc<SolvePlan> {
        self.get_or_plan_with_fingerprint(key, op.fingerprint(), op, opts)
    }

    /// [`SolvePlanCache::get_or_plan`] with a caller-computed fingerprint —
    /// the hit path does no operator probing at all, so a serving tick
    /// over frozen hyperparameters is O(1) in the cache.
    pub fn get_or_plan_with_fingerprint(
        &self,
        key: &str,
        fp: u64,
        op: &dyn LinearOp,
        opts: &SolveOptions,
    ) -> Arc<SolvePlan> {
        let mut slots = self.slots.lock().unwrap();
        if let Some(slot) = slots.get(key) {
            if slot.fingerprint == fp && slot.precond_rank == opts.precond_rank {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(&slot.plan);
            }
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        let built = Arc::new(plan(op, opts));
        slots.insert(
            key.to_string(),
            Slot {
                fingerprint: fp,
                precond_rank: opts.precond_rank,
                plan: Arc::clone(&built),
            },
        );
        built
    }

    /// Cached slot count.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no slot is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (deployment reload).
    pub fn clear(&self) {
        self.slots.lock().unwrap().clear();
    }

    /// Requests answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// First-time slot builds.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Rebuilds forced by an operator-content (hyperparameter) change.
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// One-line `hits/misses/invalidations` summary for serving logs.
    pub fn stats(&self) -> String {
        format!(
            "plans={} hits={} misses={} invalidations={}",
            self.len(),
            self.hits(),
            self.misses(),
            self.invalidations()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DenseKernelOp;
    use crate::kernels::Rbf;
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn model(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1)
    }

    #[test]
    fn miss_then_hit_shares_one_plan() {
        let cache = SolvePlanCache::new();
        let op = model(30, 1);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_plan("t", &op, &opts);
        let p2 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must reuse the plan");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 1, 0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hyperparameter_change_invalidates() {
        let cache = SolvePlanCache::new();
        let mut op = model(25, 2);
        let opts = SolveOptions::default();
        let p1 = cache.get_or_plan("t", &op, &opts);
        let mut raw = op.params();
        raw[0] += 0.3; // lengthscale moves → entries change → new fingerprint
        op.set_params(&raw);
        let p2 = cache.get_or_plan("t", &op, &opts);
        assert!(!Arc::ptr_eq(&p1, &p2), "stale plan must be rebuilt");
        assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 0, 1));
        // and the rebuilt plan is now stable
        let p3 = cache.get_or_plan("t", &op, &opts);
        assert!(Arc::ptr_eq(&p2, &p3));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn noise_only_change_also_invalidates() {
        let cache = SolvePlanCache::new();
        let mut op = model(20, 3);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("t", &op, &opts);
        let mut raw = op.params();
        let last = raw.len() - 1;
        raw[last] += 0.5; // log σ² moves: diagonal-only change
        op.set_params(&raw);
        let _ = cache.get_or_plan("t", &op, &opts);
        assert_eq!(cache.invalidations(), 1);
    }

    #[test]
    fn slots_are_independent() {
        let cache = SolvePlanCache::new();
        let a = model(15, 4);
        let b = model(15, 5);
        let opts = SolveOptions::default();
        let _ = cache.get_or_plan("a", &a, &opts);
        let _ = cache.get_or_plan("b", &b, &opts);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn precond_rank_is_part_of_the_key() {
        let cache = SolvePlanCache::new();
        let op = model(18, 6);
        let mut opts = SolveOptions::default();
        let _ = cache.get_or_plan("t", &op, &opts);
        opts.precond_rank += 2;
        let _ = cache.get_or_plan("t", &op, &opts);
        assert_eq!(cache.invalidations(), 1);
        assert!(cache.stats().contains("invalidations=1"));
    }
}
