//! [`ShardedOp`] — any row-sharded partial-product backend
//! ([`crate::linalg::mbcg::ShardedMmm`]) lifted into the operator algebra.
//!
//! `ShardedMmm` is the seam along which shards map onto devices/processes
//! (Wang et al. 2019); wrapping it as a [`LinearOp`] lets the generic
//! solve dispatcher, the engines, and the serving coordinator consume a
//! sharded backend exactly like any other composition — `matmul` assembles
//! the per-shard row blocks through [`crate::linalg::mbcg::sharded_mmm`]'s
//! work-stealing pool.

use super::LinearOp;
use crate::linalg::mbcg::{sharded_mmm, ShardedMmm};
use crate::tensor::Mat;

/// A [`ShardedMmm`] backend as a composable [`LinearOp`].
///
/// `diag`/`row` default to one shard-assembled product against a basis
/// vector per row (O(n·matmul) for the full diagonal); backends that can
/// do better supply the diagonal up front via [`ShardedOp::with_diag`].
pub struct ShardedOp<S> {
    inner: S,
    /// optional precomputed full-operator diagonal
    diag: Option<Vec<f64>>,
}

impl<S: ShardedMmm> ShardedOp<S> {
    /// Wrap a sharded backend.
    pub fn new(inner: S) -> Self {
        ShardedOp { inner, diag: None }
    }

    /// Attach a precomputed diagonal (cheap for kernel backends).
    pub fn with_diag(mut self, diag: Vec<f64>) -> Self {
        assert_eq!(diag.len(), self.inner.n());
        self.diag = Some(diag);
        self
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// Number of row shards.
    pub fn shard_count(&self) -> usize {
        self.inner.n_shards()
    }
}

impl<S: ShardedMmm> LinearOp for ShardedOp<S> {
    fn shape(&self) -> (usize, usize) {
        (self.inner.n(), self.inner.n())
    }

    fn matmul(&self, m: &Mat) -> Mat {
        sharded_mmm(&self.inner, m)
    }

    fn diag(&self) -> Vec<f64> {
        match &self.diag {
            Some(d) => d.clone(),
            None => (0..self.inner.n()).map(|i| self.row(i)[i]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::ops::Range;

    /// Toy backend: shard s multiplies its row-block of a dense matrix.
    struct DenseSharded {
        a: Mat,
        shards: Vec<Range<usize>>,
    }

    impl ShardedMmm for DenseSharded {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn n_shards(&self) -> usize {
            self.shards.len()
        }
        fn shard_rows(&self, s: usize) -> Range<usize> {
            self.shards[s].clone()
        }
        fn shard_matmul(&self, s: usize, m: &Mat, out: &mut [f64]) {
            let t = m.cols();
            for (ri, i) in self.shards[s].clone().enumerate() {
                let arow = self.a.row(i);
                let orow = &mut out[ri * t..(ri + 1) * t];
                for (j, &av) in arow.iter().enumerate() {
                    let mrow = m.row(j);
                    for c in 0..t {
                        orow[c] += av * mrow[c];
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_op_matches_dense_across_shard_counts() {
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(31, 31, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        let m = Mat::from_fn(31, 3, |_, _| rng.normal());
        let want = a.matmul(&m);
        for &s in &[1usize, 3, 7] {
            let op = ShardedOp::new(DenseSharded {
                a: a.clone(),
                shards: crate::runtime::shard::partition_rows(31, s),
            });
            assert_eq!(op.shard_count(), s);
            assert!(op.matmul(&m).max_abs_diff(&want) < 1e-11, "shards {s}");
            // default diag assembles from basis products
            for (i, d) in op.diag().iter().enumerate() {
                assert!((d - a.get(i, i)).abs() < 1e-11, "shards {s} diag {i}");
            }
        }
    }

    #[test]
    fn precomputed_diag_is_used() {
        let a = Mat::eye(5);
        let op = ShardedOp::new(DenseSharded {
            a,
            shards: crate::runtime::shard::partition_rows(5, 2),
        })
        .with_diag(vec![9.0; 5]);
        assert_eq!(op.diag(), vec![9.0; 5]);
    }
}
